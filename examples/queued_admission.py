"""Oversubscribed public cluster: N users' requests exceed pod capacity.

    PYTHONPATH=src python examples/queued_admission.py

Six users each request 4 chips of a 16-chip pod (24 > 16).  Nothing
raises: the BlockScheduler admits what fits, waitlists the rest (QUEUED
state), and auto-admits queued blocks — activating and running them — as
earlier blocks finish and expire.  Every block runs its full step target
to completion, and the Monitor reports queue depth, per-admission wait
times, and pod utilization along the way.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=16")

import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import repro.configs as C
from repro.core.block import BlockState
from repro.core.daemon import ClusterDaemon
from repro.core.runtime import JobSpec
from repro.core.topology import Topology
from repro.models.config import ShapeConfig
from repro.train.optimizer import OptConfig

N_USERS = 6
CHIPS_EACH = 4
STEPS_EACH = 4          # steps a block runs before its period ends


def main():
    topo = Topology(n_pods=1, pod_x=4, pod_y=4)
    ctl = ClusterDaemon(topo, ckpt_root="artifacts/queue_ckpt",
                            state_path="artifacts/queue_state.json")
    shape = ShapeConfig("q", "train", seq_len=32, global_batch=4,
                        microbatch=1)

    print(f"== {N_USERS} users x {CHIPS_EACH} chips = "
          f"{N_USERS * CHIPS_EACH} requested, pod has {topo.n_chips} ==")
    apps = []
    for i in range(N_USERS):
        job = JobSpec(C.get_smoke("xlstm_350m"), shape,
                      opt=OptConfig(warmup_steps=1, total_steps=20), seed=i)
        app_id, grant = ctl.submit(f"user{i}", f"job {i}", CHIPS_EACH,
                                   job=job)
        state = ctl.registry.get(app_id).state.value
        print(f"  user{i}: {app_id} -> "
              f"{'ADMITTED ' + grant.block_id if grant else 'QUEUED'}"
              f" (state={state})")
        apps.append(app_id)
    print(f"  queue depth: {ctl.scheduler.queue_depth()}")

    done = set()
    epoch = 0
    while len(done) < N_USERS:
        epoch += 1
        running = ctl.registry.by_state(BlockState.RUNNING)
        ctl.run_steps({a: 1 for a in running})
        for a in running:
            if ctl.runtimes[a].step_count >= STEPS_EACH:
                res = ctl.download(a)          # RUNNING -> DONE
                ctl.expire(a)                  # frees chips -> pump admits
                done.add(a)
                print(f"  [{epoch:02d}] {a} completed "
                      f"{res['steps']} steps and expired; "
                      f"queue depth now {ctl.scheduler.queue_depth()}")
        ctl.tick()

    print("== all blocks ran to completion ==")
    for a in apps:
        blk = ctl.registry.get(a)
        assert blk.state == BlockState.EXPIRED, (a, blk.state)
    rep = ctl.monitor.queue_report()
    print(f"  enqueued={rep['enqueued_total']} "
          f"admitted_from_queue={rep['admitted_total']} "
          f"final_depth={rep['depth']}")
    print(f"  queue wait: mean={rep['mean_wait_s']:.2f}s "
          f"max={rep['max_wait_s']:.2f}s")
    print(f"  pod utilization: mean={rep['utilization']:.0%} "
          f"now={rep['utilization_now']:.0%}")
    assert rep["depth"] == 0
    assert rep["admitted_total"] >= N_USERS - topo.n_chips // CHIPS_EACH
    print("QUEUED_ADMISSION_OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
