"""Multi-user web gateway demo: the paper's "full control and monitoring
over web", end to end over real HTTP.

    PYTHONPATH=src python examples/web_gateway_demo.py

A live ``ClusterDaemon`` (background pump thread) fronts a 16-chip pod
through the stdlib HTTP gateway.  Three users with *distinct session
profiles* (the paper's per-user configuration files: different default
priorities, quotas and deadlines) drive the full paper lifecycle purely
over the wire:

  * **alice** submits a *gang* — a trainer + eval server that must
    co-start (all-or-nothing admission);
  * **bob** walks the explicit workflow: register -> admin review ->
    confirm (capability token) -> activate -> run -> monitor;
  * **carol** (high-priority profile, tight deadline) submits into a full
    pod — the scheduler *preempts* bob (checkpoint + release) to admit
    her, and bob auto-resumes when she finishes;

while each block's long-poll event feed shows every lifecycle transition
live.  Jobs are device-free simulator blocks so the demo runs in seconds.
"""
import json
import os
import sys
import urllib.error
import urllib.request

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.core.daemon import ClusterDaemon
from repro.core.topology import Topology
from repro.gateway import GatewayServer, ProfileStore, UserProfile

BASE = None


def req(method, path, token=None, body=None, timeout=30):
    r = urllib.request.Request(BASE + path, method=method,
                               data=(json.dumps(body).encode()
                                     if body is not None else None))
    if token:
        r.add_header("Authorization", f"Bearer {token}")
    if body is not None:
        r.add_header("Content-Type", "application/json")
    try:
        with urllib.request.urlopen(r, timeout=timeout) as resp:
            return resp.status, json.loads(resp.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read())


def show_feed(name, app_id, token, after=0):
    _, page = req("GET", f"/v1/blocks/{app_id}/events?after={after}", token)
    for ev in page["events"]:
        detail = ev.get("state") or ev.get("reason") or \
            (f"wait {ev.get('wait_s', 0):.2f}s" if ev["kind"] == "admitted"
             else "")
        print(f"    [{name}:{ev['seq']:3d}] {ev['kind']:<10} {detail}")
    return page["next_after"]


def main():
    global BASE
    topo = Topology(n_pods=1, pod_x=4, pod_y=4)          # 16 chips
    dev = jax.devices()[0]
    daemon = ClusterDaemon(topo, devices=[dev] * topo.n_chips,
                           ckpt_root="artifacts/gw_demo_ckpt",
                           state_path="artifacts/gw_demo_state.json",
                           background=True, tick_interval_s=0.02)
    profiles = ProfileStore([
        # the paper's per-user configuration: each user gets their own
        # defaults, applied whenever a request omits the field
        UserProfile("alice", "tok-alice", priority=0, max_chips=8),
        UserProfile("bob", "tok-bob", priority=0, duration_s=600.0),
        UserProfile("carol", "tok-carol", priority=5, deadline_s=30.0),
        UserProfile("root", "tok-admin", admin=True),
    ])
    server = GatewayServer(daemon, profiles).start()
    BASE = server.url
    print(f"== gateway serving {topo.n_chips}-chip pod at {BASE} ==")
    for tok in ("tok-alice", "tok-bob", "tok-carol"):
        _, prof = req("GET", "/v1/profile", tok)
        p = prof["profile"]
        print(f"  {p['user']}: priority={p['priority']} "
              f"quota={p['max_chips']} deadline={p['deadline_s']}")

    sim = {"kind": "sim", "step_s": 0.002, "ckpt_every": 2}

    print("== alice: gang submission (trainer + eval co-start) ==")
    _, gang = req("POST", "/v1/gangs", "tok-alice", {
        "members": [{"job_description": "trainer", "n_chips": 4,
                     "job": sim},
                    {"job_description": "eval server", "n_chips": 4,
                     "job": sim}]})
    assert gang["admitted"], gang
    a_train, a_eval = gang["app_ids"]
    print(f"  co-started: {a_train} + {a_eval}")

    print("== bob: explicit paper workflow over HTTP ==")
    _, r = req("POST", "/v1/register", "tok-bob",
               {"job_description": "hybrid ssm experiments", "n_chips": 8})
    b = r["app_id"]
    print(f"  (1) registered {b}: state={r['state']}")
    _, rv = req("POST", f"/v1/blocks/{b}/review", "tok-admin", {})
    print(f"  (2) admin assigned block {rv['grant']['block_id']}")
    _, st = req("GET", f"/v1/blocks/{b}", "tok-bob")
    _, cf = req("POST", f"/v1/blocks/{b}/confirm", "tok-bob",
                {"token": st["token"]})
    print(f"  (3) confirmed with capability token: state={cf['state']}")
    req("POST", f"/v1/blocks/{b}/activate", "tok-bob", {"job": sim})
    _, rn = req("POST", f"/v1/blocks/{b}/run", "tok-bob", {})
    print(f"  (4+5) activated and running: state={rn['state']}")
    _, stp = req("POST", f"/v1/blocks/{b}/steps", "tok-bob", {"rounds": 6})
    print(f"  (6) stepped: {stp['steps']} steps completed")

    _, cl = req("GET", "/v1/cluster", "tok-bob")
    print(f"== pod now full: {cl['free_chips']} free of "
          f"{cl['n_chips']}, queue depth {cl['queue_depth']} ==")

    print("== carol: high-priority submit into the full pod ==")
    b_seen = req("GET", f"/v1/blocks/{b}/events", "tok-bob")[1]["next_after"]
    _, c = req("POST", "/v1/submit", "tok-carol",
               {"job_description": "urgent deadline job", "n_chips": 8,
                "est_steps": 10, "job": sim})
    assert c["admitted"], c
    _, bob_st = req("GET", f"/v1/blocks/{b}", "tok-bob")
    print(f"  carol admitted instantly ({c['app_id']}); "
          f"bob: {bob_st['state']} "
          f"(preempt #{bob_st['preempt_count']}, checkpointed)")
    req("POST", f"/v1/blocks/{c['app_id']}/steps", "tok-carol",
        {"rounds": 10})
    _, dl = req("GET", f"/v1/blocks/{c['app_id']}/download", "tok-carol")
    print(f"  (7) carol downloads results: {dl['steps']} steps")
    req("POST", f"/v1/blocks/{c['app_id']}/expire", "tok-carol", {})

    # long-poll bob's feed until the daemon's pump auto-resumes him
    deadline_evs, state = [], None
    while state != "running":
        _, page = req("GET",
                      f"/v1/blocks/{b}/events?after={b_seen}&timeout_s=5",
                      "tok-bob")
        deadline_evs += page["events"]
        b_seen = page["next_after"]
        assert page["events"], "auto-resume event feed timed out"
        state = req("GET", f"/v1/blocks/{b}", "tok-bob")[1]["state"]
    kinds = [e["kind"] for e in deadline_evs]
    print(f"== bob auto-resumed by the daemon pump "
          f"(long-polled events: {kinds}) ==")

    print("== per-block event feeds (every lifecycle transition) ==")
    for name, app, tok in [("alice/trainer", a_train, "tok-alice"),
                           ("bob", b, "tok-bob"),
                           ("carol", c["app_id"], "tok-carol")]:
        print(f"  {name}:")
        show_feed(name, app, tok)

    for app, tok in [(a_train, "tok-alice"), (a_eval, "tok-alice"),
                     (b, "tok-bob")]:
        req("POST", f"/v1/blocks/{app}/expire", tok, {})
    _, rep = req("GET", "/v1/cluster", "tok-admin")
    print(f"== final: {rep['free_chips']}/{rep['n_chips']} chips free, "
          f"preemptions={rep['preemption']['preempted_total']}, "
          f"resumes={rep['preemption']['resumed_total']}, "
          f"deadline hits={rep['deadlines']['deadline_hits']} ==")
    server.stop()
    daemon.stop()
    print("WEB_GATEWAY_DEMO_OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
