"""Autostep engine + SSE + dashboard demo: the paper's daemon-owned job
execution and "full control and monitoring over web", with zero client
step traffic.

    PYTHONPATH=src python examples/autostep_dashboard_demo.py

A live ``ClusterDaemon`` (background pump) fronts a 16-chip pod through
the HTTP gateway.  Three users submit simulator blocks with **autostep**
enabled at submission — from that moment the daemon's engine drives every
block to completion; this script never POSTs ``/steps``.  Meanwhile an
admin watcher holds the cluster-wide **Server-Sent Events** stream open
and sees every lifecycle transition and step land live, exactly what the
browser dashboard at ``<gateway>/ui`` renders.  The demo asserts:

  * all three blocks reach DONE purely through the engine (step counts
    match each block's ``until_steps``, zero client step calls);
  * the SSE stream shows the full lifecycle for every block
    (approved -> confirmed -> active -> running -> done);
  * the dashboard assets are served at ``/ui``.
"""
import json
import os
import sys
import threading
import urllib.error
import urllib.request

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.core.daemon import ClusterDaemon
from repro.core.topology import Topology
from repro.gateway import GatewayServer, ProfileStore, UserProfile

BASE = None
STEP_CALLS = 0          # client /steps POSTs (the whole point: stays 0)
TARGETS = {"alice": 60, "bob": 40, "carol": 30}


def req(method, path, token=None, body=None, timeout=30):
    global STEP_CALLS
    if path.endswith("/steps"):
        STEP_CALLS += 1
    r = urllib.request.Request(BASE + path, method=method,
                               data=(json.dumps(body).encode()
                                     if body is not None else None))
    if token:
        r.add_header("Authorization", f"Bearer {token}")
    try:
        with urllib.request.urlopen(r, timeout=timeout) as resp:
            return resp.status, json.loads(resp.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read())


def main():
    global BASE
    topo = Topology(n_pods=1, pod_x=4, pod_y=4)          # 16 chips
    dev = jax.devices()[0]
    daemon = ClusterDaemon(topo, devices=[dev] * topo.n_chips,
                           ckpt_root="artifacts/autostep_demo_ckpt",
                           background=True, tick_interval_s=0.02)
    profiles = ProfileStore([
        UserProfile("alice", "tok-alice"),
        UserProfile("bob", "tok-bob"),
        UserProfile("carol", "tok-carol", priority=2, deadline_s=60.0),
        UserProfile("root", "tok-admin", admin=True),
    ])
    server = GatewayServer(daemon, profiles).start()
    BASE = server.url
    print(f"== gateway serving {topo.n_chips}-chip pod at {BASE} ==")
    print(f"== browser dashboard: {BASE}/ui ==")

    with urllib.request.urlopen(BASE + "/ui", timeout=5) as r:
        html = r.read().decode()
    assert 'id="cluster-report"' in html and "/ui/app.js" in html
    print("   dashboard served: cluster report + live feed markup OK")

    # ------------------------- admin SSE watcher (the dashboard's feed)
    events = []
    done_users = set()
    all_done = threading.Event()

    def watch():
        url = (f"{BASE}/v1/events/stream?after=0&max_s=60"
               f"&access_token=tok-admin")
        with urllib.request.urlopen(url, timeout=90) as resp:
            for raw in resp:
                line = raw.decode().rstrip("\n")
                if not line.startswith("data: "):
                    continue
                ev = json.loads(line[len("data: "):])
                events.append(ev)
                if ev["kind"] == "state" and ev.get("state") == "done":
                    done_users.add(ev["user"])
                    if done_users >= set(TARGETS):
                        all_done.set()
                        return

    watcher = threading.Thread(target=watch, daemon=True)
    watcher.start()

    # --------------- three users submit; the ENGINE does all the stepping
    print("== 3 users submit with autostep enabled (no client steps) ==")
    apps = {}
    chips = {"alice": 8, "bob": 4, "carol": 4}
    for user, steps in TARGETS.items():
        s, r = req("POST", "/v1/submit", f"tok-{user}", {
            "job_description": f"{user}'s autostepped job",
            "n_chips": chips[user],
            "job": {"kind": "sim", "step_s": 0.002, "ckpt_every": 10},
            "autostep": {"until_steps": steps}})
        assert s == 201 and r["admitted"], r
        assert r["autostep"] and r["autostep"]["enabled"]
        apps[user] = r["app_id"]
        print(f"   {user}: {r['app_id']} admitted, engine armed "
              f"(until_steps={steps})")

    assert all_done.wait(30.0), (
        f"engine did not finish all blocks; done={done_users}")
    watcher.join(5.0)

    print("== every block ran to completion daemon-side ==")
    for user, app in apps.items():
        s, st = req("GET", f"/v1/blocks/{app}", f"tok-{user}")
        assert st["state"] == "done" and st["steps"] == TARGETS[user], st
        print(f"   {user}: state={st['state']} steps={st['steps']}"
              f"/{TARGETS[user]}")
    assert STEP_CALLS == 0, f"client made {STEP_CALLS} /steps calls"
    print(f"   client POST /steps calls: {STEP_CALLS} (engine-driven)")

    print("== SSE stream saw the whole lifecycle, live ==")
    by_app = {}
    for ev in events:
        if ev["kind"] == "state" and ev.get("app_id"):
            by_app.setdefault(ev["app_id"], []).append(ev["state"])
    for user, app in apps.items():
        states = by_app.get(app, [])
        assert states == ["approved", "confirmed", "active", "running",
                          "done"], (user, states)
        print(f"   {user}: {' -> '.join(states)}")
    n_steps = sum(1 for ev in events if ev["kind"] == "step")
    print(f"   ({len(events)} SSE frames observed, {n_steps} step events)")

    for user, app in apps.items():
        s, dl = req("GET", f"/v1/blocks/{app}/download", f"tok-{user}")
        assert dl["steps"] == TARGETS[user]
        req("POST", f"/v1/blocks/{app}/expire", f"tok-{user}", {})
    s, rep = req("GET", "/v1/cluster", "tok-admin")
    print(f"== final: {rep['free_chips']}/{rep['n_chips']} chips free, "
          f"utilization_now={rep['queue']['utilization_now']:.0%} ==")
    server.stop()
    daemon.stop()
    print("AUTOSTEP_DASHBOARD_DEMO_OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
