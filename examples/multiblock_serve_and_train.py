"""Mixed-workload multi-block session: one tenant TRAINS while another
SERVES (prefill+decode) on a disjoint block — the heterogeneous-usage case
the public cluster was built for.

    PYTHONPATH=src python examples/multiblock_serve_and_train.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time

import jax
import jax.numpy as jnp

import repro.configs as C
from repro.core.daemon import ClusterDaemon
from repro.core.runtime import JobSpec
from repro.core.topology import Topology
from repro.models.config import ShapeConfig
from repro.train.optimizer import OptConfig


def main():
    topo = Topology(n_pods=1, pod_x=4, pod_y=2)
    ctl = ClusterDaemon(topo, ckpt_root="artifacts/mixed_ckpt")

    train_shape = ShapeConfig("t", "train", seq_len=64, global_batch=8,
                              microbatch=2)
    serve_shape = ShapeConfig("s", "decode", seq_len=96, global_batch=4)

    a_train = ctl.register("alice", "training", 4, arch="mistral_nemo_12b")
    a_serve = ctl.register("bob", "serving", 4, arch="deepseek_7b")
    g1 = ctl.review(a_train)
    g2 = ctl.review(a_serve)
    ctl.confirm(a_train, g1.token)
    ctl.confirm(a_serve, g2.token)
    ctl.activate(a_train, JobSpec(C.get_smoke("mistral_nemo_12b"), train_shape,
                                  opt=OptConfig(warmup_steps=2, total_steps=50)))
    ctl.activate(a_serve, JobSpec(C.get_smoke("deepseek_7b"), serve_shape,
                                  kind="serve"))
    ctl.run(a_train)
    ctl.run(a_serve)

    print("running 8 rounds: alice trains, bob decodes, same host…")
    out = ctl.step_all(rounds=8)
    for app, rounds in out.items():
        times = [f"{r['step_s']*1e3:.0f}ms" for r in rounds[1:4]]
        kind = ctl.runtimes[app].job.kind
        print(f"  {app} [{kind}]: {times}")

    rep = ctl.interference_report()
    print(f"isolation: {rep.isolated} (shared links: "
          f"{sum(rep.shared_links.values())})")
    tok = ctl.runtimes[a_serve].token
    print(f"bob's decoded tokens (batch 0, last step): {int(tok[0, 0])}")
    ctl.expire(a_train)
    ctl.expire(a_serve)
    print("DONE")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
