"""Quickstart: train a reduced-config model end-to-end with the public API.

    PYTHONPATH=src python examples/quickstart.py

Picks the xLSTM family (smallest), builds sharded train state on whatever
devices exist, runs 60 steps of the production train step (microbatched,
remat, AdamW) on the synthetic pipeline, checkpoints, and restores.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import train as train_cli


def main():
    return train_cli.main([
        "--arch", "xlstm_350m", "--smoke",
        "--steps", "60", "--seq-len", "128", "--global-batch", "4",
        "--microbatch", "2", "--lr", "1e-3",
        "--ckpt-dir", "artifacts/quickstart_ckpt", "--ckpt-every", "25",
        "--log-every", "5",
    ])


if __name__ == "__main__":
    raise SystemExit(main())
