"""Checkpoint-backed preemption on a live cluster.

    PYTHONPATH=src python examples/preemption_demo.py

A low-priority training block owns the whole 4-chip pod.  A high-priority
request arrives; instead of waiting for the low block's period to end (the
PR-1 behavior), the scheduler suspends the victim — drains its in-flight
steps, checkpoints synchronously, releases the chips — and admits the
urgent block immediately.  When the urgent block finishes, ``tick()``
auto-resumes the victim from its checkpoint (same step count, bit-identical
state) and it runs to its own completion.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import repro.configs as C
from repro.core.block import BlockState
from repro.core.daemon import ClusterDaemon
from repro.core.runtime import JobSpec
from repro.core.topology import Topology
from repro.models.config import ShapeConfig
from repro.train.optimizer import OptConfig

LOW_TARGET_STEPS = 6
HIGH_TARGET_STEPS = 4


def state_of(ctl, app):
    return ctl.registry.get(app).state.value


def main():
    topo = Topology(n_pods=1, pod_x=2, pod_y=2)
    ctl = ClusterDaemon(topo, ckpt_root="artifacts/preempt_demo_ckpt",
                            state_path="artifacts/preempt_demo_state.json")
    shape = ShapeConfig("d", "train", seq_len=32, global_batch=4,
                        microbatch=1)

    low_job = JobSpec(C.get_smoke("xlstm_350m"), shape,
                      opt=OptConfig(warmup_steps=1, total_steps=20), seed=0)
    low, g_low = ctl.submit("lois", "background pretrain", 4, job=low_job,
                            priority=0)
    print(f"== low-priority block {g_low.block_id} holds all "
          f"{topo.n_chips} chips ==")
    ctl.step_all(rounds=3)
    ctl.runtimes[low].save(async_=False)     # periodic checkpoint
    ctl.step_all(rounds=2)
    print(f"  low block at step {ctl.runtimes[low].step_count}, "
          f"{ctl.runtimes[low].progress_lost} steps since last checkpoint")

    high_job = JobSpec(C.get_smoke("xlstm_350m"), shape,
                       opt=OptConfig(warmup_steps=1, total_steps=20), seed=1)
    high, g_high = ctl.submit("hana", "urgent eval", 4, job=high_job,
                              priority=5)
    assert g_high is not None, "high-priority request should preempt"
    print(f"== high-priority request admitted instantly: "
          f"{g_high.block_id} ==")
    print(f"  states: low={state_of(ctl, low)} high={state_of(ctl, high)}")
    blk = ctl.registry.get(low)
    print(f"  victim checkpointed at step "
          f"{blk.preemptions[-1]['checkpoint_step']} "
          f"(progress lost before save: "
          f"{blk.preemptions[-1]['progress_lost_steps']} steps)")

    while ctl.runtimes[high].step_count < HIGH_TARGET_STEPS:
        ctl.step_all(rounds=1)
    ctl.download(high)
    ctl.expire(high)                         # frees chips -> auto-resume
    print(f"== urgent block done after {HIGH_TARGET_STEPS} steps; "
          f"tick auto-resumed the victim ==")
    print(f"  states: low={state_of(ctl, low)} high={state_of(ctl, high)}")
    assert ctl.registry.get(low).state == BlockState.RUNNING

    rt = ctl.runtimes[low]
    resumed_at = rt.step_count
    while rt.step_count < LOW_TARGET_STEPS:
        ctl.step_all(rounds=1)
    print(f"  victim resumed at step {resumed_at} and ran to "
          f"{rt.step_count}")

    rep = ctl.monitor.preemption_report()
    print(f"  preemptions={rep['preempted_total']} "
          f"resumes={rep['resumed_total']} "
          f"max_progress_lost={rep['max_progress_lost_steps']} steps")
    print(f"  p50 wait: high={rep['p50_wait_high_s'] * 1e3:.2f}ms")
    ctl.partitioner.check_invariants()
    print("PREEMPTION_DEMO_OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
