"""Gang admission: a multi-block job (trainer + eval server) co-starts
atomically or not at all — the paper follow-up "Multi and Independent
Block Approach in Public Cluster" (arXiv:0708.3446).

    PYTHONPATH=src python examples/gang_admission.py

A 16-chip pod is half-occupied by a background tenant.  Bob then submits a
*gang*: an 8-chip trainer plus a 4-chip eval server that must co-start
(the eval server scores the trainer's checkpoints — starting either alone
is useless).  The trainer alone would fit the 8 free chips, but the
scheduler waitlists the gang as one all-or-nothing unit instead of
admitting it piecemeal; when the background block expires, both members
are admitted under a single partitioner lock hold and run together.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=16")

import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import repro.configs as C
from repro.core.block import BlockState
from repro.core.daemon import ClusterDaemon
from repro.core.runtime import JobSpec
from repro.core.topology import Topology
from repro.models.config import ShapeConfig
from repro.train.optimizer import OptConfig

FILLER_STEPS = 3


def main():
    topo = Topology(n_pods=1, pod_x=4, pod_y=4)
    ctl = ClusterDaemon(topo, ckpt_root="artifacts/gang_ckpt",
                            state_path="artifacts/gang_state.json")
    train_shape = ShapeConfig("t", "train", seq_len=32, global_batch=4,
                              microbatch=1)
    serve_shape = ShapeConfig("s", "serve", seq_len=32, global_batch=2)

    filler_job = JobSpec(C.get_smoke("xlstm_350m"), train_shape,
                         opt=OptConfig(warmup_steps=1, total_steps=20))
    filler, g = ctl.submit("alice", "background training", 8, job=filler_job)
    print(f"== alice holds 8 of {topo.n_chips} chips "
          f"({'admitted' if g else 'queued'}) ==")

    gang_members = [
        ("trainer", 8, JobSpec(C.get_smoke("xlstm_350m"), train_shape,
                               opt=OptConfig(warmup_steps=1,
                                             total_steps=20), seed=1)),
        ("eval server", 4, JobSpec(C.get_smoke("xlstm_350m"), serve_shape,
                                   kind="serve", seed=2)),
    ]
    free_before = ctl.partitioner.free_capacity()
    app_ids, grants = ctl.submit_gang("bob", gang_members)
    print(f"bob's gang (trainer 8 + eval 4 = 12 chips, {free_before} free): "
          f"{'ADMITTED' if grants else 'WAITLISTED as a unit'}")
    assert grants is None, "gang must not co-start into 8 free chips"
    # all-or-nothing: the trainer alone would fit, but nothing was admitted
    assert ctl.partitioner.free_capacity() == free_before
    for a in app_ids:
        st = ctl.registry.get(a).state
        print(f"  {a}: state={st.value} "
              f"(gang={ctl.registry.get(a).request.gang_id})")
        assert st == BlockState.QUEUED
    ctl.partitioner.check_invariants()

    print(f"driving alice's block for {FILLER_STEPS} steps, then expiring…")
    ctl.step_all(rounds=FILLER_STEPS)
    ctl.download(filler)
    ctl.expire(filler)                  # frees 8 -> 16 free: gang co-starts
    states = {a: ctl.registry.get(a).state for a in app_ids}
    print(f"after expiry: {[s.value for s in states.values()]}")
    assert all(s == BlockState.RUNNING for s in states.values())

    out = ctl.step_all(rounds=2)
    for a in app_ids:
        kind = ctl.runtimes[a].job.kind
        print(f"  {a} [{kind}]: {len(out[a])} steps, "
              f"{ctl.registry.get(a).grant.n_chips} chips")
        assert len(out[a]) == 2
    rep = ctl.interference_report()
    print(f"isolation between gang members + host: {rep.isolated}")
    ctl.partitioner.check_invariants()
    print("GANG_ADMISSION_OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
