"""The paper's Fig. 2 workflow, end to end, with two concurrent tenants.

    PYTHONPATH=src python examples/public_cluster_session.py

Simulates the LIPI Public Cluster on an 16-device host stand-in:
  1. alice and bob register applications (different architectures)
  2. the administrator reviews and assigns disjoint contiguous blocks
  3. users reconfirm with their capability tokens
  4. blocks are activated (sub-mesh built, step compiled = "MPD ring boot")
  5. both jobs run CONCURRENTLY (multi-block execution)
  6. the monitor tracks usage; the interference report proves isolation
  7. alice downloads her results; a chip failure hits bob's block and the
     controller migrates + restores it automatically; blocks expire.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=16")

import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import repro.configs as C
from repro.core.daemon import ClusterDaemon
from repro.core.runtime import JobSpec
from repro.core.topology import Topology
from repro.models.config import ShapeConfig
from repro.train.optimizer import OptConfig


def main():
    topo = Topology(n_pods=1, pod_x=4, pod_y=4)
    ctl = ClusterDaemon(topo, ckpt_root="artifacts/lpc_ckpt",
                            state_path="artifacts/lpc_state.json")
    shape = ShapeConfig("session", "train", seq_len=64, global_batch=8,
                        microbatch=2)
    opt = OptConfig(lr=1e-3, warmup_steps=3, total_steps=40)

    print("== (1) registration ==")
    a1 = ctl.register("alice", "train a small dense LM on my corpus", 8,
                      arch="deepseek_7b", duration_s=3600)
    a2 = ctl.register("bob", "hybrid ssm experiments", 4,
                      arch="zamba2_2p7b", duration_s=3600)
    print(f"  applications: {a1} (alice, 8 chips), {a2} (bob, 4 chips)")

    print("== (2) admin review & block assignment ==")
    g1 = ctl.review(a1)
    g2 = ctl.review(a2)
    print(f"  alice -> {g1.block_id} chips={g1.coords[:3]}... mesh={g1.mesh_shape}")
    print(f"  bob   -> {g2.block_id} chips={g2.coords[:3]}... mesh={g2.mesh_shape}")

    print("== (3) user reconfirmation (capability tokens) ==")
    ctl.confirm(a1, g1.token)
    ctl.confirm(a2, g2.token)

    print("== (4) activation: sub-mesh + compiled step per block ==")
    ctl.activate(a1, JobSpec(C.get_smoke("deepseek_7b"), shape, opt=opt))
    ctl.activate(a2, JobSpec(C.get_smoke("zamba2_2p7b"), shape, opt=opt))
    ctl.run(a1)
    ctl.run(a2)

    rep = ctl.interference_report()
    print(f"== isolation: shared ICI links = {dict(rep.shared_links)} "
          f"(isolated={rep.isolated}) ==")

    print("== (5+6) concurrent multi-block execution + monitoring ==")
    ctl.step_all(rounds=5)
    for bid, s in ctl.monitor.report().items():
        print(f"  {bid}: steps={s['steps']} ewma={s['ewma_step_s']:.3f}s "
              f"chip_s={s['chip_seconds']:.1f}")
    ctl.runtimes[a1].save(async_=False)
    ctl.runtimes[a2].save(async_=False)

    print("== (7) download results ==")
    res = ctl.download(a1)
    print(f"  alice: steps={res['steps']} ckpts={res['checkpoints']}")

    print("== chip failure on bob's block -> automatic migration ==")
    victim = g2.coords[0]
    failed = ctl.inject_chip_failure(victim)
    blk = ctl.registry.get(a2)
    print(f"  chip {victim} failed; block migrated to "
          f"{blk.grant.coords[:3]}... state={blk.state.value}")
    ctl.step_all(rounds=2)

    print("== expiry: nodes shut down, chips reclaimed ==")
    ctl.expire(a1)
    ctl.expire(a2)
    print(f"  free chips: {len(ctl.partitioner.free_chips())} / {topo.n_chips}")
    print("SESSION COMPLETE — workflow state in artifacts/lpc_state.json")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
