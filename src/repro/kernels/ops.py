"""Public kernel entry points used by the model stack.

Each op has up to three implementations:
  * ``jnp``    — chunked, O(S*chunk)-memory pure-jnp path.  This is what the
                 models lower through in the CPU dry-run and what real TPU runs
                 fall back to when Pallas is disabled.
  * ``pallas`` — the TPU kernel (``flash_attention.py`` / ``rmsnorm.py`` /
                 ``ssd_scan.py``), validated on CPU via interpret mode.
  * ``ref``    — naive oracle in ``ref.py`` (tests only).

``impl='auto'`` picks pallas on TPU backends and jnp elsewhere.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1.0e30


def _use_pallas(impl: str) -> bool:
    if impl == "pallas":
        return True
    if impl == "jnp":
        return False
    return jax.default_backend() == "tpu"


# ===========================================================================
# Flash attention (training / prefill)
# ===========================================================================

def flash_attention(q, k, v, *, causal: bool = True, sliding_window: int = 0,
                    scale: Optional[float] = None, q_offset: int = 0,
                    q_chunk: int = 1024, kv_chunk: int = 1024,
                    impl: str = "auto"):
    """Memory-efficient attention.  Shapes as in ``ref.attention``.

    q: (B, Hq, Sq, D); k: (B, Hkv, Sk, D); v: (B, Hkv, Sk, Dv).
    """
    if _use_pallas(impl):
        from repro.kernels import flash_attention as _fa
        return _fa.flash_attention_pallas(
            q, k, v, causal=causal, sliding_window=sliding_window, scale=scale,
            q_offset=q_offset, interpret=(jax.default_backend() != "tpu"))
    del q_chunk  # full-q tiles per kv chunk in the jnp path
    return _flash_jnp(q, k, v, causal, sliding_window, scale, q_offset,
                      kv_chunk)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash_jnp(q, k, v, causal, sliding_window, scale, q_offset, kv_chunk):
    """Chunked flash attention with a flash *backward* (custom VJP): neither
    direction materializes the (Sq, Sk) score matrix.  The CPU stand-in for
    the Pallas kernels; the ``vmem_fused_flash`` scopes tell the roofline
    analyzer the score tiles are VMEM-resident on TPU."""
    o, _ = _flash_fwd_impl(q, k, v, causal, sliding_window, scale, q_offset,
                           kv_chunk)
    return o


def _mask_for(q_pos, k_pos, Sk, causal, window):
    mask = k_pos[None, :] < Sk                         # strip kv padding
    if causal:
        mask = mask & (q_pos[:, None] >= k_pos[None, :])
    if window > 0:
        mask = mask & ((q_pos[:, None] - k_pos[None, :]) < window)
    return mask


def _flash_fwd_impl(q, k, v, causal, window, scale, q_offset, kv_chunk):
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, Dv = v.shape
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(D)
    kv_chunk = min(kv_chunk, Sk)
    Sk_p = -(-Sk // kv_chunk) * kv_chunk
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, Sk_p - Sk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, Sk_p - Sk), (0, 0)))
    nk = Sk_p // kv_chunk
    k_blocks = jnp.moveaxis(kp.reshape(B, Hkv, nk, kv_chunk, D), 2, 0)
    v_blocks = jnp.moveaxis(vp.reshape(B, Hkv, nk, kv_chunk, Dv), 2, 0)
    qg = q.reshape(B, Hkv, G, Sq, D)
    q32 = qg.astype(jnp.float32)
    q_pos = q_offset + jnp.arange(Sq)

    with jax.named_scope("vmem_fused_flash"):
        def kv_step(carry, inp):
            m, l, acc = carry
            ki, k_blk, v_blk = inp
            k_pos = ki * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum("bhgqd,bhkd->bhgqk", q32,
                           k_blk.astype(jnp.float32)) * scale
            mask = _mask_for(q_pos, k_pos, Sk, causal, window)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None]) * mask[None, None, None]
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p, v_blk.astype(jnp.float32))
            return (m_new, l, acc), None

        m0 = jnp.full((B, Hkv, G, Sq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, Sq), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, Sq, Dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nk), k_blocks, v_blocks))
        l_safe = jnp.where(l == 0.0, 1.0, l)
        o = (acc / l_safe[..., None]).reshape(B, Hq, Sq, Dv).astype(q.dtype)
        lse = m + jnp.log(l_safe)                     # (B, Hkv, G, Sq)
    return o, lse


def _flash_fwd_rule(q, k, v, causal, window, scale, q_offset, kv_chunk):
    o, lse = _flash_fwd_impl(q, k, v, causal, window, scale, q_offset,
                             kv_chunk)
    return o, (q, k, v, o, lse)


def _flash_bwd_rule(causal, window, scale, q_offset, kv_chunk, res, do):
    """Flash backward: per kv chunk, recompute the normalized p tile from
    (q, k, lse) and accumulate dq/dk/dv — no stacked score residuals."""
    q, k, v, o, lse = res
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, Dv = v.shape
    G = Hq // Hkv
    scale_v = scale if scale is not None else 1.0 / np.sqrt(D)
    kv_c = min(kv_chunk, Sk)
    Sk_p = -(-Sk // kv_c) * kv_c
    nk = Sk_p // kv_c
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, Sk_p - Sk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, Sk_p - Sk), (0, 0)))
    k_blocks = jnp.moveaxis(kp.reshape(B, Hkv, nk, kv_c, D), 2, 0)
    v_blocks = jnp.moveaxis(vp.reshape(B, Hkv, nk, kv_c, Dv), 2, 0)

    qg = q.reshape(B, Hkv, G, Sq, D).astype(jnp.float32)
    og = o.reshape(B, Hkv, G, Sq, Dv).astype(jnp.float32)
    dog = do.reshape(B, Hkv, G, Sq, Dv).astype(jnp.float32)
    delta = jnp.sum(og * dog, axis=-1)                    # (B,Hkv,G,Sq)
    q_pos = q_offset + jnp.arange(Sq)

    with jax.named_scope("vmem_fused_flash_bwd"):
        def kv_step(dq_acc, inp):
            ki, k_blk, v_blk = inp
            k_pos = ki * kv_c + jnp.arange(kv_c)
            kf = k_blk.astype(jnp.float32)
            vf = v_blk.astype(jnp.float32)
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kf) * scale_v
            mask = _mask_for(q_pos, k_pos, Sk, causal, window)
            p = jnp.exp(s - lse[..., None]) * mask[None, None, None]
            dv_j = jnp.einsum("bhgqk,bhgqd->bhkd", p, dog)
            dp = jnp.einsum("bhgqd,bhkd->bhgqk", dog, vf)
            ds = p * (dp - delta[..., None]) * scale_v
            dq_acc = dq_acc + jnp.einsum("bhgqk,bhkd->bhgqd", ds, kf)
            dk_j = jnp.einsum("bhgqk,bhgqd->bhkd", ds, qg)
            return dq_acc, (dk_j, dv_j)

        dq0 = jnp.zeros((B, Hkv, G, Sq, D), jnp.float32)
        dq, (dk_blocks, dv_blocks) = jax.lax.scan(
            kv_step, dq0, (jnp.arange(nk), k_blocks, v_blocks))
        dk = jnp.moveaxis(dk_blocks, 0, 2).reshape(B, Hkv, Sk_p, D)[:, :, :Sk]
        dv = jnp.moveaxis(dv_blocks, 0, 2).reshape(B, Hkv, Sk_p, Dv)[:, :, :Sk]
    return (dq.reshape(B, Hq, Sq, D).astype(q.dtype), dk.astype(k.dtype),
            dv.astype(v.dtype))


_flash_jnp.defvjp(_flash_fwd_rule, _flash_bwd_rule)


# ===========================================================================
# Decode attention (single new token vs. a cache)
# ===========================================================================

def decode_attention(q, k_cache, v_cache, cache_len, *, scale=None,
                     sliding_window: int = 0):
    """q: (B, Hq, 1, D); caches: (B, Hkv, Smax, D|Dv); cache_len: () int32.

    Attends over the first ``cache_len`` cache entries (the new token's K/V is
    assumed already written at position cache_len-1).
    """
    B, Hq, _, D = q.shape
    _, Hkv, Smax, Dv = v_cache.shape
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(D)
    qf = q.astype(jnp.float32).reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bhkd->bhgk", qf, k_cache.astype(jnp.float32)) * scale
    pos = jnp.arange(Smax)
    mask = pos < cache_len
    if sliding_window > 0:
        mask &= pos >= (cache_len - sliding_window)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bhkd->bhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, Hq, 1, Dv).astype(q.dtype)


def paged_attention(q, k_pages, v_pages, page_table, seq_lens, *,
                    scale=None, impl: str = "auto"):
    """Decode attention against a paged KV pool (continuous batching).

    q: (B, Hq, 1, D); pools: (n_pages, page, Hkv, D|Dv);
    page_table: (B, maxp) int32; seq_lens: (B,) int32 — valid entries per
    slot (the new token's K/V already written at position seq_lens-1).
    Returns (B, Hq, 1, Dv).

    Sequence position ``p`` of slot ``b`` lives at row ``p % page`` of page
    ``page_table[b, p // page]``, so the gathered view reproduces the dense
    cache layout and the masked softmax below is ``decode_attention`` with a
    per-slot length vector instead of one scalar ``cache_len``.
    """
    B, Hq, _, D = q.shape
    _, page, Hkv, Dv = v_pages.shape
    scale = scale if scale is not None else 1.0 / np.sqrt(D)
    if _use_pallas(impl):
        from repro.kernels import paged_attention as _pa
        o = _pa.paged_attention_pallas(
            q[:, :, 0], k_pages, v_pages, page_table, seq_lens, scale=scale,
            interpret=(jax.default_backend() != "tpu"))
        return o[:, :, None]
    G = Hq // Hkv
    S = page_table.shape[1] * page
    k = k_pages[page_table].reshape(B, S, Hkv, D).swapaxes(1, 2)
    v = v_pages[page_table].reshape(B, S, Hkv, Dv).swapaxes(1, 2)
    qf = q.astype(jnp.float32).reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bhkd->bhgk", qf, k.astype(jnp.float32)) * scale
    pos = jnp.arange(S)
    mask = pos[None, :] < seq_lens[:, None]
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bhkd->bhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Hq, 1, Dv).astype(q.dtype)


# ===========================================================================
# RMSNorm
# ===========================================================================

def rmsnorm(x, scale, *, eps: float = 1e-6, impl: str = "auto"):
    if _use_pallas(impl):
        from repro.kernels import rmsnorm as _rn
        return _rn.rmsnorm_pallas(x, scale, eps=eps,
                                  interpret=(jax.default_backend() != "tpu"))
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(x.dtype)


# ===========================================================================
# Fused AdamW optimizer update (single HBM pass per leaf)
# ===========================================================================

def fused_adamw(p, g, m, v, *, lr, scale, bc1, bc2, b1, b2, eps,
                weight_decay, apply_wd: Optional[bool] = None,
                impl: str = "auto"):
    """One leaf's AdamW update; m/v are fp32 arrays or ``quantized_state``
    {"q", "s"} dicts and return in the same format.

    The pallas path (``fused_adamw.py``) does the whole update — dequantize,
    moment update, bias-corrected delta, decoupled weight decay, param cast,
    requantize — in one read/write per array instead of the ~6 HBM passes
    the composed ``quantized_state`` + ``_adam_leaf`` ops lower to.  The jnp
    path replays the exact reference op sequence (bit-identical to
    ``optimizer._adam_leaf``).  ``apply_wd`` defaults to ``p.ndim >= 2``
    (decay matrices only), matching the reference.
    """
    if apply_wd is None:
        apply_wd = p.ndim >= 2
    if _use_pallas(impl):
        from repro.kernels import fused_adamw as _fo
        return _fo.fused_adamw_update(
            p, g, m, v, lr=lr, scale=scale, bc1=bc1, bc2=bc2, b1=b1, b2=b2,
            eps=eps, weight_decay=weight_decay, apply_wd=apply_wd,
            interpret=(jax.default_backend() != "tpu"))
    return _fused_adamw_jnp(p, g, m, v, lr=lr, scale=scale, bc1=bc1, bc2=bc2,
                            b1=b1, b2=b2, eps=eps, weight_decay=weight_decay,
                            apply_wd=apply_wd)


def _fused_adamw_jnp(p, g, m, v, *, lr, scale, bc1, bc2, b1, b2, eps,
                     weight_decay, apply_wd):
    from repro.train import quantized_state as qs
    quantized = isinstance(m, dict)
    g = g.astype(jnp.float32) * scale
    m_f = qs.dequantize(m) if quantized else m
    v_f = qs.dequantize(v) if quantized else v
    m_f = b1 * m_f + (1 - b1) * g
    v_f = b2 * v_f + (1 - b2) * g * g
    delta = (m_f / bc1) / (jnp.sqrt(v_f / bc2) + eps)
    if apply_wd:
        delta = delta + weight_decay * p.astype(jnp.float32)
    new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
    if quantized:
        return new_p, qs.quantize(m_f), qs.quantize(v_f)
    return new_p, m_f, v_f


# ===========================================================================
# Mamba2 SSD chunked scan
# ===========================================================================

def ssd_scan(x, dt, A, B, C, D, *, chunk: int = 256, h0=None,
             impl: str = "auto"):
    """Chunked state-space-dual scan.  Shapes as in ``ref.ssd_scan``.

    Returns (y, h_final).  O(S*chunk) memory, O(S*chunk + S*N*P) flops.
    """
    if _use_pallas(impl):
        from repro.kernels import ssd_scan as _ssd
        return _ssd.ssd_scan_pallas(x, dt, A, B, C, D, chunk=chunk, h0=h0,
                                    interpret=(jax.default_backend() != "tpu"))
    return _ssd_jnp(x, dt, A, B, C, D, chunk=chunk, h0=h0)


def _ssd_jnp(x, dt, A, B, C, D, *, chunk, h0):
    """``vmem_fused_ssd``: stand-in for the Pallas SSD kernel — the (Q x Q)
    intra-chunk decay matrices and the recurrent state stay in VMEM on TPU;
    the analyzer charges boundary traffic only."""
    with jax.named_scope("vmem_fused_ssd"):
        return _ssd_jnp_body(x, dt, A, B, C, D, chunk=chunk, h0=h0)


def _ssd_jnp_body(x, dt, A, B, C, D, *, chunk, h0):
    Bt, S, H, P = x.shape
    N = B.shape[-1]
    Q = min(chunk, S)
    Sp = -(-S // Q) * Q
    pad = Sp - S

    xf = jnp.pad(x.astype(jnp.float32), ((0, 0), (0, pad), (0, 0), (0, 0)))
    dtf = jnp.pad(dt.astype(jnp.float32), ((0, 0), (0, pad), (0, 0)))
    Bf = jnp.pad(B.astype(jnp.float32), ((0, 0), (0, pad), (0, 0)))
    Cf = jnp.pad(C.astype(jnp.float32), ((0, 0), (0, pad), (0, 0)))
    nc = Sp // Q

    # (nc, Bt, Q, ...)
    xc = jnp.moveaxis(xf.reshape(Bt, nc, Q, H, P), 1, 0)
    dtc = jnp.moveaxis(dtf.reshape(Bt, nc, Q, H), 1, 0)
    Bc = jnp.moveaxis(Bf.reshape(Bt, nc, Q, N), 1, 0)
    Cc = jnp.moveaxis(Cf.reshape(Bt, nc, Q, N), 1, 0)
    Af = A.astype(jnp.float32)

    h_init = (jnp.zeros((Bt, H, P, N), jnp.float32) if h0 is None
              else h0.astype(jnp.float32))

    def chunk_step(h, inp):
        x_c, dt_c, B_c, C_c = inp           # (Bt,Q,H,P) (Bt,Q,H) (Bt,Q,N) (Bt,Q,N)
        dA = dt_c * Af[None, None]          # (Bt,Q,H)
        a = jnp.cumsum(dA, axis=1)          # within-chunk cumulative log decay
        # inter-chunk: y_inter[t] = C_t . (exp(a_t) * h)
        y_inter = jnp.einsum("bqn,bqh,bhpn->bqhp", C_c, jnp.exp(a), h)
        # intra-chunk: L[t,j] = exp(a_t - a_j) for t >= j
        seg = a[:, :, None, :] - a[:, None, :, :]          # (Bt,Q,Q,H)
        tri = jnp.tril(jnp.ones((Q, Q), bool))
        L = jnp.where(tri[None, :, :, None], jnp.exp(seg), 0.0)
        cb = jnp.einsum("bqn,bjn->bqj", C_c, B_c)          # (Bt,Q,Q)
        y_intra = jnp.einsum("bqj,bqjh,bjh,bjhp->bqhp", cb, L, dt_c, x_c)
        # carry: h' = exp(a_Q) h + sum_j exp(a_Q - a_j) dt_j B_j x_j^T
        decay_end = jnp.exp(a[:, -1])                       # (Bt,H)
        w = jnp.exp(a[:, -1:, :] - a) * dt_c               # (Bt,Q,H)
        h_new = (h * decay_end[..., None, None]
                 + jnp.einsum("bqh,bqn,bqhp->bhpn", w, B_c, x_c))
        return h_new, y_inter + y_intra

    h_fin, yc = jax.lax.scan(chunk_step, h_init, (xc, dtc, Bc, Cc))
    y = jnp.moveaxis(yc, 0, 1).reshape(Bt, Sp, H, P)[:, :S]
    y = y + x.astype(jnp.float32) * D[None, None, :, None]
    return y.astype(x.dtype), h_fin


def ssd_decode_step(x, dt, A, B, C, D, h):
    """Single-token Mamba2 update.  x:(Bt,H,P) dt:(Bt,H) B,C:(Bt,N) h:(Bt,H,P,N)."""
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    decay = jnp.exp(dtf * A[None])
    h = h * decay[..., None, None] + jnp.einsum(
        "bh,bn,bhp->bhpn", dtf, B.astype(jnp.float32), xf)
    y = jnp.einsum("bn,bhpn->bhp", C.astype(jnp.float32), h) + xf * D[None, :, None]
    return y.astype(x.dtype), h


# ===========================================================================
# mLSTM chunked scan (xLSTM matrix memory)
# ===========================================================================

def mlstm_scan(q, k, v, i_gate, f_gate, *, chunk: int = 256, carry=None,
               impl: str = "auto"):
    """Chunkwise-parallel stabilized mLSTM.  Shapes as in ``ref.mlstm_scan``.

    Returns (h, (C, n, m)).  Matches the sequential reference exactly
    (same running-max stabilizer).  The ``vmem_fused_mlstm`` scope marks the
    chunk scan as VMEM-resident for the roofline analyzer (the (Dk x Dv)
    matrix state fits VMEM for every assigned config).
    """
    del impl  # single jnp implementation; pallas variant covers ssd_scan
    with jax.named_scope("vmem_fused_mlstm"):
        return _mlstm_scan_body(q, k, v, i_gate, f_gate, chunk=chunk,
                                carry=carry)


def _mlstm_scan_body(q, k, v, i_gate, f_gate, *, chunk, carry):
    B, H, S, Dk = q.shape
    Dv = v.shape[-1]
    scale = 1.0 / np.sqrt(Dk)
    Q = min(chunk, S)
    Sp = -(-S // Q) * Q
    pad = Sp - S

    def pad_s(t):
        return jnp.pad(t.astype(jnp.float32),
                       ((0, 0), (0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 3))

    qf, kf, vf = pad_s(q), pad_s(k), pad_s(v)
    # padded forget gates -> log f = 0 would corrupt the running max; use
    # i=-inf (no write) and f=+inf (log f ~ 0 fine since no writes occur).
    igf = jnp.pad(i_gate.astype(jnp.float32), ((0, 0), (0, 0), (0, pad)),
                  constant_values=NEG_INF)
    fgf = jnp.pad(f_gate.astype(jnp.float32), ((0, 0), (0, 0), (0, pad)),
                  constant_values=80.0)
    nc = Sp // Q

    def to_chunks(t):
        return jnp.moveaxis(t.reshape(B, H, nc, Q, *t.shape[3:]), 2, 0)

    qc, kc, vc = to_chunks(qf), to_chunks(kf), to_chunks(vf)
    ic, fc = to_chunks(igf), to_chunks(fgf)

    if carry is None:
        C0 = jnp.zeros((B, H, Dk, Dv), jnp.float32)
        n0 = jnp.zeros((B, H, Dk), jnp.float32)
        m0 = jnp.full((B, H), -jnp.inf, jnp.float32)
    else:
        C0, n0, m0 = (c.astype(jnp.float32) for c in carry)

    def chunk_step(state, inp):
        C, n, m = state
        q_c, k_c, v_c, i_c, f_c = inp       # (B,H,Q,*)
        logf = jax.nn.log_sigmoid(f_c)      # (B,H,Q)
        G = jnp.cumsum(logf, axis=-1)       # local cumulative log forget
        # D_local[t,j] = G_t - G_j + i_j  for j <= t
        d_loc = G[..., :, None] - G[..., None, :] + i_c[..., None, :]
        tri = jnp.tril(jnp.ones((Q, Q), bool))
        d_loc = jnp.where(tri, d_loc, -jnp.inf)
        # running max m_t = max(m_prev + G_t, max_j<=t d_loc[t,j]) — row t of
        # d_loc already contains every j <= t (with its decay), so the row
        # max IS the full local running max; a cummax over rows would mix in
        # stale (undecayed) values and break the carry's exp(-m) scaling.
        m_t = jnp.maximum(m[..., None] + G, jnp.max(d_loc, axis=-1))
        # intra-chunk scores
        s = jnp.einsum("bhqd,bhjd->bhqj", q_c, k_c) * scale
        w = jnp.where(tri, jnp.exp(d_loc - m_t[..., None]), 0.0)
        num_i = jnp.einsum("bhqj,bhqj,bhjv->bhqv", s, w, v_c)
        den_i = jnp.einsum("bhqj,bhqj->bhq", s, w)
        # inter-chunk: decay from carry
        inter_w = jnp.exp(m[..., None] + G - m_t)            # (B,H,Q)
        num_x = jnp.einsum("bhkv,bhqk->bhqv", C, q_c) * scale * inter_w[..., None]
        den_x = jnp.einsum("bhk,bhqk->bhq", n, q_c) * scale * inter_w
        den = jnp.maximum(jnp.abs(den_i + den_x), jnp.exp(-m_t))
        h = (num_i + num_x) / den[..., None]
        # carry update at chunk end with m_end
        m_end = m_t[..., -1]
        cw = jnp.exp(G[..., -1:] - G + i_c - m_end[..., None])   # (B,H,Q)
        C_new = (C * jnp.exp(m + G[..., -1] - m_end)[..., None, None]
                 + jnp.einsum("bhq,bhqk,bhqv->bhkv", cw, k_c, v_c))
        n_new = (n * jnp.exp(m + G[..., -1] - m_end)[..., None]
                 + jnp.einsum("bhq,bhqk->bhk", cw, k_c))
        return (C_new, n_new, m_end), h

    (Cf_, nf_, mf_), hc = jax.lax.scan(chunk_step, (C0, n0, m0),
                                       (qc, kc, vc, ic, fc))
    h = jnp.moveaxis(hc, 0, 2).reshape(B, H, Sp, Dv)[:, :, :S]
    return h.astype(q.dtype), (Cf_, nf_, mf_)


def mlstm_decode_step(q, k, v, i_gate, f_gate, carry):
    """Single-token mLSTM update.  q,k:(B,H,Dk) v:(B,H,Dv) gates:(B,H)."""
    C, n, m = carry
    scale = 1.0 / np.sqrt(q.shape[-1])
    logf = jax.nn.log_sigmoid(f_gate.astype(jnp.float32))
    m_new = jnp.maximum(logf + m, i_gate.astype(jnp.float32))
    fg = jnp.exp(logf + m - m_new)
    ig = jnp.exp(i_gate.astype(jnp.float32) - m_new)
    kf, vf, qf = (t.astype(jnp.float32) for t in (k, v, q))
    C = C * fg[..., None, None] + ig[..., None, None] * (kf[..., :, None] * vf[..., None, :])
    n = n * fg[..., None] + ig[..., None] * kf
    num = jnp.einsum("bhkv,bhk->bhv", C, qf) * scale
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qf)) * scale,
                      jnp.exp(-m_new))
    return (num / den[..., None]).astype(q.dtype), (C, n, m_new)
