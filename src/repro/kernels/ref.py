"""Pure-jnp reference oracles for every kernel.

These are the semantic ground truth: naive, O(S^2)-memory, numerically
straightforward.  Tests assert the Pallas kernels (interpret mode) and the
chunked jnp production paths in ``ops.py`` against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def attention(q, k, v, *, causal: bool = True, sliding_window: int = 0,
              scale: float | None = None, q_offset: int = 0):
    """Naive multi-head attention with GQA.

    q: (B, Hq, Sq, D);  k: (B, Hkv, Sk, D);  v: (B, Hkv, Sk, Dv)
    ``q_offset``: absolute position of q[0] (for decode: q_offset = cache_len).
    Returns (B, Hq, Sq, Dv).
    """
    B, Hq, Sq, D = q.shape
    Hkv = k.shape[1]
    Sk = k.shape[2]
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(D)
    qf = q.astype(jnp.float32).reshape(B, Hkv, G, Sq, D)
    kf = k.astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kf) * scale
    q_pos = q_offset + jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), dtype=bool)
    if causal:
        mask &= q_pos >= k_pos
    if sliding_window > 0:
        mask &= (q_pos - k_pos) < sliding_window
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(B, Hq, Sq, -1).astype(q.dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rmsnorm(x, scale, *, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Mamba2 SSD scan
# ---------------------------------------------------------------------------

def ssd_scan(x, dt, A, B, C, D, *, h0=None):
    """Sequential (ground-truth) Mamba2 recurrence.

    x:  (Bt, S, H, P)   inputs per head
    dt: (Bt, S, H)      softplus'd timestep (>0)
    A:  (H,)            negative decay rate
    B:  (Bt, S, N)      input projection (n_groups=1, shared across heads)
    C:  (Bt, S, N)      output projection
    D:  (H,)            skip
    h0: (Bt, H, P, N) or None
    Returns y (Bt, S, H, P), h_final (Bt, H, P, N).
    """
    Bt, S, H, P = x.shape
    N = B.shape[-1]
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    Bf, Cf = B.astype(jnp.float32), C.astype(jnp.float32)
    h = jnp.zeros((Bt, H, P, N), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, inp):
        x_t, dt_t, B_t, C_t = inp          # (Bt,H,P), (Bt,H), (Bt,N), (Bt,N)
        decay = jnp.exp(dt_t * A[None])    # (Bt,H)
        dBx = jnp.einsum("bh,bn,bhp->bhpn", dt_t, B_t, x_t)
        h = h * decay[..., None, None] + dBx
        y = jnp.einsum("bn,bhpn->bhp", C_t, h)
        return h, y

    h, ys = jax.lax.scan(
        step, h,
        (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
         jnp.moveaxis(Bf, 1, 0), jnp.moveaxis(Cf, 1, 0)))
    y = jnp.moveaxis(ys, 0, 1) + xf * D[None, None, :, None]
    return y.astype(x.dtype), h


# ---------------------------------------------------------------------------
# mLSTM (xLSTM matrix memory) scan
# ---------------------------------------------------------------------------

def mlstm_scan(q, k, v, i_gate, f_gate, *, c0=None, n0=None, m0=None):
    """Sequential (ground-truth) mLSTM recurrence with log-domain stabilization.

    q,k: (B, H, S, Dk); v: (B, H, S, Dv); i_gate,f_gate: (B, H, S) pre-activations.
    C_t = f C_{t-1} + i v k^T;  n_t = f n + i k;  h = (C q) / max(|n.q|, 1)
    Stabilized with m_t = max(log f + m_{t-1}, log i).
    Returns h (B,H,S,Dv) and final (C, n, m).
    """
    B, H, S, Dk = q.shape
    Dv = v.shape[-1]
    scale = 1.0 / np.sqrt(Dk)
    C = jnp.zeros((B, H, Dk, Dv), jnp.float32) if c0 is None else c0.astype(jnp.float32)
    n = jnp.zeros((B, H, Dk), jnp.float32) if n0 is None else n0.astype(jnp.float32)
    m = jnp.full((B, H), -jnp.inf, jnp.float32) if m0 is None else m0.astype(jnp.float32)

    def step(carry, inp):
        C, n, m = carry
        q_t, k_t, v_t, i_t, f_t = inp
        logf = jax.nn.log_sigmoid(f_t)               # (B,H)
        m_new = jnp.maximum(logf + m, i_t)
        fg = jnp.exp(logf + m - m_new)
        ig = jnp.exp(i_t - m_new)
        C = C * fg[..., None, None] + ig[..., None, None] * (k_t[..., :, None] * v_t[..., None, :])
        n = n * fg[..., None] + ig[..., None] * k_t
        num = jnp.einsum("bhkv,bhk->bhv", C, q_t) * scale
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q_t)) * scale,
                          jnp.exp(-m_new))
        return (C, n, m_new), num / den[..., None]

    qs = jnp.moveaxis(q.astype(jnp.float32), 2, 0)
    ks = jnp.moveaxis(k.astype(jnp.float32), 2, 0)
    vs = jnp.moveaxis(v.astype(jnp.float32), 2, 0)
    igs = jnp.moveaxis(i_gate.astype(jnp.float32), 2, 0)
    fgs = jnp.moveaxis(f_gate.astype(jnp.float32), 2, 0)
    (C, n, m), hs = jax.lax.scan(step, (C, n, m), (qs, ks, vs, igs, fgs))
    return jnp.moveaxis(hs, 0, 2).astype(q.dtype), (C, n, m)
