"""Pallas TPU fused RMSNorm.

One VMEM pass per row block: fp32 mean-of-squares, rsqrt, scale — no
intermediate HBM round-trip between the variance reduction and the scaling
(XLA emits two kernels for the naive jnp formulation on some backends).
Rows are tiled in blocks of 256; the feature dim stays whole in VMEM
(d_model <= ~8k fits comfortably: 8k fp32 = 32 KB/row).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float, n_rows: int,
                    block_rows: int):
    i = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)                 # (block_rows, d)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * s_ref[...].astype(jnp.float32)
    # mask padded rows (beyond n_rows) — harmless garbage, sliced off outside
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm_pallas(x, scale, *, eps: float = 1e-6, block_rows: int = 256,
                   interpret: bool = False):
    """x: (..., d); scale: (d,)."""
    orig_shape = x.shape
    d = x.shape[-1]
    rows = int(np.prod(orig_shape[:-1])) if len(orig_shape) > 1 else 1
    xf = x.reshape(rows, d)
    block_rows = min(block_rows, max(rows, 1))
    rows_pad = -(-rows // block_rows) * block_rows
    xf = jnp.pad(xf, ((0, rows_pad - rows), (0, 0)))
    grid = (rows_pad // block_rows,)
    kernel = functools.partial(_rmsnorm_kernel, eps=eps, n_rows=rows,
                               block_rows=block_rows)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows_pad, d), x.dtype),
        interpret=interpret,
    )(xf, scale)
    return out[:rows].reshape(orig_shape)
