"""Pallas TPU fused AdamW update — one HBM pass per optimizer leaf.

The reference path (``optimizer._adam_leaf`` + ``quantized_state``) lowers
to ~6 passes over the leaf on the ``state_bits=8`` path: dequantize m,
dequantize v, the Adam moment/delta arithmetic, the parameter update, and
one requantize (absmax reduce + scale + round) per moment.  Each pass
round-trips the leaf through HBM.  This kernel fuses the whole per-leaf
update — int8 dequantize of m/v -> Adam moment update -> bias-corrected
delta -> decoupled weight decay -> param cast back to its storage dtype ->
int8 requantize with fresh per-block absmax scales — into a single read of
(p, g, m, v) and a single write of (p', m', v'), the memory-bandwidth floor
for the update.

Layout trick: ``quantized_state`` scales are per 256-element block along
the last dim, so every leaf is viewed as *rows of quant blocks*: the
(R, L_pad) row-major leaf is reshaped (free, same bytes) to
(R * L_pad/256, 256) and the scale tree to (R * nblocks, 1).  The kernel is
then purely 2-D elementwise with a per-row absmax — no reshapes inside the
kernel, no lane-dim gymnastics on TPU.

Bit-for-bitness: the kernel replays the exact fp32 op sequence of
``optimizer._adam_leaf`` (same casts, same constants, same
``quantize``/``dequantize`` arithmetic, elementwise so reduction order
never enters except the exact ``max``).  Tests assert ``array_equal``
against ``_adam_leaf`` evaluated inside an *identical* interpret-mode grid
harness — XLA:CPU contracts mul+add into FMA differently per compilation
context, so eager-vs-compiled comparisons are not bitwise stable, but the
same expression in the same harness is (see tests/test_kernels.py).
Zero-padding keeps the equivalence: padded g/p/q codes are 0, so padded
moments stay exactly 0.0 and contribute nothing to any block's absmax —
identical to the reference, which pads with zeros inside ``quantize``.
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.train import quantized_state as qs

QBLOCK = qs.BLOCK       # 256 — quantization block = one kernel row


def _adam_math(sc_ref, p, g, m_f, v_f, *, b1: float, b2: float, eps: float,
               weight_decay: float, apply_wd: bool):
    """The exact ``optimizer._adam_leaf`` fp32 arithmetic (shared by both
    state formats).  ``sc_ref`` holds (lr, clip_scale, bc1, bc2) in SMEM."""
    lr, scale, bc1, bc2 = sc_ref[0], sc_ref[1], sc_ref[2], sc_ref[3]
    g = g.astype(jnp.float32) * scale
    m_f = b1 * m_f + (1 - b1) * g
    v_f = b2 * v_f + (1 - b2) * g * g
    delta = (m_f / bc1) / (jnp.sqrt(v_f / bc2) + eps)
    if apply_wd:    # decoupled weight decay on matrices only
        delta = delta + weight_decay * p.astype(jnp.float32)
    new_p = (p.astype(jnp.float32) - lr * delta)
    return new_p, m_f, v_f


def _requant(x):
    """Per-row (= per 256-block) absmax int8 quantize — the same ops as
    ``quantized_state.quantize`` on the rows-of-blocks view."""
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _kernel_f32(sc_ref, p_ref, g_ref, m_ref, v_ref,
                np_ref, nm_ref, nv_ref, *, b1, b2, eps, weight_decay,
                apply_wd):
    new_p, m_f, v_f = _adam_math(
        sc_ref, p_ref[...], g_ref[...], m_ref[...], v_ref[...],
        b1=b1, b2=b2, eps=eps, weight_decay=weight_decay, apply_wd=apply_wd)
    np_ref[...] = new_p.astype(np_ref.dtype)
    nm_ref[...] = m_f
    nv_ref[...] = v_f


def _kernel_i8(sc_ref, p_ref, g_ref, mq_ref, ms_ref, vq_ref, vs_ref,
               np_ref, nmq_ref, nms_ref, nvq_ref, nvs_ref, *, b1, b2, eps,
               weight_decay, apply_wd):
    # dequantize: same ops as quantized_state.dequantize (codes -> f32 * s)
    m_f = mq_ref[...].astype(jnp.float32) * ms_ref[...]
    v_f = vq_ref[...].astype(jnp.float32) * vs_ref[...]
    new_p, m_f, v_f = _adam_math(
        sc_ref, p_ref[...], g_ref[...], m_f, v_f,
        b1=b1, b2=b2, eps=eps, weight_decay=weight_decay, apply_wd=apply_wd)
    np_ref[...] = new_p.astype(np_ref.dtype)
    nmq_ref[...], nms_ref[...] = _requant(m_f)
    nvq_ref[...], nvs_ref[...] = _requant(v_f)


def _rows_of_blocks(x, R: int, L: int, Lp: int):
    """(orig shape) -> zero-padded (R * Lp/QBLOCK, QBLOCK) rows-of-blocks
    view.  Row-major (R, Lp) and (R*nb, QBLOCK) share a memory layout, so
    the second reshape is free."""
    x2 = x.reshape(R, L)
    if Lp != L:
        x2 = jnp.pad(x2, ((0, 0), (0, Lp - L)))
    return x2.reshape(R * (Lp // QBLOCK), QBLOCK)


QuantState = Dict[str, jax.Array]
MomentIn = Union[jax.Array, QuantState]


def fused_adamw_update(p, g, m: MomentIn, v: MomentIn, *, lr, scale, bc1,
                       bc2, b1: float, b2: float, eps: float,
                       weight_decay: float, apply_wd: bool,
                       block_rows: int = 256, interpret: bool = False
                       ) -> Tuple[jax.Array, MomentIn, MomentIn]:
    """Fused per-leaf AdamW.  ``m``/``v`` are fp32 arrays shaped like ``p``
    or ``{"q": int8, "s": f32}`` quantized states (``quantized_state``
    layout); the return matches the input format.  ``apply_wd`` is the
    *original* leaf's ``ndim >= 2`` — pass it explicitly because the
    ``scan_stacked`` layer-slice loop hands this function slices whose rank
    is one lower than the stored leaf."""
    quantized = isinstance(m, dict)
    shape = p.shape
    L = shape[-1] if p.ndim else 1
    R = int(np.prod(shape[:-1])) if p.ndim > 1 else 1
    Lp = -(-L // QBLOCK) * QBLOCK
    nb = Lp // QBLOCK
    RB = R * nb
    block_rows = min(block_rows, max(RB, 1))
    RBp = -(-RB // block_rows) * block_rows
    grid = (RBp // block_rows,)

    def rows(x):
        x = _rows_of_blocks(x, R, L, Lp)
        if RBp != RB:
            x = jnp.pad(x, ((0, RBp - RB), (0, 0)))
        return x

    def srows(s):
        s2 = s.reshape(RB, 1).astype(jnp.float32)
        if RBp != RB:
            # padded rows get scale 1.0 (sliced off; avoids 0/0 noise)
            s2 = jnp.pad(s2, ((0, RBp - RB), (0, 0)), constant_values=1.0)
        return s2

    scalars = jnp.stack([jnp.asarray(lr, jnp.float32),
                         jnp.asarray(scale, jnp.float32),
                         jnp.asarray(bc1, jnp.float32),
                         jnp.asarray(bc2, jnp.float32)])
    data_spec = pl.BlockSpec((block_rows, QBLOCK), lambda i: (i, 0))
    s_spec = pl.BlockSpec((block_rows, 1), lambda i: (i, 0))
    sc_spec = pl.BlockSpec(memory_space=pltpu.SMEM)
    kw = dict(b1=b1, b2=b2, eps=eps, weight_decay=weight_decay,
              apply_wd=apply_wd)

    def unrows(x):
        return x[:RB].reshape(R, Lp)[:, :L].reshape(shape)

    if not quantized:
        kernel = functools.partial(_kernel_f32, **kw)
        out = pl.pallas_call(
            kernel, grid=grid,
            in_specs=[sc_spec, data_spec, data_spec, data_spec, data_spec],
            out_specs=[data_spec, data_spec, data_spec],
            out_shape=[jax.ShapeDtypeStruct((RBp, QBLOCK), p.dtype),
                       jax.ShapeDtypeStruct((RBp, QBLOCK), jnp.float32),
                       jax.ShapeDtypeStruct((RBp, QBLOCK), jnp.float32)],
            interpret=interpret,
        )(scalars, rows(p), rows(g), rows(m), rows(v))
        return unrows(out[0]), unrows(out[1]), unrows(out[2])

    s_shape = (*shape[:-1], nb) if p.ndim else (nb,)
    kernel = functools.partial(_kernel_i8, **kw)
    out = pl.pallas_call(
        kernel, grid=grid,
        in_specs=[sc_spec, data_spec, data_spec, data_spec, s_spec,
                  data_spec, s_spec],
        out_specs=[data_spec, data_spec, s_spec, data_spec, s_spec],
        out_shape=[jax.ShapeDtypeStruct((RBp, QBLOCK), p.dtype),
                   jax.ShapeDtypeStruct((RBp, QBLOCK), jnp.int8),
                   jax.ShapeDtypeStruct((RBp, 1), jnp.float32),
                   jax.ShapeDtypeStruct((RBp, QBLOCK), jnp.int8),
                   jax.ShapeDtypeStruct((RBp, 1), jnp.float32)],
        interpret=interpret,
    )(scalars, rows(p), rows(g), rows(m["q"]), srows(m["s"]),
      rows(v["q"]), srows(v["s"]))

    def unscale(s):
        return s[:RB, 0].reshape(s_shape)

    return (unrows(out[0]),
            {"q": unrows(out[1]), "s": unscale(out[2])},
            {"q": unrows(out[3]), "s": unscale(out[4])})
