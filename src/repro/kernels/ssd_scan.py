"""Pallas TPU Mamba2 SSD chunked scan.

Grid = (B, H, n_chunks), chunk dim minor-most: the (head_dim x state) fp32
recurrent state lives in VMEM scratch across the sequential chunk sweep, so
inter-chunk state passing never round-trips HBM (the jnp fallback carries it
through a lax.scan, which XLA materializes per step).  Intra-chunk work is
the (Q x Q) decay-weighted quadratic form on the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, y_ref, h_scr, *,
                chunk: int, seq_len: int):
    ci = pl.program_id(2)
    h_idx = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, :, 0].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)        # (Q,)
    Bm = b_ref[0].astype(jnp.float32)               # (Q, N)
    Cm = c_ref[0].astype(jnp.float32)               # (Q, N)
    A = a_ref[h_idx].astype(jnp.float32)            # scalar
    D = d_ref[h_idx].astype(jnp.float32)

    # mask padded tail positions (dt=0 -> identity decay, no state writes)
    pos = ci * chunk + jax.lax.broadcasted_iota(jnp.int32, (chunk, 1), 0)[:, 0]
    valid = pos < seq_len
    dt = jnp.where(valid, dt, 0.0)

    dA = dt * A                                     # (Q,)
    a_cum = jnp.cumsum(dA)                          # (Q,)
    h_prev = h_scr[...]                             # (P, N)

    # inter-chunk: y_inter[t] = C_t . (exp(a_t) h_prev)
    y_inter = jnp.exp(a_cum)[:, None] * jax.lax.dot_general(
        Cm, h_prev, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)         # (Q, P)

    # intra-chunk: L[t,j] = exp(a_t - a_j) for t >= j
    seg = a_cum[:, None] - a_cum[None, :]
    tri = (jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
           >= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1))
    L = jnp.where(tri, jnp.exp(seg), 0.0)
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)   # (Q, Q)
    w = cb * L * dt[None, :]
    y_intra = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    y_ref[0, :, 0] = (y_inter + y_intra + x * D).astype(y_ref.dtype)

    # carry: h' = exp(a_Q) h + sum_j exp(a_Q - a_j) dt_j B_j x_j^T
    wj = jnp.exp(a_cum[-1] - a_cum) * dt            # (Q,)
    h_new = (h_prev * jnp.exp(a_cum[-1])
             + jax.lax.dot_general(x * wj[:, None], Bm,
                                   (((0,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32))
    h_scr[...] = h_new


def ssd_scan_pallas(x, dt, A, B, C, D, *, chunk: int = 256, h0=None,
                    interpret: bool = False):
    """Shapes as in ``ref.ssd_scan``: x (Bt,S,H,P), dt (Bt,S,H), A (H,),
    B/C (Bt,S,N), D (H,).  Returns (y, h_final) — h_final recomputed via the
    jnp reference tail when needed (prefill); train only consumes y."""
    assert h0 is None, "pallas path covers the from-zeros (train) case"
    Bt, S, H, P = x.shape
    N = B.shape[-1]
    Q = min(chunk, S)
    Sp = -(-S // Q) * Q
    pad = Sp - S
    xp = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
    dtp = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    Bp = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
    Cp = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    nc = Sp // Q
    grid = (Bt, H, nc)

    kernel = functools.partial(_ssd_kernel, chunk=Q, seq_len=S)
    y = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Q, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, Q, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((H,), lambda b, h, c: (0,)),
            pl.BlockSpec((1, Q, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, Q, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((H,), lambda b, h, c: (0,)),
        ],
        out_specs=pl.BlockSpec((1, Q, 1, P), lambda b, h, c: (b, c, h, 0)),
        out_shape=jax.ShapeDtypeStruct((Bt, Sp, H, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xp, dtp, A.astype(jnp.float32), Bp, Cp, D.astype(jnp.float32))
    y = y[:, :S]
    # final state (for prefill-with-cache): cheap jnp recompute of the tail
    from repro.kernels import ops as _ops
    _, h_fin = _ops._ssd_jnp(x, dt, A, B, C, D, chunk=chunk, h0=None)
    return y, h_fin
