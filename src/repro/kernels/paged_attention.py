"""Pallas paged-attention gather kernel (single-token decode, GQA).

Continuous-batching serve blocks keep every live session's K/V in one
block-granular *page pool* instead of a per-sequence ``smax`` allocation:

    k_pages / v_pages : (n_pages, page_size, Hkv, D | Dv)   the shared pool
    page_table        : (B, pages_per_seq) int32            slot -> page ids
    seq_lens          : (B,) int32                          valid tokens/slot

Sequence position ``p`` of slot ``b`` lives at row ``p % page_size`` of page
``page_table[b, p // page_size]``, so the gathered rows ``[0, seq_lens[b])``
reproduce the dense cache layout exactly and decode attention stays the same
masked softmax the dense path uses — just fetched page by page out of the
pool rather than sliced from a contiguous per-sequence buffer.

Kernel structure: grid ``(B, pages_per_seq)`` with the page sweep minor-most.
``page_table`` and ``seq_lens`` ride scalar prefetch
(``pltpu.PrefetchScalarGridSpec``) so the K/V BlockSpec index maps chase the
page table when scheduling block DMAs — the gather happens in the pipeline,
not as a materialized (B, S, ...) copy.  Each sweep stages the slot's pages
into VMEM scratch (persistent across the minor grid dim, like
``flash_attention``'s accumulators); the final step applies the *identical*
op sequence as ``ref.attention`` (fp32 einsum -> masked softmax -> fp32
einsum), so interpret mode matches the reference bit-for-bit — tests assert
``array_equal``, not ``allclose``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30   # TPU-safe -inf stand-in (same convention as flash_attention)


def _paged_attention_kernel(pt_ref, sl_ref, q_ref, k_ref, v_ref, o_ref,
                            k_scr, v_scr, *, pages_per_seq: int,
                            scale: float):
    b = pl.program_id(0)
    j = pl.program_id(1)

    # stage this page of the slot's K/V into the persistent VMEM scratch
    k_scr[j] = k_ref[0]
    v_scr[j] = v_ref[0]

    @pl.when(j == pages_per_seq - 1)
    def _finalize():
        page, Hkv, D = k_scr.shape[1:]
        Dv = v_scr.shape[-1]
        S = pages_per_seq * page
        Hq = q_ref.shape[1]
        G = Hq // Hkv
        # mirror ref.attention's exact shapes/ops (B=1, Sq=1): fp32 scores,
        # length-masked softmax, fp32 weighted sum — bitwise identical in
        # interpret mode
        qf = q_ref[...].astype(jnp.float32).reshape(1, Hkv, G, 1, D)
        kf = k_scr[...].reshape(1, S, Hkv, D).swapaxes(1, 2).astype(jnp.float32)
        vf = v_scr[...].reshape(1, S, Hkv, Dv).swapaxes(1, 2).astype(jnp.float32)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kf) * scale
        k_pos = jax.lax.broadcasted_iota(jnp.int32, (S,), 0)
        mask = k_pos < sl_ref[b]
        s = jnp.where(mask[None, None, None, :], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgqk,bhkd->bhgqd", p, vf)
        o_ref[...] = o.reshape(1, Hq, Dv).astype(o_ref.dtype)


def paged_attention_pallas(q, k_pages, v_pages, page_table, seq_lens, *,
                           scale: float | None = None,
                           interpret: bool = False):
    """q: (B, Hq, D); pools: (P, page, Hkv, D|Dv); page_table: (B, maxp)
    int32; seq_lens: (B,) int32.  Returns (B, Hq, Dv).

    Attends each slot's single query over its ``seq_lens[b]`` gathered cache
    entries (the new token's K/V already written at position
    ``seq_lens[b] - 1``).  Pages beyond a slot's allocation may point
    anywhere valid (the reserved trash page): their rows are masked out.
    """
    B, Hq, D = q.shape
    _, page, Hkv, _ = k_pages.shape
    Dv = v_pages.shape[-1]
    maxp = page_table.shape[1]
    scale = scale if scale is not None else 1.0 / np.sqrt(D)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, maxp),
        in_specs=[
            pl.BlockSpec((1, Hq, D), lambda b, j, *_: (b, 0, 0)),
            pl.BlockSpec((1, page, Hkv, D),
                         lambda b, j, pt_ref, sl_ref: (pt_ref[b, j], 0, 0, 0)),
            pl.BlockSpec((1, page, Hkv, Dv),
                         lambda b, j, pt_ref, sl_ref: (pt_ref[b, j], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, Hq, Dv), lambda b, j, *_: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((maxp, page, Hkv, D), k_pages.dtype),
            pltpu.VMEM((maxp, page, Hkv, Dv), v_pages.dtype),
        ],
    )
    kernel = functools.partial(_paged_attention_kernel,
                               pages_per_seq=maxp, scale=scale)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hq, Dv), q.dtype),
        interpret=interpret,
    )(page_table, seq_lens, q, k_pages, v_pages)
