"""Pallas TPU flash attention (forward).

Tiled online-softmax attention with GQA-aware index maps: the kernel never
materializes the (Sq, Sk) score matrix.  Grid = (B*Hq, nq, nk) with the kv
dim minor-most, so the fp32 (block_q x Dv) accumulator lives in VMEM scratch
across the kv sweep.  Block shapes are MXU-aligned (multiples of 128 where
the head dims allow).  Causal masking skips fully-masked kv blocks via
``pl.when`` (no MXU work issued for the upper triangle).

Validated against ``ref.attention`` in interpret mode on CPU; on real TPUs
``ops.flash_attention(impl='pallas')`` routes here.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale: float, causal: bool, window: int, nk: int,
               block_q: int, block_k: int, sk: int, q_offset: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    i = pl.program_id(1)
    q_pos = q_offset + i * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = j * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)

    # block-level skip: for causal masks, kv blocks strictly above the
    # diagonal contribute nothing — issue no MXU work for them.
    first_q_pos = q_offset + i * block_q
    last_k_pos = j * block_k + block_k - 1
    live = (first_q_pos + block_q - 1 >= j * block_k) if causal else True
    if window > 0:
        live = jnp.logical_and(live, last_k_pos > first_q_pos - window - block_q)

    @pl.when(live if causal or window > 0 else True)
    def _body():
        q = q_ref[0].astype(jnp.float32)                   # (bq, D)
        k = k_ref[0].astype(jnp.float32)                   # (bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale    # (bq, bk)
        mask = k_pos < sk
        if causal:
            mask = jnp.logical_and(mask, q_pos >= k_pos)
        if window > 0:
            mask = jnp.logical_and(mask, (q_pos - k_pos) < window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]                                # (bq, 1)
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new) * mask.astype(jnp.float32)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = (acc_scr[...] * corr
                        + jax.lax.dot_general(
                            p, v_ref[0].astype(jnp.float32),
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(j == nk - 1)
    def _fin():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           sliding_window: int = 0,
                           scale: Optional[float] = None, q_offset: int = 0,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = False):
    """q: (B, Hq, Sq, D); k: (B, Hkv, Sk, D); v: (B, Hkv, Sk, Dv)."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, Dv = v.shape
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(D)
    block_q = min(block_q, max(Sq, 8))
    block_k = min(block_k, max(Sk, 8))

    sq_pad = -(-Sq // block_q) * block_q
    sk_pad = -(-Sk // block_k) * block_k
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, sq_pad - Sq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, sk_pad - Sk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, sk_pad - Sk), (0, 0)))
    qf = qp.reshape(B * Hq, sq_pad, D)
    kf = kp.reshape(B * Hkv, sk_pad, D)
    vf = vp.reshape(B * Hkv, sk_pad, Dv)

    nq = sq_pad // block_q
    nk = sk_pad // block_k
    grid = (B * Hq, nq, nk)

    def kv_head(bh):
        return (bh // Hq) * Hkv + (bh % Hq) // G

    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, window=sliding_window,
        nk=nk, block_q=block_q, block_k=block_k, sk=Sk, q_offset=q_offset)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (kv_head(b), j, 0)),
            pl.BlockSpec((1, block_k, Dv), lambda b, i, j: (kv_head(b), j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, Dv), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, sq_pad, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, Hq, sq_pad, Dv)[:, :, :Sq]
