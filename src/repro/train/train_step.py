"""Train-step factory: grad-accumulation microbatch scan + remat + AdamW.

The returned ``train_step(state, batch) -> (state, metrics)`` is pjit-ready:
call sites wrap it in ``jax.jit`` with in/out shardings from the plan.  One
optimizer update per call; gradients average over ``shape.microbatch``
sequential microbatches (single implicit dp all-reduce, amortized).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import model as model_lib
from repro.models.config import ModelConfig, ShapeConfig
from repro.train import optimizer as opt_lib


def make_train_state(cfg: ModelConfig, key, opt_cfg: opt_lib.OptConfig):
    params = model_lib.init_params(cfg, key)
    return {"params": params, "opt": opt_lib.init(params, opt_cfg)}


def abstract_train_state(cfg: ModelConfig, opt_cfg: opt_lib.OptConfig):
    params = model_lib.abstract_params(cfg)
    opt = jax.eval_shape(lambda p: opt_lib.init(p, opt_cfg), params)
    return {"params": params, "opt": opt}


def _split_micro(batch, n_micro: int):
    """(G, ...) -> (n_micro, G/n_micro, ...) for every leaf."""
    def split(x):
        g = x.shape[0]
        assert g % n_micro == 0, (g, n_micro)
        return x.reshape(n_micro, g // n_micro, *x.shape[1:])
    return jax.tree.map(split, batch)


def make_train_step(cfg: ModelConfig, shape: ShapeConfig,
                    opt_cfg: opt_lib.OptConfig, *, accum: str = "f32"):
    """``accum``: gradient-accumulator dtype policy across microbatches.
    "f32" — always fp32 (default); "mixed" — bf16 for large leaves
    (>= 4M elements; the MoE expert stacks), fp32 for the rest.  Mixed halves
    accumulator HBM on 100B+-param models at a ~3-bit accumulation-precision
    cost over 8 microbatches.
    """
    n_micro = max(1, shape.microbatch)

    def _accum_dtype(p):
        if accum == "mixed" and p.size >= (1 << 22):
            return jnp.bfloat16
        return jnp.float32

    def loss_of(params, mb):
        loss, metrics = model_lib.loss_fn(params, cfg, mb)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_of, has_aux=True)

    def train_step(state, batch):
        params = state["params"]
        if n_micro == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            micro = _split_micro(batch, n_micro)

            def acc_step(carry, mb):
                g_acc, l_acc = carry
                (l, _), g = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(a.dtype), g_acc, g)
                return (g_acc, l_acc + l), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, _accum_dtype(p)), params)
            (grads, loss), _ = jax.lax.scan(acc_step, (g0, jnp.zeros(())), micro)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32) / n_micro,
                                 grads)
            loss = loss / n_micro
            metrics = {}
        new_params, new_opt, opt_metrics = opt_lib.apply(
            opt_cfg, params, state["opt"], grads)
        out_metrics = {"loss": loss, **opt_metrics}
        return {"params": new_params, "opt": new_opt}, out_metrics

    return train_step


def make_eval_step(cfg: ModelConfig):
    def eval_step(params, batch):
        loss, metrics = model_lib.loss_fn(params, cfg, batch)
        return {"loss": loss, **metrics}
    return eval_step
