"""Train-step factory: grad-accumulation microbatch scan + remat + AdamW.

The returned ``train_step(state, batch) -> (state, metrics)`` is pjit-ready:
call sites wrap it in ``jax.jit`` with in/out shardings from the plan.  One
optimizer update per call; gradients average over ``shape.microbatch``
sequential microbatches (single implicit dp all-reduce, amortized).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.models import model as model_lib
from repro.models.config import ModelConfig, ShapeConfig
from repro.train import grad_compression as gc
from repro.train import optimizer as opt_lib


def make_train_state(cfg: ModelConfig, key, opt_cfg: opt_lib.OptConfig):
    params = model_lib.init_params(cfg, key)
    return {"params": params, "opt": opt_lib.init(params, opt_cfg)}


def abstract_train_state(cfg: ModelConfig, opt_cfg: opt_lib.OptConfig):
    params = model_lib.abstract_params(cfg)
    opt = jax.eval_shape(lambda p: opt_lib.init(p, opt_cfg), params)
    return {"params": params, "opt": opt}


def _split_micro(batch, n_micro: int):
    """(G, ...) -> (n_micro, G/n_micro, ...) for every leaf."""
    def split(x):
        g = x.shape[0]
        assert g % n_micro == 0, (g, n_micro)
        return x.reshape(n_micro, g // n_micro, *x.shape[1:])
    return jax.tree.map(split, batch)


#: leaves at least this large accumulate in bf16 under ``accum="mixed"``
#: (4M elements — the MoE expert stacks; everything smaller stays fp32)
MIXED_ACCUM_MIN_SIZE = 1 << 22


def accum_dtype(accum: str, p, threshold: int = MIXED_ACCUM_MIN_SIZE):
    """Accumulator dtype policy for one grad leaf (see ``make_train_step``)."""
    if accum == "mixed" and p.size >= threshold:
        return jnp.bfloat16
    return jnp.float32


def make_train_step(cfg: ModelConfig, shape: ShapeConfig,
                    opt_cfg: opt_lib.OptConfig, *, accum: str = "f32",
                    accum_threshold: int = MIXED_ACCUM_MIN_SIZE,
                    overlap_comm: bool = False, mesh: Optional[Mesh] = None,
                    pod_axis: str = "pod"):
    """``accum``: gradient-accumulator dtype policy across microbatches.
    "f32" — always fp32 (default); "mixed" — bf16 for large leaves
    (>= 4M elements; the MoE expert stacks), fp32 for the rest.  Mixed halves
    accumulator HBM on 100B+-param models at a ~3-bit accumulation-precision
    cost over 8 microbatches.

    ``overlap_comm``: fold the cross-pod gradient all-reduce into the
    accumulation scan.  Each microbatch's pod-local gradients are int8
    compressed-psum'd over ``pod_axis`` (``grad_compression``) while the
    *next* microbatch's backprop runs, instead of one monolithic fp32
    all-reduce of the whole accumulated tree after the scan — on the slow
    cross-pod links the reduce hides behind compute and shrinks 4x.
    Requires ``mesh`` containing ``pod_axis``, treated as a pure *replica*
    axis (params/opt replicated across pods — the federation layout; FSDP
    keeps sharding over the remaining dp axes via partial-auto shard_map).
    Quantization error is carried microbatch-to-microbatch as error
    feedback in the scan state; the final microbatch's residual is dropped
    (identically on every pod, so replicas stay bitwise in sync), bounding
    the per-step gradient error at one microbatch's quantization noise
    divided by ``n_micro``.
    """
    n_micro = max(1, shape.microbatch)
    if overlap_comm:
        assert mesh is not None and pod_axis in mesh.axis_names, \
            (pod_axis, None if mesh is None else mesh.axis_names)

    def _accum_dtype(p):
        return accum_dtype(accum, p, accum_threshold)

    def loss_of(params, mb):
        loss, metrics = model_lib.loss_fn(params, cfg, mb)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_of, has_aux=True)

    def _accum_serial(params, micro):
        """Plain accumulation: grads come out of ``grad_fn`` already
        globally reduced (GSPMD inserts the dp/pod psum per microbatch)."""
        def acc_step(carry, mb):
            g_acc, l_acc = carry
            (l, _), g = grad_fn(params, mb)
            g_acc = jax.tree.map(
                lambda a, b: a + b.astype(a.dtype), g_acc, g)
            return (g_acc, l_acc + l), None

        g0 = jax.tree.map(
            lambda p: jnp.zeros(p.shape, _accum_dtype(p)), params)
        (grads, loss), _ = jax.lax.scan(acc_step, (g0, jnp.zeros(())), micro)
        return grads, loss

    def _pod_reduce(g_pod, ef):
        """Manual over ``pod_axis``: int8 compressed psum of one
        microbatch's per-pod grads.  Leaves arrive with a leading pod dim
        whose local slice has size 1.  Deliberately scan-free — any
        ``lax.scan`` inside a partial-auto shard_map trips an XLA
        manual-subgroup check on this jax, so the model never runs in
        here (see ``_accum_overlapped``)."""
        red, new_ef = gc.compressed_psum_pod(
            jax.tree.map(lambda x: x[0], g_pod),
            jax.tree.map(lambda e: e[0], ef), mesh, pod_axis)
        return red, jax.tree.map(lambda e: e[None], new_ef)

    def _accum_overlapped(params, micro):
        """Per-microbatch compressed pod reduce inside the accumulation scan
        (the reduce of microbatch i overlaps microbatch i+1's compute).

        Pod-local grads are produced in the *auto* world by vmapping
        ``grad_fn`` over an explicit leading pod dim of the microbatch (the
        batch split GSPMD would do implicitly, made structural), so the
        model's own layer scans never sit inside the manual region; only
        the small elementwise quantize+psum enters shard_map."""
        n_pods = mesh.shape[pod_axis]
        rep = jax.tree.map(lambda _: P(), params)
        pod_lead = jax.tree.map(lambda _: P(pod_axis), params)
        run = shard_map(
            _pod_reduce, mesh=mesh, in_specs=(pod_lead, pod_lead),
            out_specs=(rep, pod_lead), axis_names={pod_axis},
            check_rep=False)
        pod_grad = jax.vmap(grad_fn, in_axes=(None, 0))

        def split_pod(x):
            assert x.shape[0] % n_pods == 0, (x.shape, n_pods)
            x = x.reshape(n_pods, x.shape[0] // n_pods, *x.shape[1:])
            return jax.lax.with_sharding_constraint(
                x, jax.sharding.NamedSharding(mesh, P(pod_axis)))

        def acc_step(carry, mb):
            g_acc, l_acc, ef = carry
            (l, _), g = pod_grad(params, jax.tree.map(split_pod, mb))
            red, ef = run(g, ef)
            g_acc = jax.tree.map(
                lambda a, b: a + b.astype(a.dtype), g_acc, red)
            return (g_acc, l_acc + jnp.mean(l), ef), None

        g0 = jax.tree.map(
            lambda p: jnp.zeros(p.shape, _accum_dtype(p)), params)
        ef0 = jax.tree.map(
            lambda p: jnp.zeros((n_pods, *p.shape), jnp.float32), params)
        (grads, loss, _), _ = jax.lax.scan(
            acc_step, (g0, jnp.zeros(()), ef0), micro)
        return grads, loss

    def train_step(state, batch):
        params = state["params"]
        if n_micro == 1 and not overlap_comm:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            micro = _split_micro(batch, n_micro)
            if overlap_comm:
                grads, loss = _accum_overlapped(params, micro)
            else:
                grads, loss = _accum_serial(params, micro)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32) / n_micro,
                                 grads)
            loss = loss / n_micro
            metrics = {}
        new_params, new_opt, opt_metrics = opt_lib.apply(
            opt_cfg, params, state["opt"], grads)
        out_metrics = {"loss": loss, **opt_metrics}
        return {"params": new_params, "opt": new_opt}, out_metrics

    return train_step


def make_eval_step(cfg: ModelConfig):
    def eval_step(params, batch):
        loss, metrics = model_lib.loss_fn(params, cfg, batch)
        return {"loss": loss, **metrics}
    return eval_step
