"""Block-wise int8 quantization for optimizer state (8-bit Adam).

m and v are stored as int8 codes with fp32 absmax scales per 256-element
block along the last dim (bitsandbytes-style).  This cuts optimizer-state
HBM 4x — the difference between a 400B-param model fitting a 256-chip v5e
pod or not (see EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_to_block(x):
    last = x.shape[-1]
    pad = (-last) % BLOCK
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x, pad


def quantize(x) -> Dict[str, jax.Array]:
    """x: fp array -> {"q": int8 same shape, "s": f32 (..., nblocks)}."""
    if x.ndim == 0:     # scalar leaf: one 1-element block, shape preserved
        st = quantize(x.reshape(1))
        return {"q": st["q"].reshape(()), "s": st["s"]}
    xf = x.astype(jnp.float32)
    orig_last = xf.shape[-1]
    xp, pad = _pad_to_block(xf)
    nb = xp.shape[-1] // BLOCK
    blocks = xp.reshape(*xp.shape[:-1], nb, BLOCK)
    amax = jnp.max(jnp.abs(blocks), axis=-1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(blocks / scale[..., None]), -127, 127).astype(jnp.int8)
    q = q.reshape(*xp.shape)[..., :orig_last]
    return {"q": q, "s": scale}


def dequantize(state: Dict[str, jax.Array]) -> jax.Array:
    q, s = state["q"], state["s"]
    if q.ndim == 0:
        return dequantize({"q": q.reshape(1), "s": s}).reshape(())
    orig_last = q.shape[-1]
    qp, pad = _pad_to_block(q.astype(jnp.float32))
    nb = qp.shape[-1] // BLOCK
    blocks = qp.reshape(*qp.shape[:-1], nb, BLOCK)
    x = blocks * s[..., None]
    return x.reshape(*qp.shape)[..., :orig_last]


def zeros_like_quantized(p) -> Dict[str, jax.Array]:
    last = p.shape[-1] if p.ndim else 1
    nb = -(-last // BLOCK)
    scale_shape = (*p.shape[:-1], nb) if p.ndim else (nb,)
    return {"q": jnp.zeros(p.shape, jnp.int8),
            "s": jnp.ones(scale_shape, jnp.float32)}
