"""int8 gradient compression with error feedback for the low-bandwidth
cross-pod axis.

Mechanism (beyond-paper distributed-optimization trick): per-tensor absmax
int8 quantization.  The quantization error is fed back into the next step's
gradients ("EF-SGD"), preserving convergence.  The compressed all-reduce is
expressed with ``shard_map`` over the ``pod`` axis (manual collective) while
the remaining axes stay under GSPMD auto-sharding, so per-pod gradients are
all-reduced as int8 (4x fewer bytes on the pod links) and dequantized locally.
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map


def quantize(g, *, bits: int = 8):
    """Per-tensor symmetric absmax quantization -> (int8 codes, scale)."""
    gf = g.astype(jnp.float32)
    amax = jnp.max(jnp.abs(gf))
    qmax = 2.0 ** (bits - 1) - 1
    scale = jnp.where(amax > 0, amax / qmax, 1.0)
    codes = jnp.clip(jnp.round(gf / scale), -qmax, qmax).astype(jnp.int8)
    return codes, scale


def dequantize(codes, scale):
    return codes.astype(jnp.float32) * scale


def compress_residual(g, err):
    """Apply error feedback, quantize, return (codes, scale, new_err)."""
    gf = g.astype(jnp.float32) + err
    codes, scale = quantize(gf)
    new_err = gf - dequantize(codes, scale)
    return codes, scale, new_err


def compressed_psum_pod(grads, err, mesh: Mesh, pod_axis: str = "pod"):
    """Mean-reduce ``grads`` over the pod axis in int8 with error feedback.

    Two-phase compressed all-reduce: (1) a scalar pmax agrees on a shared
    scale per tensor; (2) the payload all-reduce runs on int8 codes (widened
    to int32 for the summation — 4x fewer payload bytes on the pod links
    than fp32).  Quantization error is carried into the next step (EF).

    grads/err: pytrees whose leaves are *pod-local* gradients.  Must be
    called inside a shard_map manual over ``pod_axis``.  Returns the
    pod-mean gradients and the new error-feedback tree.
    """
    n = jax.lax.psum(jnp.ones((), jnp.float32), pod_axis)

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        amax = jax.lax.pmax(jnp.max(jnp.abs(gf)), pod_axis)
        scale = jnp.where(amax > 0, amax / 127.0, 1.0)
        codes = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        new_e = gf - codes.astype(jnp.float32) * scale
        total = jax.lax.psum(codes.astype(jnp.int32), pod_axis)
        return total.astype(jnp.float32) * scale / n, new_e

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(treedef, [o[0] for o in out]),
            jax.tree.unflatten(treedef, [o[1] for o in out]))


@functools.lru_cache(maxsize=64)
def _compressed_allreduce_fn(mesh: Mesh, pod_axis: str, n_leaves: int):
    """Jitted shard-mapped reducer, cached by (mesh, axis, leaf count) so
    per-step calls hit the jit cache instead of retracing."""
    spec = tuple(P(pod_axis) for _ in range(n_leaves))

    @functools.partial(shard_map, mesh=mesh, in_specs=(spec, spec),
                       out_specs=(spec, spec), axis_names={pod_axis})
    def _run(gs, es):
        red, new = compressed_psum_pod(list(gs), list(es), mesh, pod_axis)
        return tuple(red), tuple(new)

    return jax.jit(_run)


def compressed_allreduce(grads, err, mesh: Mesh, pod_axis: str = "pod"):
    """Convenience wrapper: run ``compressed_psum_pod`` inside a partial-auto
    ``shard_map`` (manual over ``pod_axis``, GSPMD-auto elsewhere).

    grads/err: pytrees of *global* arrays whose leading dim is sharded over
    ``pod_axis``.  Returns (pod-mean grads, new error-feedback tree) with the
    same global layout.  The shard-mapped body is jitted because partial-auto
    shard_map requires a surrounding jit on jax<=0.4.
    """
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err)
    fn = _compressed_allreduce_fn(mesh, pod_axis, len(flat_g))
    red, new = fn(tuple(flat_g), tuple(flat_e))
    return (jax.tree.unflatten(treedef, list(red)),
            jax.tree.unflatten(treedef, list(new)))


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
