"""Compiled-step cache: reuse jitted executables across runtime rebuilds.

``jax.jit`` caches compiled executables per *wrapper object*, but
``BlockRuntime._build`` historically created a fresh closure and a fresh
``jax.jit`` wrapper on every attach — so a preemption resume on the very
same chips, or a paged serve block rebuilding its ``DecodeScheduler`` after
re-admission, recompiled the whole step from scratch.  On the 400B-class
cells that is minutes of XLA time on the resume critical path.

The cache keys the *logical* build signature — (step family, model config,
shape, optimizer config, mesh geometry + device ids, donate signature) —
and hands back the previously built jit wrapper.  A hit on the same device
set reuses the wrapper's internal executable cache outright (zero
recompilation); a different chip set or mesh geometry is a different key
and compiles its own entry.  Cached wrappers only close over values derived
from the key (configs, meshes with identical device sets, shardings built
from both), so reuse is semantically transparent.

Hits and misses are announced as kind="compile" events on the bus attached
via ``set_bus`` (the controller attaches its own), and the ``Monitor``
counts them — a resume that recompiles when it should not shows up as a
miss where the preemption tests expect a hit.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable, Dict, Optional, Tuple


def freeze(obj) -> Any:
    """Recursively convert configs (dataclasses / dicts / lists / sets)
    into hashable nested tuples for cache keys."""
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return (type(obj).__name__,) + tuple(
            (f.name, freeze(getattr(obj, f.name)))
            for f in dataclasses.fields(obj))
    if isinstance(obj, dict):
        return tuple(sorted((k, freeze(v)) for k, v in obj.items()))
    if isinstance(obj, (list, tuple)):
        return tuple(freeze(v) for v in obj)
    if isinstance(obj, (set, frozenset)):
        return tuple(sorted(freeze(v) for v in obj))
    return obj


def mesh_fingerprint(mesh) -> Tuple:
    """(axis layout, device ids): two meshes with the same fingerprint can
    share a jitted wrapper *and* its compiled executables (jax ``Mesh``
    hashes by content, so equal-fingerprint rebuilds hit jax's own cache)."""
    if mesh is None:
        return ("default",)
    return (tuple(zip(mesh.axis_names, mesh.devices.shape)),
            tuple(int(d.id) for d in mesh.devices.flat))


class CompileCache:
    """Thread-safe keyed store of built (usually jitted) step callables."""

    def __init__(self, bus=None):
        self._lock = threading.Lock()
        self._entries: Dict[Any, Any] = {}
        self.hits = 0
        self.misses = 0
        self._bus = bus

    def set_bus(self, bus) -> None:
        """Attach the event bus hit/miss events are published on (the
        controller attaches its own at construction)."""
        self._bus = bus

    def get(self, key, builder: Callable[[], Any], *,
            label: str = "step", block_id: Optional[str] = None,
            app_id: Optional[str] = None, now: Optional[float] = None) -> Any:
        """Return the cached artifact for ``key``, building (and caching)
        it with ``builder()`` on a miss.  Publishes a kind="compile" event
        either way."""
        with self._lock:
            hit = key in self._entries
            if hit:
                self.hits += 1
                out = self._entries[key]
        if not hit:
            out = builder()          # build outside the lock: XLA is slow
            with self._lock:
                # a racing builder may have landed first; keep the winner
                # so every caller shares one wrapper (and its jit cache)
                out = self._entries.setdefault(key, out)
                self.misses += 1
        bus = self._bus
        if bus is not None:
            bus.publish("compile", block_id=block_id, app_id=app_id,
                        now=now, action="hit" if hit else "miss",
                        label=label)
        return out

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"hits": self.hits, "misses": self.misses,
                    "entries": len(self._entries)}

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self.hits = 0
            self.misses = 0


#: process-wide default — BlockRuntime and DecodeScheduler build through
#: this so any rebuild anywhere in the process can reuse prior work
GLOBAL = CompileCache()
