"""AdamW over bf16 params with fp32 *or* block-quantized int8 moments,
global-norm clipping, warmup+cosine schedule, and optional per-layer scanned
updates (bounds optimizer temp memory to one layer-slice at a time).

States are sharded exactly like their params (ZeRO-3 when the plan FSDPs
params); with ``state_bits=8`` the m/v trees hold {"q": int8, "s": f32}
leaves (see ``quantized_state``), cutting optimizer HBM 4x — required to fit
the 400B-class MoE cells on a single 256-chip v5e pod.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.train import quantized_state as qs


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    state_bits: Optional[int] = None     # None = fp32 moments; 8 = int8
    scan_stacked: bool = True            # lax.map update over layer stacks
    scan_min_ndim: int = 3               # leaves with >= this many dims scan
    fused: str = "auto"                  # kernels.ops.fused_adamw impl:
                                         # "auto"/"pallas"/"jnp"; "off" =
                                         # composed _adam_leaf reference


def schedule(cfg: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * cos


def init(params, cfg: Optional[OptConfig] = None) -> Dict[str, Any]:
    cfg = cfg or OptConfig()
    if cfg.state_bits == 8:
        zeros = lambda p: qs.zeros_like_quantized(p)
    else:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    make = lambda: jax.tree.map(zeros, params)
    return {"m": make(), "v": make(), "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(l.astype(jnp.float32) ** 2) for l in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def _adam_leaf(cfg: OptConfig, lr, scale, bc1, bc2, p, g, m, v):
    """One leaf's update in fp32; m/v enter/leave in storage format.

    Reference implementation: ``kernels.ops.fused_adamw`` must reproduce
    this op sequence bit-for-bit (see tests/test_kernels.py).  The moment
    format is read off the leaf itself (quantized leaves are {"q","s"}
    dicts) so fp32 fallbacks for odd leaves stay possible under
    ``state_bits=8``.
    """
    quantized = isinstance(m, dict)
    g = g.astype(jnp.float32) * scale
    m_f = qs.dequantize(m) if quantized else m
    v_f = qs.dequantize(v) if quantized else v
    m_f = cfg.b1 * m_f + (1 - cfg.b1) * g
    v_f = cfg.b2 * v_f + (1 - cfg.b2) * g * g
    delta = (m_f / bc1) / (jnp.sqrt(v_f / bc2) + cfg.eps)
    if p.ndim >= 2:     # decoupled weight decay on matrices only
        delta = delta + cfg.weight_decay * p.astype(jnp.float32)
    new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
    if quantized:
        return new_p, qs.quantize(m_f), qs.quantize(v_f)
    return new_p, m_f, v_f


def _leaf_update(cfg: OptConfig, lr, scale, bc1, bc2, p, g, m, v):
    """Dispatch one leaf to the fused kernel (one HBM pass) or the composed
    reference.  Both are bit-identical on CPU; on TPU ``fused != "off"``
    routes through the Pallas kernel in ``kernels/fused_adamw.py``."""
    if cfg.fused == "off":
        return _adam_leaf(cfg, lr, scale, bc1, bc2, p, g, m, v)
    return ops.fused_adamw(
        p, g, m, v, lr=lr, scale=scale, bc1=bc1, bc2=bc2, b1=cfg.b1,
        b2=cfg.b2, eps=cfg.eps, weight_decay=cfg.weight_decay,
        impl=cfg.fused)


def apply(cfg: OptConfig, params, opt_state, grads
          ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    step = opt_state["step"] + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    upd = functools.partial(_leaf_update, cfg, lr, scale, bc1, bc2)

    flat_p, treedef = jax.tree.flatten(params)
    is_state_leaf = (lambda x: isinstance(x, dict) and "q" in x) \
        if cfg.state_bits == 8 else None
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"], is_leaf=is_state_leaf)
    flat_v = jax.tree.leaves(opt_state["v"], is_leaf=is_state_leaf)

    out = []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        if (cfg.scan_stacked and p.ndim >= cfg.scan_min_ndim
                and p.shape[0] <= 64 and p.size // max(p.shape[0], 1) >= 2 ** 16):
            # scan the update over the leading (layer-stack) dim: optimizer
            # temps hold one layer slice, not the whole stacked tensor
            new_p, new_m, new_v = jax.lax.map(
                lambda pgmv: upd(*pgmv), (p, g, m, v))
        else:
            new_p, new_m, new_v = upd(p, g, m, v)
        out.append((new_p, new_m, new_v))

    unflat = lambda i: jax.tree.unflatten(treedef, [o[i] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return unflat(0), {"m": unflat(1), "v": unflat(2), "step": step}, metrics
