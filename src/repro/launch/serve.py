"""Serving driver: batched prefill + autoregressive decode on real devices.

  PYTHONPATH=src python -m repro.launch.serve --arch deepseek_7b --smoke \
      --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro.data import pipeline
from repro.models import model as model_lib
from repro.models.config import ShapeConfig
from repro.serve import serve_step as serve_lib


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--sample", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (configs.get_smoke(args.arch) if args.smoke
           else configs.get(args.arch))
    if cfg.is_encoder:
        raise SystemExit("encoder-only arch has no decode path")
    B, P, G = args.batch, args.prompt_len, args.gen
    smax = P + G
    key = jax.random.PRNGKey(args.seed)
    params = model_lib.init_params(cfg, key)

    shape = ShapeConfig("cli", "prefill", seq_len=P, global_batch=B)
    batch = {k: jnp.asarray(v) for k, v in pipeline.synthetic_batch(
        cfg, shape, step=0, seed=args.seed).items() if k != "labels"}

    cache = model_lib.init_cache(cfg, B, smax)
    prefill = jax.jit(serve_lib.make_prefill_step(cfg))
    decode = jax.jit(serve_lib.make_decode_step(cfg, sample=args.sample))

    t0 = time.time()
    logits, cache = prefill(params, batch, cache)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    jax.block_until_ready(tok)
    t_prefill = time.time() - t0

    out_tokens = [tok]
    t0 = time.time()
    for i in range(G - 1):
        tok, cache = decode(params, tok, cache, jnp.int32(P + i))
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen = np.concatenate([np.asarray(t) for t in out_tokens], axis=1)
    print(f"# arch={cfg.name} batch={B} prompt={P} gen={G}")
    print(f"# prefill: {t_prefill*1e3:.1f} ms "
          f"({B*P/t_prefill:.0f} tok/s)")
    print(f"# decode:  {t_decode*1e3:.1f} ms "
          f"({B*(G-1)/max(t_decode,1e-9):.0f} tok/s)")
    print("# first generations:", gen[:2, :10].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
