"""Serving driver: batched prefill + autoregressive decode — run as a
serve-kind block through the ClusterDaemon service layer (register ->
admit -> activate -> prefill -> decode steps -> download), so the CLI
exercises the same lifecycle, dispatcher and monitoring as any other
tenant of the public cluster.

  PYTHONPATH=src python -m repro.launch.serve --arch deepseek_7b --smoke \
      --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro.core.daemon import ClusterDaemon
from repro.core.runtime import JobSpec
from repro.core.topology import Topology
from repro.data import pipeline
from repro.models.config import ShapeConfig


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--sample", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (configs.get_smoke(args.arch) if args.smoke
           else configs.get(args.arch))
    if cfg.is_encoder:
        raise SystemExit("encoder-only arch has no decode path")
    B, P, G = args.batch, args.prompt_len, args.gen

    n_dev = len(jax.devices())
    topo = Topology(n_pods=1, pod_x=n_dev, pod_y=1)
    daemon = ClusterDaemon(topo, ckpt_root="artifacts/serve_ckpt")
    # cache sized for prompt + generation; the block's decode step and
    # (lazy) prefill both compile on its granted sub-mesh
    job = JobSpec(cfg, ShapeConfig("cli", "serve", seq_len=P + G,
                                   global_batch=B),
                  kind="serve", seed=args.seed, decode_sample=args.sample)
    app_id, grant = daemon.submit("cli", f"serve {cfg.name}", n_dev,
                                  job=job)
    assert grant is not None, "single-tenant pod must admit immediately"
    rt = daemon.runtime(app_id)

    prompt_shape = ShapeConfig("cli", "prefill", seq_len=P, global_batch=B)
    batch = {k: jnp.asarray(v) for k, v in pipeline.synthetic_batch(
        cfg, prompt_shape, step=0, seed=args.seed).items()
        if k != "labels"}

    t0 = time.time()
    rt.prefill(batch)
    jax.block_until_ready(rt.token)
    t_prefill = time.time() - t0

    out_tokens = [np.asarray(rt.token)]
    t0 = time.time()
    for _ in range(G - 1):
        # one dispatch round per generated token so every token is
        # collected (decode is a serial chain — no parallelism is lost)
        daemon.run_steps({app_id: 1})
        out_tokens.append(np.asarray(rt.token))
    t_decode = time.time() - t0

    gen = np.concatenate(out_tokens, axis=1)
    res = daemon.download(app_id)
    print(f"# arch={cfg.name} batch={B} prompt={P} gen={G} "
          f"block={grant.block_id}")
    print(f"# prefill: {t_prefill*1e3:.1f} ms "
          f"({B*P/t_prefill:.0f} tok/s)")
    print(f"# decode:  {t_decode*1e3:.1f} ms "
          f"({B*(G-1)/max(t_decode,1e-9):.0f} tok/s) "
          f"steps={res['steps']}")
    print("# first generations:", gen[:2, :10].tolist())
    daemon.expire(app_id)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
