"""Per-scope attribution of roofline terms — the profiling tool for the
hypothesis->change->measure loop (EXPERIMENTS.md §Perf).

Groups flops / HBM bytes / collective bytes by the jax named-scope prefix in
each instruction's op_name metadata, so a dominant term can be traced to the
owning subsystem (attention, moe, optimizer, grad-accum, ...).

  PYTHONPATH=src python -m repro.launch.attribute --arch deepseek_v2_236b \
      --shape train_4k [--multi-pod] [--top 20] [--by coll|hbm|flops]
"""
import argparse
import re
import sys
from collections import Counter

from repro.launch import hlo_parse


_OPNAME_RE = re.compile(r'op_name="([^"]*)"')


def _scope_of(line: str, depth: int = 3) -> str:
    m = _OPNAME_RE.search(line)
    if not m:
        return "(no-scope)"
    parts = [p for p in m.group(1).split("/") if not p.startswith("jit(")]
    keep = []
    for p in parts:
        keep.append(p.split("[")[0])
        if len(keep) >= depth:
            break
    return "/".join(keep) or "(root)"


def attribute(text: str, depth: int = 3):
    a = hlo_parse.HloAnalyzer(text)
    flops, hbm, coll = Counter(), Counter(), Counter()

    def walk(comp_name, mult, top):
        comp = a.comps.get(comp_name)
        if comp is None:
            return
        for name in comp.order:
            ins = comp.instrs[name]
            op = ins.opcode
            scope = _scope_of(ins.line, depth)
            if op == "while":
                trip = a._while_trip(ins)
                fused = "vmem_fused" in ins.line
                mb = re.search(r"body=%([\w.\-]+)", ins.line)
                if fused and top:
                    hbm[scope] += (hlo_parse._shape_bytes(
                        a._operand_shapes(comp, ins))
                        + hlo_parse._shape_bytes(ins.shapes)) * mult
                if mb:
                    walk(mb.group(1), mult * trip, top and not fused)
                continue
            if op in ("fusion", "call", "async-start"):
                mb = re.search(r"(?:calls|body)=%([\w.\-]+)", ins.line)
                inner = a.computation_costs(mb.group(1), False) if mb else None
                if inner:
                    flops[scope] += inner.flops * mult
                    for k, v in inner.coll_bytes.items():
                        coll[scope + f" [{k}]"] += v * mult
                if top:
                    hbm[scope] += a._fusion_traffic(
                        comp, ins, mb.group(1) if mb else None) * mult
                continue
            kind = op.replace("-start", "")
            if kind in hlo_parse._COLL_KINDS:
                b = hlo_parse._shape_bytes(a._operand_shapes(comp, ins))
                coll[scope + f" [{kind}]"] += b * mult
                if top:
                    hbm[scope] += (b + hlo_parse._shape_bytes(ins.shapes)) * mult
                continue
            if op in hlo_parse._FREE_OPS or op.endswith("-done"):
                continue
            if op == "dot":
                flops[scope] += a._dot_flops(comp, ins) * mult
            if top and op not in ("copy", "convert"):
                if op == "dynamic-update-slice":
                    upd = (comp.instrs.get(ins.operands[1])
                           if len(ins.operands) > 1 else None)
                    hbm[scope] += 2.0 * (hlo_parse._shape_bytes(upd.shapes)
                                         if upd else 0) * mult
                elif op in ("dynamic-slice", "slice", "gather"):
                    hbm[scope] += 2.0 * hlo_parse._shape_bytes(ins.shapes) * mult
                else:
                    hbm[scope] += (hlo_parse._shape_bytes(
                        a._operand_shapes(comp, ins))
                        + hlo_parse._shape_bytes(ins.shapes)) * mult

    walk(a.entry.name, 1, True)
    return flops, hbm, coll


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--top", type=int, default=15)
    ap.add_argument("--depth", type=int, default=4)
    ap.add_argument("--hlo-file", default=None,
                    help="analyze a saved HLO text instead of lowering")
    args = ap.parse_args(argv)

    if args.hlo_file:
        text = open(args.hlo_file).read()
    else:
        from repro.launch import dryrun
        lowered, meta = dryrun.lower_cell(args.arch, args.shape,
                                          multi_pod=args.multi_pod,
                                          microbatch=args.microbatch)
        text = lowered.compile().as_text()
    flops, hbm, coll = attribute(text, args.depth)
    for title, counter, unit, scale in (
            ("FLOPS", flops, "GF", 1e9), ("HBM", hbm, "GB", 1e9),
            ("COLLECTIVES", coll, "GB", 1e9)):
        total = sum(counter.values())
        print(f"== {title}: total {total/scale:.1f} {unit} (per device)")
        for scope, v in counter.most_common(args.top):
            print(f"  {v/scale:10.2f} {unit}  {v/total*100:5.1f}%  {scope}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
