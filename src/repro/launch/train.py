"""End-to-end training driver (single block, real execution).

Runs a reduced or full architecture config for N steps on the available
devices with the production plan machinery: sharded state, synthetic data
pipeline, async checkpointing, monitoring.  Used by the examples and the
~100M-scale end-to-end run in EXPERIMENTS.md.

  PYTHONPATH=src python -m repro.launch.train --arch xlstm_350m --steps 200 \
      --seq-len 256 --global-batch 8 --smoke
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro.checkpoint.manager import CheckpointManager
from repro.data import pipeline
from repro.models import model as model_lib
from repro.models.config import ShapeConfig
from repro.sharding import ctx as shard_ctx
from repro.sharding import plans
from repro.train import optimizer as opt_lib
from repro.train import train_step as train_lib


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = (configs.get_smoke(args.arch) if args.smoke
           else configs.get(args.arch))
    shape = ShapeConfig("cli", "train", seq_len=args.seq_len,
                        global_batch=args.global_batch,
                        microbatch=args.microbatch)
    opt_cfg = opt_lib.OptConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                                total_steps=args.steps)

    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev, 1), ("data", "model")) if n_dev > 1 else \
        jax.make_mesh((1, 1), ("data", "model"))
    axes = plans.MeshAxes(dp=("data",), model="model")
    ctx = shard_ctx.ShardCtx(mesh, ("data",), "model")

    state_abs = train_lib.abstract_train_state(cfg, opt_cfg)
    p_spec = plans.param_specs(state_abs["params"], mesh, axes)
    state_spec = {"params": p_spec,
                  "opt": plans.opt_state_specs(state_abs["opt"], p_spec)}
    state_sh = plans.to_shardings(state_spec, mesh)
    batch_abs = pipeline.input_specs(cfg, shape)
    batch_sh = plans.to_shardings(
        plans.batch_specs(batch_abs, mesh, axes), mesh)

    step_fn = train_lib.make_train_step(cfg, shape, opt_cfg)

    def fn(state, batch):
        with shard_ctx.use(ctx):
            return step_fn(state, batch)

    jstep = jax.jit(fn, in_shardings=(state_sh, batch_sh),
                    out_shardings=(state_sh, None), donate_argnums=(0,))
    init = jax.jit(lambda k: train_lib.make_train_state(cfg, k, opt_cfg),
                   out_shardings=state_sh)
    state = init(jax.random.PRNGKey(args.seed))
    n_params = model_lib.count_params(state["params"])
    print(f"# arch={cfg.name} params={n_params/1e6:.2f}M devices={n_dev} "
          f"tokens/step={shape.global_batch * shape.seq_len}")

    ckpt = None
    start_step = 0
    if args.ckpt_dir:
        ckpt = CheckpointManager(args.ckpt_dir, namespace=cfg.name)
        if args.resume and ckpt.latest_step() is not None:
            state, start_step = ckpt.restore(state, shardings=state_sh)
            print(f"# resumed from step {start_step}")

    data = pipeline.DataIterator(cfg, shape, seed=args.seed,
                                 shardings=batch_sh)
    losses = []
    t_start = time.time()
    for step in range(start_step, args.steps):
        batch = data.batch(step)
        state, metrics = jstep(state, batch)
        if step % args.log_every == 0 or step == args.steps - 1:
            loss = float(metrics["loss"])
            losses.append(loss)
            print(f"step {step:5d} loss {loss:8.4f} "
                  f"gnorm {float(metrics['grad_norm']):8.3f} "
                  f"lr {float(metrics['lr']):.2e}", flush=True)
        if ckpt and args.ckpt_every and (step + 1) % args.ckpt_every == 0:
            ckpt.save_async(step + 1, state)
    if ckpt:
        ckpt.wait()
    wall = time.time() - t_start
    tok_s = (args.steps - start_step) * shape.global_batch * shape.seq_len / wall
    print(f"# done: {wall:.1f}s, {tok_s:.0f} tok/s, "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
