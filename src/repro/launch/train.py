"""End-to-end training driver (single block, real execution) — runs
through the ClusterDaemon service layer: the job is registered, admitted
and activated as a block (the full paper lifecycle), stepped through the
event-driven dispatcher, and monitored via the event bus, exactly like a
tenant of the public cluster.  Nothing here constructs a controller
directly.

  PYTHONPATH=src python -m repro.launch.train --arch xlstm_350m --steps 200 \
      --seq-len 256 --global-batch 8 --smoke
"""
from __future__ import annotations

import argparse
import time

import jax

import repro.configs as configs
from repro.core.daemon import ClusterDaemon
from repro.core.runtime import JobSpec
from repro.core.topology import Topology
from repro.models import model as model_lib
from repro.models.config import ShapeConfig
from repro.train import optimizer as opt_lib


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--autostep", action="store_true",
                    help="daemon-side stepping: the cluster's autostep "
                         "engine drives the block to --steps (checkpoints "
                         "included); no client step loop")
    ap.add_argument("--pace", type=float, default=None,
                    help="with --autostep: cap the engine at this many "
                         "steps/s")
    args = ap.parse_args(argv)

    cfg = (configs.get_smoke(args.arch) if args.smoke
           else configs.get(args.arch))
    shape = ShapeConfig("cli", "train", seq_len=args.seq_len,
                        global_batch=args.global_batch,
                        microbatch=args.microbatch)
    opt_cfg = opt_lib.OptConfig(lr=args.lr,
                                warmup_steps=max(args.steps // 20, 1),
                                total_steps=args.steps)

    # one block spanning every available device, granted by the daemon
    # (--autostep needs the background pump: the engine steps from there)
    n_dev = len(jax.devices())
    topo = Topology(n_pods=1, pod_x=n_dev, pod_y=1)
    daemon = ClusterDaemon(topo,
                           ckpt_root=args.ckpt_dir or "artifacts/train_ckpt",
                           background=args.autostep)
    job = JobSpec(cfg, shape, opt=opt_cfg, seed=args.seed,
                  collect_metrics=True,
                  # stable namespace so --resume finds earlier runs
                  ckpt_namespace=cfg.name if args.ckpt_dir else None,
                  # periodic checkpoints under autostep come from the
                  # engine (client-driven mode saves between chunks below)
                  ckpt_every=(args.ckpt_every if args.ckpt_dir else 0))
    app_id, grant = daemon.submit("cli", f"train {cfg.name}", n_dev,
                                  job=job)
    assert grant is not None, "single-tenant pod must admit immediately"
    rt = daemon.runtime(app_id)
    n_params = model_lib.count_params(rt.state["params"])
    print(f"# arch={cfg.name} params={n_params/1e6:.2f}M devices={n_dev} "
          f"block={grant.block_id} "
          f"tokens/step={shape.global_batch * shape.seq_len}")

    start_step = 0
    if args.ckpt_dir and args.resume:
        at = daemon.restore(app_id)
        if at is not None:
            start_step = rt.step_count
            print(f"# resumed from step {start_step}")

    losses = []

    def on_step(ev):
        """Event-bus monitoring: each completed step carries its metrics
        (collect_metrics=True) through the async dispatch window."""
        p = ev.payload
        m = p.get("metrics") or {}
        step = daemon.monitor.steps_done(ev.block_id) + start_step - 1
        if "loss" in m:
            losses.append(m["loss"])
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {m.get('loss', float('nan')):8.4f} "
                  f"gnorm {m.get('grad_norm', float('nan')):8.3f} "
                  f"lr {m.get('lr', 0.0):.2e}", flush=True)

    daemon.bus.subscribe(on_step, kinds={"step"})

    t_start = time.time()
    if args.autostep:
        # daemon-side execution: arm the engine and watch — zero client
        # step calls; progress, metrics and checkpoints all flow from the
        # pump thread through the event bus
        from repro.core.block import BlockState
        daemon.autostep_enable(app_id, until_steps=args.steps,
                               max_rate_hz=args.pace)
        while daemon.registry.get(app_id).state not in (
                BlockState.DONE, BlockState.FAILED, BlockState.EXPIRED):
            time.sleep(0.1)
        if args.ckpt_dir and args.ckpt_every:
            daemon.save(app_id, async_=True)   # final-step checkpoint
    else:
        done = start_step
        while done < args.steps:
            chunk = min(args.ckpt_every or args.steps, args.steps - done)
            daemon.run_steps({app_id: chunk})
            done += chunk
            if args.ckpt_dir and args.ckpt_every:
                daemon.save(app_id, async_=True)
    wall = time.time() - t_start

    rt.ckpt.wait()                # an async save may still be landing
    res = daemon.download(app_id)
    tok_s = ((args.steps - start_step) * shape.global_batch *
             shape.seq_len / wall)
    loss_span = (f"loss {losses[0]:.4f} -> {losses[-1]:.4f}"
                 if losses else "loss n/a")
    print(f"# done: {wall:.1f}s, {tok_s:.0f} tok/s, {loss_span}, "
          f"checkpoints={res['checkpoints']}")
    daemon.expire(app_id)
    daemon.stop()          # no-op in deterministic (non --autostep) mode
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
