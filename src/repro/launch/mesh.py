"""Production mesh builders.

Functions, not module constants: importing this module never touches jax
device state.  The dry-run process sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import so these shapes are satisfiable on the CPU host.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_block_mesh(devices, shape, axis_names=("data", "model")):
    """Mesh over an explicit device subset (a tenant block's sub-mesh)."""
    import numpy as np
    arr = np.asarray(devices).reshape(shape)
    return jax.sharding.Mesh(arr, axis_names)
