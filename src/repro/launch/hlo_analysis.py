"""Post-compile HLO analysis: collective-traffic extraction + roofline terms.

``cost_analysis()`` supplies FLOPs and bytes-accessed; collective bytes are
not in it, so we parse the optimized HLO text and sum operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute /
collective-broadcast op (assignment convention: operand bytes).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

import numpy as np

# TPU v5e hardware constants (assignment-specified)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "collective-broadcast")

# e.g.  bf16[16,4096,5120]{2,1,0}
_SHAPE_RE = re.compile(r"\b([a-z]+\d+(?:e\d+m\d+(?:fn)?)?|pred)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(?:\([^)]*\)|[^=]+?)\s*"
    r"(all-gather-start|all-gather|all-reduce-start|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute|"
    r"collective-broadcast)\(", re.M)


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    bytes_by_kind: Dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.counts.values())


def collective_stats(hlo_text: str) -> CollectiveStats:
    counts: Dict[str, int] = {}
    total: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        kind = m.group(1).replace("-start", "")
        # operand shapes = every shape appearing AFTER the opcode's '('
        after = line[m.end():]
        op_bytes = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(after))
        counts[kind] = counts.get(kind, 0) + 1
        total[kind] = total.get(kind, 0) + op_bytes
    return CollectiveStats(counts=counts, bytes_by_kind=total)


@dataclasses.dataclass
class Roofline:
    hlo_flops: float             # total FLOPs across all devices
    hlo_bytes: float             # total HBM bytes accessed across devices
    collective_bytes: float      # summed collective operand bytes (per device program)
    n_chips: int
    model_flops: float = 0.0
    bytes_per_device: float = 0.0

    @property
    def compute_s(self) -> float:
        return self.hlo_flops / (self.n_chips * PEAK_FLOPS)

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / (self.n_chips * HBM_BW)

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / (self.n_chips * LINK_BW)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step time = max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_frac(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achieved at the modeled step time:
        useful (model) FLOPs / (step_time * peak).  1.0 = compute-bound with
        zero waste."""
        denom = self.step_time_s * self.n_chips * PEAK_FLOPS
        return self.model_flops / denom if denom else 0.0

    xla_cost: Optional[Dict] = None
    coll_detail: Optional[Dict] = None

    def to_dict(self) -> Dict:
        return {
            "xla_cost": self.xla_cost,
            "coll_detail": self.coll_detail,
            "hlo_flops": self.hlo_flops,
            "hlo_bytes": self.hlo_bytes,
            "collective_bytes": self.collective_bytes,
            "n_chips": self.n_chips,
            "model_flops": self.model_flops,
            "bytes_per_device": self.bytes_per_device,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "step_time_s": self.step_time_s,
            "useful_flops_frac": self.useful_flops_frac,
            "roofline_fraction": self.roofline_fraction,
        }


def model_step_flops(cfg, shape) -> float:
    """Analytic useful-FLOPs per step: 6ND for training, 2ND for inference
    (N = active non-embedding params, D = tokens touched per step).  The
    numerator of MFU — what the Monitor divides by measured step time."""
    from repro.models import model as model_lib
    n_active = model_lib.count_active_params(cfg)
    # exclude the embedding gather (not matmul flops); keep lm_head
    n_eff = max(n_active - cfg.vocab_size * cfg.d_model, 1)
    if shape.kind == "train":
        return 6.0 * n_eff * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_eff * shape.global_batch * shape.seq_len
    return 2.0 * n_eff * shape.global_batch      # decode: one token per seq


def block_roofline(cfg, shape, n_chips: int) -> Dict:
    """Roofline model for a live block, for ``Monitor.set_roofline``.

    Prefers the dry-run artifact for this (arch, shape) cell — the full
    compute/memory/collective model from the compiled HLO — and falls back
    to the analytic compute-bound floor (model FLOPs / chips x peak) when no
    sweep has been run, so every block always carries an MFU denominator.
    """
    flops = model_step_flops(cfg, shape)
    out = {"model_flops": flops, "n_chips": int(n_chips),
           "peak_flops": PEAK_FLOPS, "source": "analytic",
           "step_time_s": flops / (max(1, n_chips) * PEAK_FLOPS),
           "bottleneck": "compute"}
    cell = dryrun_roofline(getattr(cfg, "name", None),
                           getattr(shape, "name", None))
    if cell:
        # per-chip terms from the sweep's mesh scale to this block's size:
        # step time is per-device under perfect balance, so it carries over
        out.update({"source": "dryrun",
                    "step_time_s": cell["step_time_s"],
                    "bottleneck": cell.get("bottleneck", "compute"),
                    "model_flops": cell.get("model_flops", flops) or flops})
    return out


def dryrun_roofline(arch: Optional[str],
                    shape_name: Optional[str]) -> Optional[Dict]:
    """Look up the dry-run sweep's roofline dict for one cell, or None.

    Reads ``artifacts/dryrun/*.jsonl`` (written by ``repro.launch.dryrun
    --all --out``; tabulated by ``benchmarks/roofline_report.py``).  Single-
    pod cells win over multi-pod when both exist."""
    if not arch or not shape_name:
        return None
    import glob as _glob
    import json as _json
    import os as _os
    art = _os.path.join(_os.path.dirname(__file__), "..", "..", "..",
                        "artifacts", "dryrun")
    best = None
    for path in sorted(_glob.glob(_os.path.join(art, "*.jsonl"))):
        try:
            with open(path) as f:
                for line in f:
                    try:
                        d = _json.loads(line)
                    except ValueError:
                        continue
                    if (d.get("arch") == arch
                            and d.get("shape") == shape_name
                            and d.get("status") == "ok"
                            and "roofline" in d):
                        if best is None or d.get("mesh") == "single":
                            best = d["roofline"]
        except OSError:
            continue
    return best


def analyze(compiled, *, n_chips: int, model_flops: float = 0.0) -> Roofline:
    """Roofline terms from a compiled SPMD executable.

    The compiled program is per-device; the trip-count-aware HLO walker
    (``hlo_parse``) supplies per-device flops / HBM bytes / collective operand
    bytes, which are scaled by ``n_chips`` into global quantities.  The three
    roofline terms then divide by (chips x per-chip peak), i.e. they equal the
    per-device time under perfect balance.  XLA's own ``cost_analysis()``
    counts while bodies once and is kept only as a cross-check field.
    """
    from repro.launch import hlo_parse
    text = compiled.as_text()
    costs = hlo_parse.analyze_text(text)
    xla_cost = {}
    try:
        c = compiled.cost_analysis()
        if isinstance(c, list):
            c = c[0]
        xla_cost = {"flops": float(c.get("flops", 0.0)),
                    "bytes_accessed": float(c.get("bytes accessed", 0.0))}
    except Exception:
        pass
    bpd = 0.0
    try:
        mem = compiled.memory_analysis()
        bpd = float(mem.temp_size_in_bytes + mem.argument_size_in_bytes
                    + mem.output_size_in_bytes - mem.alias_size_in_bytes)
    except Exception:
        pass
    r = Roofline(hlo_flops=costs.flops * n_chips,
                 hlo_bytes=costs.hbm_bytes * n_chips,
                 collective_bytes=costs.total_coll_bytes * n_chips,
                 n_chips=n_chips, model_flops=model_flops,
                 bytes_per_device=bpd)
    r.xla_cost = xla_cost
    r.coll_detail = {"bytes_by_kind": dict(costs.coll_bytes),
                     "counts": dict(costs.coll_counts)}
    return r
