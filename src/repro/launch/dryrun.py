"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell with production shardings, prove it fits (memory_analysis) and
extract roofline terms (cost_analysis + collective parse).

The two ``os.environ`` lines below MUST precede any jax import: jax locks the
device count on first init.  This module is the only place the 512-device
override is set (smoke tests and benches see the real single CPU device).

Usage:
  python -m repro.launch.dryrun --arch deepseek_7b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out artifacts/dryrun
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import sys
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

import repro.configs as configs
from repro.data import pipeline
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.models import model as model_lib
from repro.models.config import ModelConfig, ShapeConfig
from repro.serve import serve_step as serve_lib
from repro.sharding import ctx as shard_ctx
from repro.sharding import plans
from repro.train import optimizer as opt_lib
from repro.train import train_step as train_lib

# per-(arch, shape) training overrides (memory fitting; see EXPERIMENTS.md
# §Dry-run).  The 100B+-scale MoE models need 8-bit Adam moments + mixed-
# precision grad accumulation to fit 16 GB/chip on the single-pod mesh.
TRAIN_OVERRIDES: Dict[str, Dict[str, Any]] = {
    "llama4_maverick_400b": {"state_bits": 8, "accum": "mixed"},
    "deepseek_v2_236b": {"state_bits": 8, "accum": "mixed"},
    # sub-1B model: TP buys nothing and the sLSTM time scan would pay
    # per-step model-axis collectives — run pure 256-way DP (ZeRO-3)
    "xlstm_350m": {"no_tp": True, "microbatch": 1},
}


def _sds(tree_abstract, sharding_tree):
    return jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        tree_abstract, sharding_tree)


def _model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    return hlo_analysis.model_step_flops(cfg, shape)


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               microbatch: Optional[int] = None, plan_overrides=None):
    """Build + lower one cell.  Returns (lowered, meta dict)."""
    cfg = configs.get(arch)
    shape = configs.shape(shape_name)
    if microbatch:
        shape = shape.replace(microbatch=microbatch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    axes = plans.MeshAxes.from_mesh(mesh)
    ctx = shard_ctx.ShardCtx(mesh, axes.dp, axes.model,
                             seq_axis=axes.dp[-1])

    if shape.kind == "train":
        over = TRAIN_OVERRIDES.get(configs.canonical(arch), {})
        if over.get("microbatch") and not microbatch:
            shape = shape.replace(microbatch=over["microbatch"])
        # no_tp folds the model axis into dp: requires global_batch %
        # dp_size == 0, which holds on the single-pod mesh (256) but not on
        # the 512-chip multi-pod mesh with batch 256 — there the default
        # TP plan stays in force.
        no_tp = bool(over.get("no_tp")) and not multi_pod
        if no_tp:
            axes = plans.MeshAxes(dp=tuple(mesh.axis_names), model="model")
            ctx = shard_ctx.ShardCtx(mesh, axes.dp, "model", tp=False)
        opt_cfg = opt_lib.OptConfig(state_bits=over.get("state_bits"))
        step_fn = train_lib.make_train_step(cfg, shape, opt_cfg,
                                            accum=over.get("accum", "f32"))
        state_abs = train_lib.abstract_train_state(cfg, opt_cfg)
        p_spec = plans.param_specs(state_abs["params"], mesh, axes,
                                   no_tp=no_tp)
        state_spec = {"params": p_spec,
                      "opt": plans.opt_state_specs(state_abs["opt"], p_spec)}
        state_shard = plans.to_shardings(state_spec, mesh)
        batch_abs = pipeline.input_specs(cfg, shape)
        b_spec = plans.batch_specs(batch_abs, mesh, axes)
        b_shard = plans.to_shardings(b_spec, mesh)
        metrics_abs = {"loss": jax.ShapeDtypeStruct((), jnp.float32),
                       "grad_norm": jax.ShapeDtypeStruct((), jnp.float32),
                       "lr": jax.ShapeDtypeStruct((), jnp.float32)}
        out_shard = (state_shard,
                     jax.tree.map(lambda _: NamedSharding(mesh, P()),
                                  metrics_abs))

        def fn(state, batch):
            with shard_ctx.use(ctx):
                return step_fn(state, batch)

        jitted = jax.jit(fn, in_shardings=(state_shard, b_shard),
                         out_shardings=out_shard, donate_argnums=(0,))
        args = (_sds(state_abs, state_shard), _sds(batch_abs, b_shard))
        lowered = jitted.lower(*args)
        entry = "train_step"

    elif shape.kind == "prefill":
        pf = serve_lib.make_prefill_step(cfg)
        params_abs = model_lib.abstract_params(cfg)
        p_spec = plans.param_specs(params_abs, mesh, axes)
        p_shard = plans.to_shardings(p_spec, mesh)
        batch_abs = pipeline.input_specs(cfg, shape)
        b_shard = plans.to_shardings(
            plans.batch_specs(batch_abs, mesh, axes), mesh)
        cache_abs = serve_lib.abstract_cache(cfg, shape.global_batch,
                                             shape.seq_len)
        if cache_abs is None:   # encoder: "prefill" = full encode, no cache
            def fn(params, batch):
                with shard_ctx.use(ctx):
                    x = model_lib.embed_inputs(params, cfg, batch)
                    logits, _, _ = model_lib.forward(
                        params, cfg, x, positions=jnp.arange(x.shape[1]))
                    return logits[:, -1]
            jitted = jax.jit(fn, in_shardings=(p_shard, b_shard))
            lowered = jitted.lower(_sds(params_abs, p_shard),
                                   _sds(batch_abs, b_shard))
        else:
            c_shard = plans.to_shardings(
                plans.cache_specs(cache_abs, cfg, mesh, axes,
                                  batch_size=shape.global_batch), mesh)

            def fn(params, batch, cache):
                with shard_ctx.use(ctx):
                    return pf(params, batch, cache)
            jitted = jax.jit(fn, in_shardings=(p_shard, b_shard, c_shard),
                             donate_argnums=(2,))
            lowered = jitted.lower(_sds(params_abs, p_shard),
                                   _sds(batch_abs, b_shard),
                                   _sds(cache_abs, c_shard))
        entry = "prefill_step"

    else:  # decode
        dec = serve_lib.make_decode_step(cfg)
        params_abs = model_lib.abstract_params(cfg)
        p_spec = plans.param_specs(params_abs, mesh, axes)
        p_shard = plans.to_shardings(p_spec, mesh)
        B = shape.global_batch
        cache_abs = serve_lib.abstract_cache(cfg, B, shape.seq_len)
        c_shard = plans.to_shardings(
            plans.cache_specs(cache_abs, cfg, mesh, axes, batch_size=B), mesh)
        dp_size = int(np.prod([mesh.shape[a] for a in axes.dp]))
        tok_spec = P(axes.dp if len(axes.dp) > 1 else axes.dp[0], None) \
            if B % dp_size == 0 else P(None, None)
        tok_shard = NamedSharding(mesh, tok_spec)
        len_shard = NamedSharding(mesh, P())

        def fn(params, token, cache, cache_len):
            with shard_ctx.use(ctx):
                return dec(params, token, cache, cache_len)

        jitted = jax.jit(fn, in_shardings=(p_shard, tok_shard, c_shard,
                                           len_shard),
                         donate_argnums=(2,))
        lowered = jitted.lower(
            _sds(params_abs, p_shard),
            jax.ShapeDtypeStruct((B, 1), jnp.int32, sharding=tok_shard),
            _sds(cache_abs, c_shard),
            jax.ShapeDtypeStruct((), jnp.int32, sharding=len_shard))
        entry = "decode_step"

    meta = {
        "arch": arch, "shape": shape_name, "entry": entry,
        "mesh": "2x16x16(pod,data,model)" if multi_pod else "16x16(data,model)",
        "n_chips": int(np.prod(list(mesh.shape.values()))),
        "model_flops": _model_flops(cfg, shape),
        "microbatch": shape.microbatch if shape.kind == "train" else None,
    }
    return lowered, meta


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             microbatch: Optional[int] = None) -> Dict[str, Any]:
    status = configs.cell_status(arch, shape_name)
    base = {"arch": arch, "shape": shape_name,
            "mesh": "multi" if multi_pod else "single", "status": status}
    if status != "run":
        return base
    t0 = time.time()
    lowered, meta = lower_cell(arch, shape_name, multi_pod=multi_pod,
                               microbatch=microbatch)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    roof = hlo_analysis.analyze(compiled, n_chips=meta["n_chips"],
                                model_flops=meta["model_flops"])
    mem_report = {}
    try:
        mem = compiled.memory_analysis()
        mem_report = {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
            "peak_bytes_per_device": int(mem.argument_size_in_bytes
                                         + mem.output_size_in_bytes
                                         + mem.temp_size_in_bytes
                                         - mem.alias_size_in_bytes),
        }
    except Exception as e:  # pragma: no cover
        mem_report = {"error": str(e)}
    base.update(meta)
    base.update({
        "status": "ok",
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": mem_report,
        "roofline": roof.to_dict(),
    })
    return base


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--out", default=None, help="append JSONL here")
    args = ap.parse_args(argv)

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    cells = []
    if args.all:
        for a, s, _ in configs.all_cells():
            cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        cells.append((configs.canonical(args.arch), args.shape))

    rc = 0
    for arch, shape_name in cells:
        for mp in meshes:
            try:
                res = run_cell(arch, shape_name, multi_pod=mp,
                               microbatch=args.microbatch)
            except Exception as e:
                res = {"arch": arch, "shape": shape_name,
                       "mesh": "multi" if mp else "single",
                       "status": f"FAIL: {type(e).__name__}: {e}"}
                rc = 1
            line = json.dumps(res)
            print(line, flush=True)
            if args.out:
                os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
                with open(args.out, "a") as f:
                    f.write(line + "\n")
    return rc


if __name__ == "__main__":
    sys.exit(main())
