"""Optimized-HLO text analyzer with correct while-loop trip-count expansion.

XLA's built-in ``cost_analysis()`` counts each ``while`` body ONCE, which
under-counts scanned programs (layer scans, microbatch scans, chunked
attention) by orders of magnitude.  This walker parses the compiled HLO
text, reads ``known_trip_count`` from each while's backend_config, and
accumulates:

  flops            — dot/convolution (2*M*N*K-style) + 1/elem for elementwise
  hbm_bytes        — per *top-level kernel* (fusion boundary): operands + result
  collective_bytes — operand bytes of all-gather/all-reduce/reduce-scatter/
                     all-to-all/collective-permute, by kind and total
all multiplied by the product of enclosing trip counts.  Numbers are for the
per-device (partitioned) program.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute", "collective-broadcast")

_ELEMWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "exponential-minus-one", "log", "log-plus-one",
    "tanh", "rsqrt", "sqrt", "power", "cosine", "sine", "logistic",
    "select", "compare", "and", "or", "xor", "not", "floor", "ceil",
    "round-nearest-even", "sign", "clamp", "erf", "atan2", "remainder",
}

# "%name = TYPE opcode(operands), attrs"   (TYPE may be a tuple containing
# /*index=N*/ comments, so it is brace-matched, not regexed)
_LINE_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_OPCODE_RE = re.compile(r"\s*([\w\-]+)\(")
_SHAPE_RE = re.compile(r"\b([a-z]\w*)\[([\d,]*)\]")
_SCALAR_INT_CONST_RE = re.compile(r"[su]32\[\]\s+constant\((\d+)\)")
_CALL_ATTR_RE = re.compile(r"(?:calls|body)=%([\w.\-]+)")
_COND_ATTR_RE = re.compile(r"condition=%([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*?)\)\s*->")
_DIMS_ATTR = re.compile(r"(\w+_contracting_dims)=\{([\d,]*)\}")
_BATCH_ATTR = re.compile(r"(\w+_batch_dims)=\{([\d,]*)\}")


def _parse_shape(dtype: str, dims: str) -> Tuple[str, Tuple[int, ...]]:
    return dtype, tuple(int(d) for d in dims.split(",") if d)


def _shape_bytes(shapes: List[Tuple[str, Tuple[int, ...]]]) -> int:
    tot = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        tot += n * _DTYPE_BYTES.get(dt, 4)
    return tot


def _numel(dims: Tuple[int, ...]) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


@dataclasses.dataclass
class Instr:
    name: str
    shapes: List[Tuple[str, Tuple[int, ...]]]   # result shapes (tuple-expanded)
    opcode: str
    operands: List[str]
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: Dict[str, Instr]
    order: List[str]
    is_entry: bool


def _split_operands(s: str) -> List[str]:
    """Operand names from the call-paren region of an instruction line."""
    depth = 0
    out = []
    # operands region terminates at the matching ')' of the opcode '('
    buf = ""
    for ch in s:
        if ch == "(":
            depth += 1
            buf += ch
        elif ch == ")":
            if depth == 0:
                break
            depth -= 1
            buf += ch
        else:
            buf += ch
    for part in buf.split(","):
        part = part.strip()
        m = re.search(r"%([\w.\-]+)\s*$", part)
        if m:
            out.append(m.group(1))
    return out


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            if line.endswith("{") and ("->" in line):
                m = _COMP_HDR_RE.match(line.strip())
                if m:
                    cur = Computation(name=m.group(1), instrs={}, order=[],
                                      is_entry=line.startswith("ENTRY"))
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _LINE_RE.match(line)
        if not m:
            continue
        name, rest = m.groups()
        rest = rest.lstrip()
        # split "TYPE opcode(operands...)": TYPE may be a paren tuple with
        # embedded /*index=N*/ comments -> brace-match it.
        if rest.startswith("("):
            depth = 0
            end = 0
            for i, ch in enumerate(rest):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        end = i + 1
                        break
            type_str, after = rest[:end], rest[end:]
        else:
            sp = rest.find(" ")
            if sp < 0:
                continue
            type_str, after = rest[:sp], rest[sp:]
        mo = _OPCODE_RE.match(after)
        if not mo:
            continue
        opcode = mo.group(1)
        shapes = [_parse_shape(dt, dm) for dt, dm in _SHAPE_RE.findall(type_str)]
        operands = _split_operands(after[mo.end():])
        cur.instrs[name] = Instr(name=name, shapes=shapes, opcode=opcode,
                                 operands=operands, line=line)
        cur.order.append(name)
    return comps


_FREE_OPS = {"parameter", "tuple", "get-tuple-element", "bitcast", "constant",
             "iota", "after-all", "partition-id", "replica-id"}


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_counts: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, other: "Costs", mult: float = 1.0):
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] = self.coll_bytes.get(k, 0.0) + v * mult
        for k, v in other.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0.0) + v * mult

    @property
    def total_coll_bytes(self) -> float:
        return sum(self.coll_bytes.values())


class HloAnalyzer:
    def __init__(self, text: str):
        self.comps = parse_module(text)
        self.entry = next((c for c in self.comps.values() if c.is_entry), None)
        self._memo: Dict[Tuple[str, bool], Costs] = {}

    # ---------------------------------------------------------------- helpers
    def _operand_shapes(self, comp: Computation, instr: Instr):
        out = []
        for op in instr.operands:
            src = comp.instrs.get(op)
            if src is not None:
                out.extend(src.shapes)
        return out

    def _dot_flops(self, comp: Computation, instr: Instr) -> float:
        lhs = comp.instrs.get(instr.operands[0]) if instr.operands else None
        if lhs is None or not lhs.shapes:
            return 0.0
        lhs_dims = lhs.shapes[0][1]
        m = _DIMS_ATTR.findall(instr.line)
        lhs_c = []
        for key, idxs in m:
            if key.startswith("lhs"):
                lhs_c = [int(i) for i in idxs.split(",") if i]
        k = 1
        for i in lhs_c:
            if i < len(lhs_dims):
                k *= lhs_dims[i]
        out_elems = _numel(instr.shapes[0][1]) if instr.shapes else 0
        return 2.0 * out_elems * k

    def _conv_flops(self, comp: Computation, instr: Instr) -> float:
        # flops ~= 2 * out_elems * kernel_elems / out_channels
        rhs = comp.instrs.get(instr.operands[1]) if len(instr.operands) > 1 else None
        out_elems = _numel(instr.shapes[0][1]) if instr.shapes else 0
        k_elems = _numel(rhs.shapes[0][1]) if rhs and rhs.shapes else 1
        out_ch = instr.shapes[0][1][-1] if instr.shapes and instr.shapes[0][1] else 1
        return 2.0 * out_elems * k_elems / max(out_ch, 1)

    _PASSTHRU = {"parameter", "convert", "bitcast", "copy", "reshape",
                 "transpose", "tuple", "get-tuple-element"}

    def _is_dtype_artifact(self, callee: Optional[Computation]) -> bool:
        """Fusions containing only converts/copies/layout ops are XLA:CPU
        bf16->f32 promotion artifacts: TPU computes bf16 natively and these
        kernels do not exist in its lowering.  Charged zero."""
        if callee is None:
            return False
        return all(i.opcode in self._PASSTHRU
                   for i in callee.instrs.values())

    def _fusion_traffic(self, comp: Computation, instr: Instr,
                        called: Optional[str]) -> float:
        """HBM traffic of one fused kernel.

        Base model: operands + result.  Scan-critical refinements:
          * root = dynamic-update-slice: the big buffer is updated in place
            (XLA aliases it) — traffic is ~2x the update slice plus the other
            small operands, not the whole buffer per trip.
          * parameters consumed only by (dynamic-)slice ops: only the slice
            bytes move, not the whole source operand (scan xs indexing).
          * pure convert/copy fusions: zero (CPU dtype-promotion artifacts).
        """
        operand_shapes = []
        per_operand = []
        for opnd in instr.operands:
            src = comp.instrs.get(opnd)
            sh = src.shapes if src is not None else []
            per_operand.append(sh)
            operand_shapes.extend(sh)
        result_b = _shape_bytes(instr.shapes)
        callee = self.comps.get(called) if called else None
        if callee is None:
            return _shape_bytes(operand_shapes) + result_b
        if self._is_dtype_artifact(callee):
            return 0.0

        root_name = callee.order[-1] if callee.order else None
        root = callee.instrs.get(root_name) if root_name else None

        # map: parameter index -> set of consumer opcodes + slice result bytes
        param_names = {}
        for nm in callee.order:
            ins = callee.instrs[nm]
            if ins.opcode == "parameter":
                # "parameter(N)" — N from the line
                mnum = re.search(r"parameter\((\d+)\)", ins.line)
                if mnum:
                    param_names[nm] = int(mnum.group(1))
        # consumers of each instruction (to follow zero-cost bitcast chains)
        consumers_of: Dict[str, List[str]] = {}
        for nm in callee.order:
            for opnd in callee.instrs[nm].operands:
                consumers_of.setdefault(opnd, []).append(nm)

        def effective_consumers(nm: str, depth: int = 0) -> List[Instr]:
            out: List[Instr] = []
            if depth > 4:
                return out
            for cn in consumers_of.get(nm, []):
                ci = callee.instrs[cn]
                if ci.opcode == "bitcast":
                    out.extend(effective_consumers(cn, depth + 1))
                else:
                    out.append(ci)
            return out

        sliced_param_bytes: Dict[int, float] = {}
        param_consumers: Dict[str, List[str]] = {n: [] for n in param_names}
        for pname, pidx in param_names.items():
            for ci in effective_consumers(pname):
                param_consumers[pname].append(ci.opcode)
                if ci.opcode in ("dynamic-slice", "slice", "gather"):
                    sliced_param_bytes[pidx] = (
                        sliced_param_bytes.get(pidx, 0.0)
                        + _shape_bytes(ci.shapes))

        total = 0.0
        dus_inplace = root is not None and root.opcode == "dynamic-update-slice"
        for i, sh in enumerate(per_operand):
            b = _shape_bytes(sh)
            pname = [n for n, pi in param_names.items() if pi == i]
            consumers = param_consumers.get(pname[0], ["?"]) if pname else ["?"]
            if dus_inplace and sh and instr.shapes and sh == instr.shapes:
                continue  # aliased in-place buffer: charged via the update
            if pname and consumers and all(
                    c in ("dynamic-slice", "slice", "gather") for c in consumers):
                total += min(b, sliced_param_bytes.get(i, b))
            else:
                total += b
        if dus_inplace:
            upd = callee.instrs.get(root.operands[1]) if len(root.operands) > 1 else None
            upd_b = _shape_bytes(upd.shapes) if upd is not None else 0
            total += 2.0 * upd_b        # read-modify-write of the slice
        else:
            total += result_b
        return total

    def _while_trip(self, instr: Instr) -> int:
        """Trip count: backend_config known_trip_count, else the scalar int
        constant in the condition computation (jax scans: cond is `i < N`)."""
        mt = _TRIP_RE.search(instr.line)
        if mt:
            return int(mt.group(1))
        mc = _COND_ATTR_RE.search(instr.line)
        if mc:
            cond = self.comps.get(mc.group(1))
            if cond is not None:
                consts = []
                for nm in cond.order:
                    consts += [int(v) for v in
                               _SCALAR_INT_CONST_RE.findall(cond.instrs[nm].line)]
                if consts:
                    return max(consts)
        return 1

    # ------------------------------------------------------------------ walk
    def computation_costs(self, comp_name: str, top_level: bool) -> Costs:
        key = (comp_name, top_level)
        if key in self._memo:
            return self._memo[key]
        comp = self.comps.get(comp_name)
        costs = Costs()
        if comp is None:
            self._memo[key] = costs
            return costs
        for name in comp.order:
            instr = comp.instrs[name]
            op = instr.opcode
            if op == "while":
                trip = self._while_trip(instr)
                # scans marked "vmem_fused_*" are CPU stand-ins for Pallas
                # kernels whose intra-scan tiles live in VMEM scratch on TPU:
                # charge boundary traffic once, count flops/collectives fully
                fused = "vmem_fused" in instr.line
                mb = re.search(r"body=%([\w.\-]+)", instr.line)
                if mb:
                    costs.add(self.computation_costs(
                        mb.group(1), top_level and not fused), trip)
                if fused and top_level:
                    costs.hbm_bytes += (
                        _shape_bytes(self._operand_shapes(comp, instr))
                        + _shape_bytes(instr.shapes))
                continue
            if op in ("fusion", "call", "async-start"):
                mb = _CALL_ATTR_RE.search(instr.line)
                inner = (self.computation_costs(mb.group(1), False)
                         if mb else Costs())
                hbm = (self._fusion_traffic(comp, instr,
                                            mb.group(1) if mb else None)
                       if top_level else 0.0)
                kernel = Costs(flops=inner.flops, hbm_bytes=hbm,
                               coll_bytes=dict(inner.coll_bytes),
                               coll_counts=dict(inner.coll_counts))
                costs.add(kernel)
                continue
            if op == "conditional":
                # take the max-cost branch (upper bound)
                branches = re.findall(r"%([\w.\-]+)", instr.line)
                # heuristics: branch computations referenced via
                # true_computation=/false_computation=/branch_computations=
                bs = re.findall(r"computations?=\{?%?([\w.\-]+)", instr.line)
                best = Costs()
                for b in bs:
                    c = self.computation_costs(b, True)
                    if c.flops >= best.flops:
                        best = c
                costs.add(best)
                continue
            kind = op.replace("-start", "") if op.endswith("-start") else op
            if kind in _COLL_KINDS:
                b = _shape_bytes(self._operand_shapes(comp, instr))
                costs.coll_bytes[kind] = costs.coll_bytes.get(kind, 0.0) + b
                costs.coll_counts[kind] = costs.coll_counts.get(kind, 0.0) + 1
                if top_level:
                    costs.hbm_bytes += b + _shape_bytes(instr.shapes)
                continue
            if op in _FREE_OPS or op.endswith("-done") or op.endswith("-update"):
                continue
            # compute flops
            if op == "dot":
                costs.flops += self._dot_flops(comp, instr)
            elif op == "convolution":
                costs.flops += self._conv_flops(comp, instr)
            elif op in ("reduce", "reduce-window"):
                costs.flops += float(sum(_numel(s[1]) for s in
                                         self._operand_shapes(comp, instr)))
            elif op in _ELEMWISE:
                costs.flops += float(_numel(instr.shapes[0][1])
                                     if instr.shapes else 0)
            # memory: only top-level kernels touch HBM
            if top_level:
                if op in ("copy", "convert"):
                    continue  # CPU dtype-promotion / layout artifacts
                if op == "dynamic-update-slice":
                    upd = (comp.instrs.get(instr.operands[1])
                           if len(instr.operands) > 1 else None)
                    costs.hbm_bytes += 2.0 * (_shape_bytes(upd.shapes)
                                              if upd else 0)
                elif op in ("dynamic-slice", "slice", "gather"):
                    costs.hbm_bytes += 2.0 * _shape_bytes(instr.shapes)
                else:
                    costs.hbm_bytes += (
                        _shape_bytes(self._operand_shapes(comp, instr))
                        + _shape_bytes(instr.shapes))
        self._memo[key] = costs
        return costs

    def analyze(self) -> Costs:
        if self.entry is None:
            return Costs()
        return self.computation_costs(self.entry.name, True)


def analyze_text(text: str) -> Costs:
    return HloAnalyzer(text).analyze()
