"""deepseek-v2-236b [moe] — 60L d_model=5120 128H, MLA kv_lora=512
(q_lora=1536, qk_rope=64), d_ff_expert=1536, vocab=102400,
MoE 2 shared + 160 routed top-6.  [arXiv:2405.04434; hf]
"""
from repro.models.config import AttentionConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek_v2_236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    vocab_size=102_400,
    d_ff=0,                         # every layer MoE (first-layer-dense of the
                                    # HF release folded into MoE; see DESIGN.md)
    attention=AttentionConfig(n_heads=128, n_kv_heads=128, head_dim=128,
                              rope_theta=10_000.0,
                              q_lora_rank=1536, kv_lora_rank=512,
                              qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=160, top_k=6, d_ff_expert=1536, n_shared=2),
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek_v2_236b_smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        vocab_size=256,
        d_ff=0,
        attention=AttentionConfig(n_heads=4, n_kv_heads=4, head_dim=16,
                                  q_lora_rank=32, kv_lora_rank=16,
                                  qk_rope_head_dim=8, v_head_dim=16),
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=64, n_shared=2),
    )
