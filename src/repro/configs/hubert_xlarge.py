"""hubert-xlarge [audio] — 48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504 —
encoder-only (wav2vec2 architecture), masked cluster prediction.
The conv waveform frontend is a STUB per the assignment: ``input_specs()``
delivers precomputed frame embeddings (T x 1280).  [arXiv:2106.07447; unverified]
"""
from repro.models.config import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="hubert_xlarge",
    family="encoder",
    n_layers=48,
    d_model=1280,
    vocab_size=504,
    d_ff=5120,
    attention=AttentionConfig(n_heads=16, n_kv_heads=16, head_dim=80,
                              causal=False),
    norm="layer",
    act="gelu",
    mlp_gated=False,
    frontend="frame",
    frontend_dim=1280,
    is_encoder=True,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="hubert_xlarge_smoke",
        family="encoder",
        n_layers=3,
        d_model=64,
        vocab_size=32,
        d_ff=128,
        attention=AttentionConfig(n_heads=4, n_kv_heads=4, head_dim=16,
                                  causal=False),
        norm="layer",
        act="gelu",
        mlp_gated=False,
        frontend="frame",
        frontend_dim=64,
        is_encoder=True,
    )
