"""pixtral-12b [vlm] — mistral-nemo-12b text backbone (40L d_model=5120 32H
GQA kv=8 d_ff=14336 vocab=131072) + pixtral-ViT patch frontend.
The vision tower is a STUB per the assignment: ``input_specs()`` delivers
precomputed patch embeddings (n_patches x 1024) which a learned projection
maps into the backbone.  [hf:mistralai/Pixtral-12B-2409; unverified]
"""
from repro.models.config import AttentionConfig, ModelConfig

N_PATCHES = 256          # image tokens occupying the sequence prefix
PATCH_DIM = 1024         # pixtral ViT hidden size delivered by the stub

CONFIG = ModelConfig(
    name="pixtral_12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    vocab_size=131_072,
    d_ff=14_336,
    attention=AttentionConfig(n_heads=32, n_kv_heads=8, head_dim=128,
                              rope_theta=1_000_000.0),
    frontend="patch",
    frontend_dim=PATCH_DIM,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="pixtral_12b_smoke",
        family="vlm",
        n_layers=3,
        d_model=64,
        vocab_size=256,
        d_ff=192,
        attention=AttentionConfig(n_heads=4, n_kv_heads=2, head_dim=16),
        frontend="patch",
        frontend_dim=32,
    )
