"""llama4-maverick-400b-a17b [moe] — 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048, MoE 128 experts top-1 + 1 shared, alternating
dense/MoE layers (interleave=2, Maverick layout).
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
"""
from repro.models.config import AttentionConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4_maverick_400b",
    family="moe",
    n_layers=48,
    d_model=5120,
    vocab_size=202_048,
    d_ff=8192,                      # dense (non-MoE) layers' MLP width
    attention=AttentionConfig(n_heads=40, n_kv_heads=8, head_dim=128,
                              rope_theta=500_000.0),
    moe=MoEConfig(n_experts=128, top_k=1, d_ff_expert=8192, n_shared=1),
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama4_maverick_400b_smoke",
        family="moe",
        n_layers=4,
        d_model=64,
        vocab_size=256,
        d_ff=128,
        attention=AttentionConfig(n_heads=4, n_kv_heads=2, head_dim=16),
        moe=MoEConfig(n_experts=8, top_k=1, d_ff_expert=128, n_shared=1),
    )
