"""yi-34b [dense] — 60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000 —
llama architecture with GQA.  [arXiv:2403.04652; hf]
"""
from repro.models.config import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="yi_34b",
    family="dense",
    n_layers=60,
    d_model=7168,
    vocab_size=64_000,
    d_ff=20_480,
    attention=AttentionConfig(n_heads=56, n_kv_heads=8, head_dim=128,
                              rope_theta=5_000_000.0),
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="yi_34b_smoke",
        family="dense",
        n_layers=3,
        d_model=64,
        vocab_size=256,
        d_ff=192,
        attention=AttentionConfig(n_heads=4, n_kv_heads=2, head_dim=16),
    )
