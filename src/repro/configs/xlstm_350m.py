"""xlstm-350m [ssm] — 24L d_model=1024 4H vocab=50304, sLSTM + mLSTM blocks
(xLSTM[7:1]: one sLSTM per 8 blocks).  d_ff=0 (blocks carry their own
projections).  [arXiv:2405.04517; unverified]
"""
from repro.models.config import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm_350m",
    family="xlstm",
    n_layers=24,
    d_model=1024,
    vocab_size=50_304,
    d_ff=0,
    xlstm=XLSTMConfig(n_heads=4, proj_factor=2.0, qk_factor=0.5,
                      slstm_every=8, chunk=256),
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="xlstm_350m_smoke",
        family="xlstm",
        n_layers=4,
        d_model=64,
        vocab_size=256,
        d_ff=0,
        xlstm=XLSTMConfig(n_heads=2, proj_factor=2.0, qk_factor=0.5,
                          slstm_every=2, chunk=16),
        tie_embeddings=True,
    )
