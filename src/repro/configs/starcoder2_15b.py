"""starcoder2-15b [dense] — 40L d_model=6144 48H (GQA kv=4) d_ff=24576
vocab=49152 — GQA, RoPE, LayerNorm, non-gated GELU MLP.
[arXiv:2402.19173; hf]
"""
from repro.models.config import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="starcoder2_15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    vocab_size=49_152,
    d_ff=24_576,
    attention=AttentionConfig(n_heads=48, n_kv_heads=4, head_dim=128,
                              rope_theta=100_000.0),
    norm="layer",
    act="gelu",
    mlp_gated=False,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2_15b_smoke",
        family="dense",
        n_layers=3,
        d_model=96,
        vocab_size=256,
        d_ff=384,
        attention=AttentionConfig(n_heads=6, n_kv_heads=2, head_dim=16),
        norm="layer",
        act="gelu",
        mlp_gated=False,
    )
