"""Architecture config registry.

Every assigned architecture is a module exporting ``CONFIG`` (full size) and
``smoke_config()`` (reduced same-family config for CPU tests).  Select with
``repro.configs.get(name)`` or ``--arch <id>`` on the launchers.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig, SHAPES_BY_NAME, ShapeConfig

ARCH_IDS: List[str] = [
    "llama4_maverick_400b",
    "deepseek_v2_236b",
    "xlstm_350m",
    "starcoder2_15b",
    "deepseek_7b",
    "mistral_nemo_12b",
    "yi_34b",
    "pixtral_12b",
    "hubert_xlarge",
    "zamba2_2p7b",
]

_ALIASES = {
    "llama4-maverick-400b-a17b": "llama4_maverick_400b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "xlstm-350m": "xlstm_350m",
    "starcoder2-15b": "starcoder2_15b",
    "deepseek-7b": "deepseek_7b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "yi-34b": "yi_34b",
    "pixtral-12b": "pixtral_12b",
    "hubert-xlarge": "hubert_xlarge",
    "zamba2-2.7b": "zamba2_2p7b",
}


def canonical(name: str) -> str:
    name = _ALIASES.get(name, name).replace("-", "_")
    if name not in ARCH_IDS:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_IDS}")
    return name


def get(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.CONFIG


def get_smoke(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.smoke_config()


def shape(name: str) -> ShapeConfig:
    return SHAPES_BY_NAME[name]


# --- assigned-cell table: which (arch, shape) cells execute vs. skip -------

def cell_status(arch: str, shape_name: str) -> str:
    """'run' or a skip reason (documented in DESIGN.md §Arch-applicability)."""
    arch = canonical(arch)
    cfg = get(arch)
    if shape_name in ("decode_32k", "long_500k") and cfg.is_encoder:
        return "skip: encoder-only arch has no autoregressive decode"
    if shape_name == "long_500k" and cfg.family not in ("xlstm", "hybrid"):
        return "skip: full-attention arch; 500k ctx needs sub-quadratic mixing"
    return "run"


def all_cells():
    """Yield (arch, shape_name, status) for the full 40-cell assignment."""
    for a in ARCH_IDS:
        for s in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
            yield a, s, cell_status(a, s)
