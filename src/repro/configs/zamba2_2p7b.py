"""zamba2-2.7b [hybrid] — 54L d_model=2560 ssm_state=64, Mamba2 backbone with
a weight-SHARED attention(32H kv=32)+MLP(d_ff=10240) block applied once per
group of 5 Mamba2 blocks (9 applications, one parameter set — Zamba2's
shared-block design).  vocab=32000.  [arXiv:2411.15242; hf]
"""
from repro.models.config import (AttentionConfig, HybridConfig, ModelConfig,
                                 SSMConfig)

CONFIG = ModelConfig(
    name="zamba2_2p7b",
    family="hybrid",
    n_layers=54,                   # 54 = 9 groups x (5 mamba + 1 shared attn)
    d_model=2560,
    vocab_size=32_000,
    d_ff=10_240,                   # shared block MLP width
    attention=AttentionConfig(n_heads=32, n_kv_heads=32, head_dim=80,
                              rope_theta=10_000.0),
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, chunk=256),
    hybrid=HybridConfig(mamba_per_group=5),
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2_2p7b_smoke",
        family="hybrid",
        n_layers=6,
        d_model=64,
        vocab_size=256,
        d_ff=128,
        attention=AttentionConfig(n_heads=4, n_kv_heads=4, head_dim=16),
        ssm=SSMConfig(state_dim=16, head_dim=16, expand=2, chunk=16),
        hybrid=HybridConfig(mamba_per_group=2),
        tie_embeddings=True,
    )
