"""mistral-nemo-12b [dense] — 40L d_model=5120 32H (GQA kv=8, head_dim=128)
d_ff=14336 vocab=131072 — 128k ctx (rope_theta=1e6).
[hf:mistralai/Mistral-Nemo-Base-2407; hf]
"""
from repro.models.config import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="mistral_nemo_12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    vocab_size=131_072,
    d_ff=14_336,
    attention=AttentionConfig(n_heads=32, n_kv_heads=8, head_dim=128,
                              rope_theta=1_000_000.0),
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mistral_nemo_12b_smoke",
        family="dense",
        n_layers=3,
        d_model=64,
        vocab_size=256,
        d_ff=192,
        attention=AttentionConfig(n_heads=4, n_kv_heads=2, head_dim=16),
    )
