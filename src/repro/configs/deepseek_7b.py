"""deepseek-7b [dense] — 30L d_model=4096 32H (MHA kv=32) d_ff=11008
vocab=102400 — llama architecture.  [arXiv:2401.02954; hf]
"""
from repro.models.config import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="deepseek_7b",
    family="dense",
    n_layers=30,
    d_model=4096,
    vocab_size=102_400,
    d_ff=11_008,
    attention=AttentionConfig(n_heads=32, n_kv_heads=32, head_dim=128,
                              rope_theta=10_000.0),
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek_7b_smoke",
        family="dense",
        n_layers=3,
        d_model=64,
        vocab_size=256,
        d_ff=192,
        attention=AttentionConfig(n_heads=4, n_kv_heads=4, head_dim=16),
    )
