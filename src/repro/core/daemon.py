"""ClusterDaemon — the event-driven service layer over the controller.

The paper runs per-user engine daemons under one always-on control plane;
its companion papers (*Web-based Interface in Public Cluster*,
arXiv:0711.0528; *openPC*, arXiv:1012.2499) put a web front-end on top.
This module is that split's server half: a ``ClusterDaemon`` owns the
``ClusterController`` (and through it the partitioner, registry, monitor,
scheduler and event bus) and is the only thing callers talk to — the web
gateway, the launch drivers and the examples all go through it; nothing
outside ``repro.core`` constructs a controller directly.

Two execution modes, one API:

* **Background (service) mode** — ``background=True`` starts a pump
  thread.  Every mutating call from any thread is wrapped in a typed
  ``Command`` and enqueued; the pump executes commands strictly one at a
  time and, between commands, drives the periodic ``tick()`` (auto-expiry,
  waitlist admission, auto-resume, heartbeat health decay, utilization
  sampling) that callers had to drive by hand before.  Engine rounds run
  on one worker thread per live federation pod (``run_round(pod=...)``),
  so a slow pod's harvest never stalls another pod's pump — but every
  round still takes the same daemon lock, so federation adds threads
  without adding interleavings.  Serializing all mutations through one
  lock is what makes a multi-user HTTP gateway safe to point at the
  controller without sprinkling locks through the scheduler.

* **Deterministic single-thread mode** — the default.  Calls execute
  inline on the caller's thread (still serialized by a reentrant lock) and
  ``tick()`` only runs when invoked, so tests and benchmarks see the exact
  pre-daemon semantics, model-time ``now=`` plumbing included.

Reads (status, reports, event history) bypass the command queue — they
touch thread-safe structures (registry lock, monitor lock, event bus) and
must not queue behind a long-running step command.
"""
from __future__ import annotations

import dataclasses
import os
import queue
import threading
import time
import traceback
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.analysis import runtime_check
from repro.core.controller import ClusterController
from repro.core.events import BlockEvent, EventBus
from repro.core.topology import Topology
from repro.engine import AutostepEngine, PacingPolicy
from repro.federation.pods import POD_DEAD
from repro.obs.bridge import wire_bus
from repro.obs.flight import RECORDER
from repro.obs.metrics import REGISTRY
from repro.obs.trace import TRACER


@dataclasses.dataclass
class Command:
    """One serialized mutation: a named controller operation plus its
    arguments, with a completion event the submitting thread waits on."""
    name: str
    args: Tuple = ()
    kwargs: Dict = dataclasses.field(default_factory=dict)
    result: Any = None
    error: Optional[BaseException] = None
    claimed: bool = False     # pump took it (or the submitter gave up)
    done: threading.Event = dataclasses.field(
        default_factory=threading.Event)
    # trace context captured at enqueue: the pump parents the queue-wait
    # and exec spans back to the submitting request (None = tracing off)
    ctx: Optional[Tuple[str, str]] = None
    t_enq: float = 0.0        # perf_counter at enqueue (tracing on only)
    # request correlation id captured at enqueue: the pump stamps it on
    # the exec span so events published there carry it too (the id would
    # otherwise be stranded on the submitting thread's span stack)
    rid: Optional[str] = None


class ClusterDaemon:
    #: names accepted by ``call`` — the typed command surface.  Everything
    #: the gateway or a driver may mutate goes through exactly these.
    COMMANDS = (
        "register", "submit", "submit_gang", "review", "confirm",
        "activate", "run", "run_steps", "step_all", "download", "expire",
        "preempt", "resume", "resize", "tick", "inject_chip_failure",
        "save", "restore", "set_quota",
        "autostep_enable", "autostep_disable", "autostep_pace",
        "autostep_round", "generate",
        "attach_pod", "drain_pod", "detach_pod", "fail_pod",
        "pod_heartbeat",
    )

    def __init__(self, topo: Topology, devices: Optional[Sequence] = None,
                 ckpt_root: str = "artifacts/ckpt",
                 state_path: Optional[str] = None,
                 background: bool = False,
                 tick_interval_s: float = 0.05,
                 autostep_interval_s: float = 0.001,
                 pacing: Optional[PacingPolicy] = None,
                 placer=None, trace: bool = False):
        self.ctl = ClusterController(topo, devices=devices,
                                     ckpt_root=ckpt_root,
                                     state_path=state_path,
                                     placer=placer)
        # observability: the metrics bridge and flight recorder are
        # passive bus subscribers (always on — they mutate nothing in the
        # control plane); tracing stays opt-in so deterministic inline
        # mode is bit-identical by default
        if trace:
            TRACER.enable()
        wire_bus(self.ctl.bus)
        RECORDER.configure(
            dir=os.path.join(ckpt_root, "postmortems")).install(self.ctl.bus)
        # the autostep engine drives RUNNING blocks from the pump thread
        # (or inline via autostep_round); the controller drains a victim's
        # in-flight window through it before a preemption suspend
        self.engine = AutostepEngine(self.ctl, policy=pacing)
        self.ctl.engine = self.engine
        self.autostep_interval_s = autostep_interval_s
        self._engine_error_logged = False   # first engine error traceback
        self._serial = threading.RLock()      # inline-mode serialization
        self._cmds: "queue.Queue[Command]" = queue.Queue()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.tick_interval_s = tick_interval_s
        ctl = self.ctl
        self._table: Dict[str, Callable] = {
            "register": ctl.register,
            "submit": ctl.submit,
            "submit_gang": ctl.submit_gang,
            "review": ctl.review,
            "confirm": ctl.confirm,
            "activate": ctl.activate,
            "run": ctl.run,
            "run_steps": self._run_steps,
            "step_all": ctl.step_all,
            "download": ctl.download,
            "expire": ctl.expire,
            "preempt": ctl.preempt,
            "resume": ctl.resume,
            "resize": ctl.resize_block,
            "tick": ctl.tick,
            "inject_chip_failure": ctl.inject_chip_failure,
            "save": self._save,
            "restore": self._restore,
            "set_quota": ctl.scheduler.policy.set_quota,
            "autostep_enable": self.engine.enable,
            "autostep_disable": self.engine.disable,
            "autostep_pace": self.engine.set_pace,
            "autostep_round": self.engine.run_round,
            "generate": self._generate,
            "attach_pod": ctl.attach_pod,
            "drain_pod": ctl.drain_pod,
            "detach_pod": ctl.detach_pod,
            "fail_pod": ctl.fail_pod,
            "pod_heartbeat": ctl.pod_heartbeat,
        }
        #: per-pod engine worker threads (background mode): pod_id ->
        #: thread.  Only the pump thread mutates this dict.
        self._pod_workers: Dict[int, threading.Thread] = {}
        if background:
            self.start()

    # ------------------------------------------------------------ lifecycle
    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def start(self) -> "ClusterDaemon":
        """Enter background (service) mode: start the pump thread."""
        if self.running:
            return self
        self._stop.clear()
        self._thread = threading.Thread(target=self._pump_loop,
                                        name="cluster-daemon", daemon=True)
        self._thread.start()
        return self

    def stop(self, timeout: float = 10.0) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join(timeout)
        self._thread = None
        for th in list(self._pod_workers.values()):
            th.join(timeout)
        self._pod_workers.clear()
        # fail queued commands instead of leaving their submitters hanging
        while True:
            try:
                cmd = self._cmds.get_nowait()
            except queue.Empty:
                break
            cmd.error = RuntimeError("daemon stopped")
            cmd.done.set()

    def __enter__(self) -> "ClusterDaemon":
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

    def _pump_loop(self) -> None:
        last_tick = time.monotonic()
        while not self._stop.is_set():
            # federation: one engine worker per live pod drives that pod's
            # residents at the autostep cadence; the pump itself only
            # serves commands and the periodic tick, so a slow pod's
            # rounds never stall another pod (or command latency)
            self._sync_pod_workers()
            try:
                cmd = self._cmds.get(timeout=self.tick_interval_s)
            except queue.Empty:
                cmd = None
            if cmd is not None:
                with self._serial:
                    if cmd.claimed or cmd.done.is_set():
                        continue     # submitter already gave up on it
                    cmd.claimed = True
                    t_claim = time.perf_counter()
                    if TRACER.enabled and cmd.t_enq:
                        # queue-wait and exec tile the caller's call span:
                        # the exec span starts at the exact instant the
                        # queue span ends
                        TRACER.record(f"daemon.queue:{cmd.name}",
                                      cmd.t_enq, t_claim, cat="daemon",
                                      ctx=cmd.ctx)
                    rid_arg = ({"request_id": cmd.rid} if cmd.rid else {})
                    try:
                        with TRACER.span(f"daemon.exec:{cmd.name}",
                                         cat="daemon", ctx=cmd.ctx,
                                         t0=t_claim, **rid_arg):
                            with runtime_check.serialized("control-plane"):
                                cmd.result = self._table[cmd.name](
                                    *cmd.args, **cmd.kwargs)
                    except BaseException as e:   # delivered to the caller
                        cmd.error = e
                    dt = time.perf_counter() - t_claim
                    REGISTRY.observe("repro_daemon_command_seconds", dt,
                                     labels={"name": cmd.name})
                cmd.done.set()
            if time.monotonic() - last_tick >= self.tick_interval_s:
                with self._serial:
                    t0 = time.perf_counter()
                    try:
                        self.ctl.tick()
                    except Exception:
                        # a tick must never kill the service loop — but a
                        # crashing control plane is exactly what the
                        # flight recorder exists to explain
                        RECORDER.dump("daemon_crash",
                                      detail={"where": "tick",
                                              "error":
                                              traceback.format_exc()})
                    dt = time.perf_counter() - t0
                    REGISTRY.observe("repro_pump_tick_seconds", dt)
                    REGISTRY.sample("pump_tick_ms", dt * 1e3)
                last_tick = time.monotonic()

    def _sync_pod_workers(self) -> None:
        """Keep one engine worker thread alive per live pod (pump thread
        only — the dict has a single writer by construction).  Workers
        exit on their own when their pod dies or detaches; dead threads
        are reaped here so a re-attached pod id gets a fresh worker."""
        for pid in list(self._pod_workers):
            if not self._pod_workers[pid].is_alive():
                del self._pod_workers[pid]
        for p in self.ctl.pods.live():
            if p.pod_id not in self._pod_workers:
                th = threading.Thread(target=self._pod_worker,
                                      args=(p.pod_id,),
                                      name=f"pod-worker-{p.pod_id}",
                                      daemon=True)
                self._pod_workers[p.pod_id] = th
                th.start()

    def _pod_worker(self, pod_id: int) -> None:
        """Per-pod engine pump: drives ``run_round(pod=pod_id)`` for this
        pod's residents while the pod is alive.  Rounds are serialized
        with every other mutation via the daemon lock, so federation adds
        threads without adding interleavings — it changes *who* pumps,
        not what can overlap."""
        while not self._stop.is_set():
            pod = self.ctl.pods.get(pod_id)
            if pod is None or pod.phase == POD_DEAD:
                return               # detached/dead: the worker retires
            busy = False
            if self.engine.armed:
                with self._serial:
                    try:
                        self.engine.run_round(pod=pod_id)
                        busy = self.engine.last_round_busy
                    except Exception:
                        # an engine bug must not kill the worker — but it
                        # must not busy-spin or fail silently either
                        self.engine.last_round_busy = False
                        if not self._engine_error_logged:
                            self._engine_error_logged = True
                            traceback.print_exc()
            self._stop.wait(self.autostep_interval_s if busy
                            else self.tick_interval_s)

    # -------------------------------------------------------------- command
    def call(self, name: str, *args, **kwargs):
        """Execute one typed command.  Background mode enqueues and waits
        (mutations run strictly serialized on the pump thread);
        deterministic mode runs inline on the caller's thread.  Calls
        *from* the pump thread itself (an event subscriber reacting to a
        command) run inline too — enqueueing would deadlock."""
        if name not in self._table:
            raise ValueError(f"unknown daemon command {name!r}")
        if not self.running or threading.current_thread() is self._thread:
            with TRACER.span(f"daemon.call:{name}", cat="daemon"):
                with self._serial:
                    with runtime_check.serialized("control-plane"):
                        return self._table[name](*args, **kwargs)
        with TRACER.span(f"daemon.call:{name}", cat="daemon"):
            cmd = Command(name=name, args=args, kwargs=kwargs)
            if TRACER.enabled:
                cmd.ctx = TRACER.context()
                cmd.rid = TRACER.current_request_id()
                cmd.t_enq = time.perf_counter()
            self._cmds.put(cmd)
            # bounded waits: a stop() racing this enqueue (queue drained
            # just before our put) would otherwise leave the caller parked
            # forever on a command no thread will ever serve
            while not cmd.done.wait(0.2):
                if not self.running:
                    with self._serial:
                        if not cmd.claimed and not cmd.done.is_set():
                            # orphaned by the race: run it inline (a later
                            # start() skips claimed commands)
                            cmd.claimed = True
                            return self._table[name](*args, **kwargs)
            if cmd.error is not None:
                raise cmd.error
            return cmd.result

    # ----------------------------------------------------- command bodies
    def _run_steps(self, targets, max_inflight: Optional[int] = None):
        return self.ctl.scheduler.run_dispatch(
            targets, max_inflight=max_inflight)

    def _save(self, app_id: str, async_: bool = False) -> None:
        self.ctl.runtimes[app_id].save(async_=async_)

    def _restore(self, app_id: str,
                 step: Optional[int] = None) -> Optional[int]:
        rt = self.ctl.runtimes[app_id]
        if rt.ckpt.latest_step() is None:
            return None
        return rt.restore(step=step)

    def _generate(self, app_id: str, prompt: Sequence[int],
                  max_new_tokens: int = 16,
                  eos_id: Optional[int] = None,
                  now: Optional[float] = None) -> str:
        """Queue a generate session on a paged serve block.  Tokens flow
        back as ``generate``/``session`` events published by the autostep
        engine's decode rounds (the gateway's generate endpoint streams
        them; deterministic-mode callers drive ``autostep_round``)."""
        blk = self.ctl.registry.get(app_id)       # KeyError -> caller 404
        rt = self.ctl.runtimes.get(app_id)
        start = getattr(rt, "start_session", None)
        if rt is None or start is None or getattr(rt, "sessions", None) is None:
            raise ValueError(
                f"{app_id} has no generate surface: needs an active paged "
                f"serve job (activate with kind=serve, paged=true)")
        # bind the block to the submitting request's trace (if any): the
        # engine's later decode rounds for this block join it, giving one
        # connected gateway -> queue -> admit -> decode trace per request
        TRACER.bind(app_id)
        with TRACER.span("serve.submit", cat="serve", app_id=app_id,
                         user=blk.request.user):
            sid = start(list(prompt), max_new_tokens=max_new_tokens,
                        eos_id=eos_id)
        self.ctl.bus.publish("session", app_id=app_id,
                             block_id=blk.block_id, user=blk.request.user,
                             now=now, action="submitted", session=sid,
                             prompt_tokens=len(prompt),
                             max_new_tokens=int(max_new_tokens))
        return sid

    # ------------------------------------------------------ typed wrappers
    def register(self, *a, **kw) -> str:
        return self.call("register", *a, **kw)

    def submit(self, *a, **kw):
        return self.call("submit", *a, **kw)

    def submit_gang(self, *a, **kw):
        return self.call("submit_gang", *a, **kw)

    def review(self, *a, **kw):
        return self.call("review", *a, **kw)

    def confirm(self, app_id: str, token: str) -> None:
        return self.call("confirm", app_id, token)

    def activate(self, app_id: str, job):
        return self.call("activate", app_id, job)

    def run(self, app_id: str) -> None:
        return self.call("run", app_id)

    def run_steps(self, targets, max_inflight: Optional[int] = None):
        """Step RUNNING blocks (``targets``: rounds-per-app mapping or a
        single int for every running block), event-driven."""
        return self.call("run_steps", targets, max_inflight=max_inflight)

    def step_all(self, rounds: int = 1, sync_every: int = 1):
        return self.call("step_all", rounds, sync_every)

    def download(self, app_id: str) -> Dict:
        return self.call("download", app_id)

    def expire(self, app_id: str, now: Optional[float] = None) -> None:
        return self.call("expire", app_id, now=now)

    def preempt(self, app_id: str, reason: str = "admin preempt",
                now: Optional[float] = None) -> None:
        return self.call("preempt", app_id, reason=reason, now=now)

    def resume(self, app_id: str, n_chips: Optional[int] = None):
        return self.call("resume", app_id, n_chips=n_chips)

    def resize(self, app_id: str, new_n_chips: int):
        return self.call("resize", app_id, new_n_chips)

    def tick(self, now: Optional[float] = None) -> List[str]:
        return self.call("tick", now=now)

    def inject_chip_failure(self, coord, now: Optional[float] = None):
        return self.call("inject_chip_failure", coord, now=now)

    def save(self, app_id: str, async_: bool = False) -> None:
        return self.call("save", app_id, async_=async_)

    def restore(self, app_id: str,
                step: Optional[int] = None) -> Optional[int]:
        return self.call("restore", app_id, step=step)

    def set_quota(self, user: str, max_chips: Optional[int] = None,
                  max_chip_seconds: Optional[float] = None):
        return self.call("set_quota", user, max_chips=max_chips,
                         max_chip_seconds=max_chip_seconds)

    def autostep_enable(self, app_id: str, **cfg) -> Dict:
        """Arm the autostep engine for one block (daemon-side stepping:
        the pump drives the block's dispatch window; no client ``steps``
        traffic needed).  ``cfg``: max_rate_hz, until_steps, until_t,
        stop_at_deadline, ckpt_every."""
        return self.call("autostep_enable", app_id, **cfg)

    def autostep_disable(self, app_id: str, reason: str = "disabled"):
        return self.call("autostep_disable", app_id, reason=reason)

    def autostep_pace(self, app_id: str, max_rate_hz: Optional[float]):
        return self.call("autostep_pace", app_id, max_rate_hz)

    def generate(self, app_id: str, prompt: Sequence[int],
                 max_new_tokens: int = 16, eos_id: Optional[int] = None,
                 now: Optional[float] = None) -> str:
        """Submit a generate session to a paged serve block; returns the
        session id whose tokens stream back as ``generate`` events."""
        return self.call("generate", app_id, prompt,
                         max_new_tokens=max_new_tokens, eos_id=eos_id,
                         now=now)

    def autostep_round(self, now: Optional[float] = None,
                       budget: Optional[int] = None,
                       pod: Optional[int] = None) -> int:
        """Drive one engine round inline (deterministic mode / tests;
        background mode runs rounds from the per-pod workers
        automatically).  ``pod`` restricts the round to that pod's
        residents."""
        return self.call("autostep_round", now=now, budget=budget, pod=pod)

    # ------------------------------------------------------- federation
    def attach_pod(self, pod_x: int, pod_y: int, name: Optional[str] = None,
                   devices: Optional[Sequence] = None,
                   power_budget_chips: Optional[float] = None,
                   now: Optional[float] = None) -> Dict:
        """Attach a new pod at runtime: its chips join the federated free
        pool immediately and the next pump admits queued/preempted blocks
        onto it (no daemon restart)."""
        # the pod name rides positionally: call()'s own first parameter
        # is also ``name`` (the command), so the kwarg would collide
        return self.call("attach_pod", pod_x, pod_y, name, devices,
                         power_budget_chips=power_budget_chips, now=now)

    def drain_pod(self, pod_id: int, now: Optional[float] = None) -> Dict:
        """Stop placing new blocks on a pod (residents keep running)."""
        return self.call("drain_pod", pod_id, now=now)

    def detach_pod(self, pod_id: int, force: bool = False,
                   now: Optional[float] = None) -> Dict:
        """Remove a pod.  Refuses while residents hold chips unless
        ``force`` (which evicts/migrates them first)."""
        return self.call("detach_pod", pod_id, force=force, now=now)

    def fail_pod(self, pod_id: int, reason: str = "pod died",
                 now: Optional[float] = None) -> List[str]:
        """Declare a pod dead (fault injection / admin): every resident
        is preempted or migrated; returns the victim app ids."""
        return self.call("fail_pod", pod_id, reason=reason, now=now)

    def pod_heartbeat(self, pod_id: int,
                      now: Optional[float] = None) -> Dict:
        return self.call("pod_heartbeat", pod_id, now=now)

    # ------------------------------------------------------------ reads
    # (thread-safe structures; never queued behind commands)
    @property
    def bus(self) -> EventBus:
        return self.ctl.bus

    @property
    def registry(self):
        return self.ctl.registry

    @property
    def partitioner(self):
        return self.ctl.partitioner

    @property
    def monitor(self):
        return self.ctl.monitor

    @property
    def scheduler(self):
        return self.ctl.scheduler

    @property
    def runtimes(self):
        return self.ctl.runtimes

    @property
    def topo(self) -> Topology:
        return self.ctl.topo

    @property
    def pods(self):
        return self.ctl.pods

    def list_pods(self) -> List[Dict]:
        """Public federation view: every pod's directory entry."""
        return self.ctl.pods.describe_all()

    def runtime(self, app_id: str):
        return self.ctl.runtimes.get(app_id)

    def interference_report(self):
        return self.ctl.interference_report()

    def status(self, app_id: str,
               _stragglers: Optional[List[str]] = None) -> Dict:
        """One block's public lifecycle view (what the gateway serves).
        ``_stragglers`` lets ``list_apps`` compute the straggler set once
        for the whole table instead of once per row."""
        blk = self.ctl.registry.get(app_id)
        rt = self.ctl.runtimes.get(app_id)
        stragglers = (_stragglers if _stragglers is not None
                      else self.ctl.monitor.stragglers())
        return {
            "app_id": app_id,
            "user": blk.request.user,
            "job": blk.request.job_description,
            "state": blk.state.value,
            "n_chips": blk.request.n_chips,
            "priority": blk.request.priority,
            "deadline_at": blk.deadline_at,
            "est_steps": blk.request.est_steps,
            "gang_id": blk.request.gang_id,
            "block_id": blk.block_id,
            "pod": (blk.grant.coords[0][0]
                    if blk.grant and blk.grant.coords
                    else blk.request.pod),
            "coords": list(blk.grant.coords) if blk.grant else None,
            "mesh_shape": list(blk.grant.mesh_shape) if blk.grant else None,
            "expires_at": blk.grant.expires_at if blk.grant else None,
            "queued_at": blk.queued_at,
            "preempt_count": blk.preempt_count,
            "failure": blk.failure_reason,
            "steps": getattr(rt, "step_count", 0) if rt else 0,
            "mfu": self.ctl.monitor.mfu(blk.block_id),
            "straggler": blk.block_id in stragglers,
            "autostep": self.engine.describe(app_id),
        }

    def list_apps(self, user: Optional[str] = None) -> List[Dict]:
        reg = self.ctl.registry
        with reg._lock:
            ids = [a for a, b in reg.apps.items()
                   if user is None or b.request.user == user]
        stragglers = self.ctl.monitor.stragglers()
        return [self.status(a, _stragglers=stragglers) for a in ids]

    def cluster_report(self) -> Dict:
        topo = self.ctl.topo
        return {
            "n_pods": topo.n_pods, "pod_x": topo.pod_x, "pod_y": topo.pod_y,
            # federation totals: chips across every *live* pod (boot +
            # runtime-attached), not just the boot topology
            "n_chips": self.ctl.total_chips(),
            "free_chips": self.ctl.partitioner.free_capacity(),
            "pods": self.ctl.pods.describe_all(),
            "federation": self.ctl.monitor.federation_report(),
            # raw waitlist length, not queue_depth(): that would prune —
            # a mutation — outside the command serialization
            "queue_depth": len(self.ctl.scheduler.waitlist),
            "queue": self.ctl.monitor.queue_report(),
            "deadlines": self.ctl.monitor.deadline_report(),
            "preemption": self.ctl.monitor.preemption_report(),
            "compile": self.ctl.monitor.compile_report(),
            "roofline": self.ctl.monitor.roofline_report(),
            "obs": self.obs_report(),
        }

    def obs_report(self) -> Dict:
        """Observability summary for the dashboard tiles: key latency
        histograms, abuse counters, straggler set, recent postmortems and
        the sparkline series rings."""
        stragglers = self.ctl.monitor.stragglers()
        REGISTRY.set_gauge("repro_stragglers", len(stragglers))
        return {
            "trace_enabled": TRACER.enabled,
            "pump_tick": REGISTRY.hist_summary("repro_pump_tick_seconds"),
            "admission_wait": REGISTRY.hist_summary(
                "repro_admission_wait_seconds"),
            "http_429": REGISTRY.counter_total("repro_http_429_total"),
            "http_413": REGISTRY.counter_total("repro_http_413_total"),
            "sse_streams": REGISTRY.gauge_value("repro_sse_streams"),
            "stragglers": sorted(stragglers),
            "postmortems": RECORDER.dumps(),
            "series": REGISTRY.series(),
        }

    def events_since(self, after_seq: int = 0,
                     app_id: Optional[str] = None,
                     kinds=None, limit: int = 1000) -> List[BlockEvent]:
        return self.ctl.bus.events_since(after_seq, app_id=app_id,
                                         kinds=kinds, limit=limit)

    def wait_events(self, after_seq: int = 0,
                    app_id: Optional[str] = None, kinds=None,
                    timeout: float = 10.0,
                    limit: int = 1000) -> List[BlockEvent]:
        return self.ctl.bus.wait(after_seq, app_id=app_id, kinds=kinds,
                                 timeout=timeout, limit=limit)
