"""BlockScheduler — admission queue + event-driven multi-block dispatch.

The paper's public-cluster property (and its follow-ups: "Multi and
Independent Block Approach", arXiv:0708.3446; openPC, arXiv:1012.2499) is
that one shared master absorbs *competing* block requests automatically.
The seed controller had neither piece: ``Partitioner.allocate`` raised
``AllocationError`` when the pod was full, and ``step_all`` round-robined
with a fixed-order ``block_until_ready`` so one slow block gated every
other block's next dispatch on the host thread.

Two subsystems fix that:

* **Admission queue** — ``submit()`` tries to allocate immediately; when
  the pod cannot fit the request the application is parked on a waitlist
  (registry state QUEUED) instead of raising.  ``pump()`` re-examines the
  waitlist whenever capacity frees (block expiry via ``tick()``, explicit
  ``expire()``, elastic shrink) and admits entries in fair-share order:
  priority first, then fewest currently-held chips per user, then FIFO.
  Entries that fit are backfilled past ones that don't, so a large stuck
  request doesn't idle chips a small request could use.

* **Event-driven dispatch** — ``drive()`` keeps up to ``max_inflight``
  async steps outstanding per block (dispatch-depth backpressure) and
  harvests completions in whatever order the devices finish, blocking only
  when every window is full and nothing is ready.  A slow block therefore
  never stalls a fast block's next dispatch on the host thread.

``SimRuntime`` is a wall-clock model of a block's serial step chain used
by the scheduler benchmark and tests (no devices required).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Mapping, Optional, Union

from repro.core.block import BlockGrant, BlockState
from repro.core.partition import AllocationError


@dataclasses.dataclass
class QueueEntry:
    app_id: str
    user: str
    n_chips: int
    priority: int
    enqueued_at: float
    seq: int                          # registry FIFO sequence number
    pod: Optional[int] = None
    job: Optional[object] = None      # JobSpec -> auto activate+run on admit


# ----------------------------------------------------------------- dispatch
def drive(runtimes: Mapping[str, object], targets: Mapping[str, int],
          max_inflight: int = 2,
          on_step: Optional[Callable[[str, Dict[str, float]], None]] = None,
          ) -> Dict[str, List[Dict[str, float]]]:
    """Run each runtime for ``targets[app_id]`` steps, event-driven.

    Runtimes need the in-flight window protocol: ``dispatch()``,
    ``poll(block=False)``, ``inflight_depth``, ``oldest_dispatch_t()``.
    Steps are dispatched while a
    block's window has room and harvested as they finish; when every window
    is full and nothing is ready, we block on the runtime with the oldest
    outstanding dispatch rather than spinning.
    """
    remaining = {a: int(n) for a, n in targets.items()
                 if a in runtimes and n > 0}
    out: Dict[str, List[Dict[str, float]]] = {a: [] for a in remaining}

    def harvest(app_id: str, block: bool = False) -> int:
        n = 0
        for rec in runtimes[app_id].poll(block=block):
            out[app_id].append(rec)
            if on_step is not None:
                on_step(app_id, rec)
            n += 1
        return n

    while True:
        dispatched = 0
        for app_id in list(remaining):
            rt = runtimes[app_id]
            while remaining[app_id] > 0 and rt.inflight_depth < max_inflight:
                rt.dispatch()
                remaining[app_id] -= 1
                dispatched += 1
        harvested = sum(harvest(a) for a in out)
        busy = [a for a in out if runtimes[a].inflight_depth > 0]
        if not busy and all(v == 0 for v in remaining.values()):
            return out
        if dispatched == 0 and harvested == 0 and busy:
            # every window full / work pending: wait on the oldest dispatch
            oldest = min(busy, key=lambda a: runtimes[a].oldest_dispatch_t())
            harvest(oldest, block=True)


class BlockScheduler:
    """Admission queue + dispatch loop over a ClusterController."""

    def __init__(self, ctl, max_inflight: int = 2):
        self.ctl = ctl
        self.max_inflight = max_inflight
        self.waitlist: Dict[str, QueueEntry] = {}   # app_id -> entry

    # ------------------------------------------------------------ admission
    def submit(self, app_id: str, job: Optional[object] = None,
               priority: Optional[int] = None,
               pod: Optional[int] = None) -> Optional[BlockGrant]:
        """Admit a registered application now, or park it on the waitlist.

        Returns the grant on immediate admission, None when queued.  With a
        ``job`` the block is auto-confirmed, activated and run on admission
        (immediately or later from ``pump()``), so a caller can fire
        arbitrary request traffic at the cluster and let it absorb the load.
        """
        blk = self.ctl.registry.get(app_id)
        if not self.ctl.partitioner.shape_possible(blk.request.n_chips):
            # never admissible (invalid size / exceeds pod geometry):
            # waitlisting would park it forever, so reject up front
            self.ctl.registry.deny(
                app_id, f"{blk.request.n_chips} chips can never fit this pod")
            return None
        entry = QueueEntry(
            app_id=app_id, user=blk.request.user,
            n_chips=blk.request.n_chips,
            priority=(blk.request.priority if priority is None else priority),
            enqueued_at=time.time(), seq=0, pod=pod, job=job)
        # admit the existing waitlist first so a newcomer can't jump a
        # higher-ranked entry that also fits
        self.pump()
        if not self.waitlist:
            grant = self._try_admit(entry)
            if grant is not None:
                return grant
        entry.seq = self.ctl.registry.enqueue(
            app_id, f"waitlisted: {entry.n_chips} chips unavailable")
        entry.enqueued_at = self.ctl.registry.get(app_id).queued_at
        self.waitlist[app_id] = entry
        self.ctl.monitor.record_enqueue(app_id)
        # backfill: the newcomer may fit even though higher-ranked entries
        # don't (pump admits in fair-share order with skip-past)
        self.pump()
        if app_id not in self.waitlist:
            return self.ctl.registry.get(app_id).grant
        return None

    def _held_chips_by_user(self) -> Dict[str, int]:
        held: Dict[str, int] = {}
        reg = self.ctl.registry
        for app_id in reg.by_state(BlockState.APPROVED, BlockState.CONFIRMED,
                                   BlockState.ACTIVE, BlockState.RUNNING,
                                   BlockState.DONE):
            blk = reg.get(app_id)
            if blk.grant:
                held[blk.request.user] = (held.get(blk.request.user, 0)
                                          + blk.grant.n_chips)
        return held

    def ordered_waitlist(self) -> List[QueueEntry]:
        """Fair-share admission order: priority desc, then fewest chips the
        user currently holds, then FIFO."""
        held = self._held_chips_by_user()
        return sorted(self.waitlist.values(),
                      key=lambda e: (-e.priority, held.get(e.user, 0), e.seq))

    def _try_admit(self, entry: QueueEntry) -> Optional[BlockGrant]:
        try:
            grant = self.ctl.grant_block(entry.app_id, entry.n_chips,
                                         pod=entry.pod)
        except AllocationError:
            return None
        if entry.job is not None:
            self.ctl.confirm(entry.app_id, grant.token)
            self.ctl.activate(entry.app_id, entry.job)
            self.ctl.run(entry.app_id)
        return grant

    def _prune_waitlist(self) -> None:
        """Drop entries whose application left the QUEUED state behind the
        scheduler's back (admin deny, forced expiry): admitting them would
        be an illegal transition and would leak their chips."""
        for app_id in list(self.waitlist):
            if self.ctl.registry.get(app_id).state != BlockState.QUEUED:
                del self.waitlist[app_id]
                self.ctl.monitor.record_dequeue(app_id)

    def pump(self, now: Optional[float] = None) -> List[str]:
        """Admit waitlisted applications that now fit, in fair-share order
        (with backfill past entries that still don't fit).  Called from
        ``tick()`` and after every expiry/shrink."""
        admitted: List[str] = []
        now = now or time.time()
        self._prune_waitlist()
        while True:
            progress = False
            for entry in self.ordered_waitlist():
                if not self.ctl.partitioner.can_fit(entry.n_chips, entry.pod):
                    continue
                grant = self._try_admit(entry)
                if grant is None:
                    continue
                del self.waitlist[entry.app_id]
                self.ctl.monitor.record_admission(
                    entry.app_id, max(0.0, now - entry.enqueued_at))
                admitted.append(entry.app_id)
                progress = True
                break    # holdings changed: recompute fair-share order
            if not progress:
                return admitted

    def queue_depth(self) -> int:
        self._prune_waitlist()
        return len(self.waitlist)

    # ------------------------------------------------------------- dispatch
    def run_dispatch(self, targets: Union[int, Mapping[str, int]],
                     max_inflight: Optional[int] = None,
                     ) -> Dict[str, List[Dict[str, float]]]:
        """Event-driven stepping of RUNNING blocks.

        ``targets`` is either a per-app step count or a single int applied
        to every RUNNING block.  Completions feed the Monitor as they land.
        """
        reg = self.ctl.registry
        if isinstance(targets, int):
            targets = {a: targets for a in reg.by_state(BlockState.RUNNING)}
        runtimes = {a: self.ctl.runtimes[a] for a in targets
                    if a in self.ctl.runtimes}

        def on_step(app_id: str, rec: Dict[str, float]) -> None:
            blk = reg.get(app_id)
            self.ctl.monitor.record_step(blk.block_id, rec["step_s"],
                                         blk.grant.n_chips)

        return drive(runtimes, targets,
                     max_inflight=max_inflight or self.max_inflight,
                     on_step=on_step)


# ---------------------------------------------------------------- simulation
class SimRuntime:
    """Wall-clock model of a block runtime: steps are serially dependent
    within the block (each becomes ready ``step_s`` after its predecessor)
    and concurrent across blocks — the paper's disjoint-sub-mesh model.
    Implements both the in-flight window protocol (``dispatch``/``poll``/
    ``inflight_depth``) and a synchronous ``step()`` for emulating the old
    round-robin dispatcher."""

    def __init__(self, step_s: float):
        self.step_s = step_s
        self.step_count = 0
        self._inflight: List[tuple] = []   # (dispatch_t, start_t, ready_at)
        self._chain_free_at = 0.0          # when the serial chain is idle

    @property
    def inflight_depth(self) -> int:
        return len(self._inflight)

    def oldest_dispatch_t(self) -> float:
        return self._inflight[0][0] if self._inflight else float("inf")

    def dispatch(self) -> None:
        now = time.perf_counter()
        start = max(now, self._chain_free_at)
        self._chain_free_at = start + self.step_s
        self._inflight.append((now, start, self._chain_free_at))

    def poll(self, block: bool = False) -> List[Dict[str, float]]:
        out: List[Dict[str, float]] = []
        while self._inflight:
            t0, start, ready_at = self._inflight[0]
            now = time.perf_counter()
            if now < ready_at:
                if not (block and not out):
                    break
                time.sleep(ready_at - now)
            self._inflight.pop(0)
            self.step_count += 1
            # execution time only (not wait-behind-predecessor): the same
            # chain accounting BlockRuntime.poll uses
            out.append({"step_s": ready_at - start})
        return out

    def step(self) -> Dict[str, float]:
        """Synchronous step (old round-robin semantics)."""
        self.dispatch()
        return self.poll(block=True)[0]
