"""BlockScheduler — admission queue + event-driven multi-block dispatch.

The paper's public-cluster property (and its follow-ups: "Multi and
Independent Block Approach", arXiv:0708.3446; openPC, arXiv:1012.2499) is
that one shared master absorbs *competing* block requests automatically.
The seed controller had neither piece: ``Partitioner.allocate`` raised
``AllocationError`` when the pod was full, and ``step_all`` round-robined
with a fixed-order ``block_until_ready`` so one slow block gated every
other block's next dispatch on the host thread.

Two subsystems fix that:

* **Admission queue** — ``submit()`` tries to allocate immediately; when
  the pod cannot fit the request the application is parked on a waitlist
  (registry state QUEUED) instead of raising.  ``pump()`` re-examines the
  waitlist whenever capacity frees (block expiry via ``tick()``, explicit
  ``expire()``, elastic shrink) and admits entries in fair-share order:
  priority first, then fewest currently-held chips per user, then FIFO.
  Entries that fit are backfilled past ones that don't, so a large stuck
  request doesn't idle chips a small request could use.

* **Event-driven dispatch** — ``drive()`` keeps up to ``max_inflight``
  async steps outstanding per block (dispatch-depth backpressure) and
  harvests completions in whatever order the devices finish, blocking only
  when every window is full and nothing is ready.  A slow block therefore
  never stalls a fast block's next dispatch on the host thread.

* **Checkpoint-backed preemption** — when a waitlisted entry outranks a
  running block (strictly higher priority) and no free rectangle fits it,
  ``pump()`` picks a victim by (priority asc, progress-lost = steps since
  its last checkpoint asc, held chips asc), suspends it (drain in-flight →
  synchronous checkpoint → release chips) and admits the waiter.  The
  victim re-enters the waitlist *ahead of its fair-share class* and is
  auto-resumed by ``tick()`` — on a possibly different chip set / mesh
  geometry — as capacity frees.  The strict-priority requirement is the
  no-churn guard: two equal-priority blocks can never evict each other in
  a loop.

* **Tenancy policy** — a ``SchedulingPolicy`` (``repro.core.policy``) is
  consulted at three points: at *submit* and *pump* time,
  ``admission_blocked`` enforces per-user quotas (held-chip caps and
  chip-second budgets fed from ``Monitor.chip_seconds``) — over-quota
  requests are *waitlisted*, never denied, and become admissible again as
  the user's blocks retire; at *pump* time, ``waitlist_key`` orders each
  fair-share class by least deadline slack instead of FIFO (queue entries
  carry an absolute ``deadline_at`` computed at submission), with the
  Monitor recording admission-time slack as deadline hits/misses; at
  *preempt* time, ``victim_key`` promotes quota-busting running blocks to
  preferred victims ahead of the (priority, progress-lost, chips) key.

* **Gang admission** — ``submit_gang([...])`` admits a *set* of blocks
  atomically (multi-block jobs that must co-start, e.g. trainer + eval
  server): ``Partitioner.allocate_many`` finds every rectangle under one
  lock hold and rolls back on partial failure, the waitlist treats the
  gang as one all-or-nothing unit, and victim selection frees room for
  the whole gang or evicts nothing.  Evicted members re-enter the
  waitlist as a gang unit too, so co-start also holds across evictions.

* **Completion-aware slack** — within a fair-share class, ordering uses
  *effective* slack: time-to-deadline minus the estimated remaining
  service time (the request's declared ``est_steps`` x the Monitor's
  EWMA step time), so ordering reflects time-to-complete, not just
  time-to-deadline.  Victim selection likewise weighs each candidate's
  own deadline headroom and never evicts a block into a miss it would
  not otherwise have had (``SchedulingPolicy.victim_deadline_exempt``).

Scheduling decisions are published on the controller's ``EventBus``
(``admitted``/``enqueued``/``preempted``/``step``/...); the ``Monitor``
subscribes instead of being called directly.

``SimRuntime`` is a wall-clock model of a block's serial step chain used
by the scheduler benchmarks and tests (no devices required).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Mapping, Optional, Tuple, Union

from repro.analysis import runtime_check
from repro.core.block import BlockGrant, BlockState
from repro.core.inflight import InflightWindow
from repro.core.partition import AllocationError
from repro.core.policy import SchedulingPolicy
from repro.obs.trace import TRACER


@dataclasses.dataclass
class QueueEntry:
    app_id: str
    user: str
    n_chips: int
    priority: int
    enqueued_at: float
    seq: int                          # registry FIFO sequence number
    pod: Optional[int] = None
    job: Optional[object] = None      # JobSpec -> auto activate+run on admit
    preempted: bool = False           # evicted victim awaiting auto-resume
    deadline_at: Optional[float] = None  # absolute SLO deadline (slack order)
    gang_id: Optional[str] = None     # all-or-nothing co-admission set


# ----------------------------------------------------------------- dispatch
def drive(runtimes: Mapping[str, object], targets: Mapping[str, int],
          max_inflight: int = 2,
          on_step: Optional[Callable[[str, Dict[str, float]], None]] = None,
          ) -> Dict[str, List[Dict[str, float]]]:
    """Run each runtime for ``targets[app_id]`` steps, event-driven.

    Runtimes need the in-flight window protocol: ``dispatch()``,
    ``poll(block=False)``, ``inflight_depth``, ``oldest_dispatch_t()``.
    Steps are dispatched while a
    block's window has room and harvested as they finish; when every window
    is full and nothing is ready, we block on the runtime with the oldest
    outstanding dispatch rather than spinning.
    """
    remaining = {a: int(n) for a, n in targets.items()
                 if a in runtimes and n > 0}
    out: Dict[str, List[Dict[str, float]]] = {a: [] for a in remaining}

    def harvest(app_id: str, block: bool = False) -> int:
        n = 0
        for rec in runtimes[app_id].poll(block=block):
            out[app_id].append(rec)
            if on_step is not None:
                on_step(app_id, rec)
            n += 1
        return n

    while True:
        dispatched = 0
        for app_id in list(remaining):
            rt = runtimes[app_id]
            while remaining[app_id] > 0 and rt.inflight_depth < max_inflight:
                rt.dispatch()
                remaining[app_id] -= 1
                dispatched += 1
        harvested = sum(harvest(a) for a in out)
        busy = [a for a in out if runtimes[a].inflight_depth > 0]
        if not busy and all(v == 0 for v in remaining.values()):
            return out
        if dispatched == 0 and harvested == 0 and busy:
            # every window full / work pending: wait on the oldest dispatch
            oldest = min(busy, key=lambda a: runtimes[a].oldest_dispatch_t())
            harvest(oldest, block=True)


class BlockScheduler:
    """Admission queue + dispatch loop over a ClusterController."""

    def __init__(self, ctl, max_inflight: int = 2,
                 preemption_enabled: bool = True,
                 policy: Optional[SchedulingPolicy] = None):
        self.ctl = ctl
        self.max_inflight = max_inflight
        self.preemption_enabled = preemption_enabled
        self.policy = policy or SchedulingPolicy()
        self.waitlist: Dict[str, QueueEntry] = {}   # app_id -> entry

    # ------------------------------------------------------------ admission
    def _entry_for(self, app_id: str, job: Optional[object],
                   priority: Optional[int], pod: Optional[int],
                   deadline_s: Optional[float], now: float) -> QueueEntry:
        """Build a queue entry, persisting overrides onto the request: after
        admission the request is the canonical record, and preemption
        (victim selection, requeue) must see the same priority/pod/deadline
        that admission used."""
        blk = self.ctl.registry.get(app_id)
        if priority is not None:
            blk.request.priority = priority
        if pod is not None:
            blk.request.pod = pod
        if deadline_s is not None:
            blk.request.deadline_s = deadline_s
        if blk.request.deadline_s is not None and blk.deadline_at is None:
            # the SLO clock starts at submission and is absolute from then
            # on — requeues after preemption keep the original deadline
            blk.deadline_at = now + blk.request.deadline_s
        return QueueEntry(
            app_id=app_id, user=blk.request.user,
            n_chips=blk.request.n_chips,
            priority=blk.request.priority,
            enqueued_at=now, seq=0, pod=blk.request.pod, job=job,
            deadline_at=blk.deadline_at, gang_id=blk.request.gang_id)

    def submit(self, app_id: str, job: Optional[object] = None,
               priority: Optional[int] = None,
               pod: Optional[int] = None,
               deadline_s: Optional[float] = None,
               now: Optional[float] = None) -> Optional[BlockGrant]:
        """Admit a registered application now, or park it on the waitlist.

        Returns the grant on immediate admission, None when queued.  With a
        ``job`` the block is auto-confirmed, activated and run on admission
        (immediately or later from ``pump()``), so a caller can fire
        arbitrary request traffic at the cluster and let it absorb the load.
        Requests the user's quota cannot cover are waitlisted (not denied)
        until the user's running blocks retire.  ``now`` keeps deadline and
        wait accounting on the model clock under a simulated-clock driver.
        """
        now = now if now is not None else time.time()
        blk = self.ctl.registry.get(app_id)
        with TRACER.span("sched.submit", cat="sched", app_id=app_id,
                         user=blk.request.user,
                         n_chips=blk.request.n_chips) as sp:
            if not self.ctl.partitioner.shape_possible(blk.request.n_chips):
                # never admissible (invalid size / exceeds pod geometry):
                # waitlisting would park it forever, so reject up front
                self.ctl.registry.deny(
                    app_id,
                    f"{blk.request.n_chips} chips can never fit this pod")
                sp.set(outcome="denied")
                return None
            entry = self._entry_for(app_id, job, priority, pod, deadline_s,
                                    now)
            if self._submit_unit([entry], now):
                sp.set(outcome="admitted")
                return self.ctl.registry.get(app_id).grant
            sp.set(outcome="queued")
            return None

    def submit_gang(self, app_ids: List[str],
                    jobs: Optional[Mapping[str, object]] = None,
                    priority: Optional[int] = None,
                    pod: Optional[int] = None,
                    deadline_s: Optional[float] = None,
                    now: Optional[float] = None
                    ) -> Optional[Dict[str, BlockGrant]]:
        """All-or-nothing admission of a set of registered applications
        (multi-block jobs that must co-start).  Returns ``{app_id: grant}``
        when the whole gang is admitted now, None when it was waitlisted as
        a unit — no member is ever admitted without the others, and a
        failed attempt leaves the partitioner inventory untouched."""
        now = now if now is not None else time.time()
        jobs = jobs or {}
        reg = self.ctl.registry
        part = self.ctl.partitioner
        if not all(part.shape_possible(reg.get(a).request.n_chips)
                   for a in app_ids):
            for a in app_ids:       # one impossible member dooms the gang
                reg.deny(a, "gang member can never fit this pod")
            return None
        gang_id = f"gang_{app_ids[0]}"
        unit = []
        for app_id in app_ids:
            reg.get(app_id).request.gang_id = gang_id
            unit.append(self._entry_for(app_id, jobs.get(app_id),
                                        priority, pod, deadline_s, now))
        if self._submit_unit(unit, now):
            return {e.app_id: reg.get(e.app_id).grant for e in unit}
        return None

    def _submit_unit(self, unit: List[QueueEntry], now: float) -> bool:
        """Shared submission sequence for a singleton or gang unit: admit
        the existing waitlist first (so a newcomer can't jump a
        higher-ranked entry that also fits), try immediate admission —
        zero-wait admissions count as SLO outcomes too, or the miss rate
        would only see requests that queued — otherwise enqueue every
        member and backfill (pump admits in fair-share order with
        skip-past).  Returns True when the whole unit holds grants."""
        # a unit whose chip footprint exceeds its user's own cap can never
        # become admissible (no running block of theirs can retire enough):
        # waitlisting would park it forever, so reject up front the way
        # shape_possible rejects geometrically-impossible sizes
        per_user: Dict[str, int] = {}
        for e in unit:
            per_user[e.user] = per_user.get(e.user, 0) + e.n_chips
        for user, req in per_user.items():
            cap = self.policy.quota_for(user).max_chips
            if cap is not None and req > cap:
                for e in unit:
                    self.ctl.registry.deny(
                        e.app_id,
                        f"quota: {req} chips exceeds {user}'s cap {cap}")
                return False
        self.pump(now)
        quota_reason = self._quota_blocked(unit, self._held_chips_by_user(),
                                           self._chip_seconds_by_user())
        if not self.waitlist and quota_reason is None:
            if self._admit_unit(unit, now) is not None:
                for e in unit:
                    blk = self.ctl.registry.get(e.app_id)
                    slack = (None if e.deadline_at is None
                             else e.deadline_at - now)
                    self.ctl.bus.publish(
                        "admitted", app_id=e.app_id, block_id=blk.block_id,
                        user=e.user, now=now, immediate=True, wait_s=0.0,
                        priority=e.priority, slack_s=slack)
                return True
        note = (f"gang {unit[0].gang_id} waitlisted" if len(unit) > 1
                else "waitlisted")
        for entry in unit:
            entry.seq = self.ctl.registry.enqueue(
                entry.app_id,
                quota_reason or f"{note}: {entry.n_chips} chips unavailable",
                now=now)
            entry.enqueued_at = self.ctl.registry.get(entry.app_id).queued_at
            self.waitlist[entry.app_id] = entry
            self.ctl.bus.publish("enqueued", app_id=entry.app_id,
                                 user=entry.user, now=now,
                                 priority=entry.priority,
                                 n_chips=entry.n_chips)
        self.pump(now)
        return all(e.app_id not in self.waitlist for e in unit)

    def _held_chips_by_user(self) -> Dict[str, int]:
        held: Dict[str, int] = {}
        reg = self.ctl.registry
        for app_id in reg.by_state(BlockState.APPROVED, BlockState.CONFIRMED,
                                   BlockState.ACTIVE, BlockState.RUNNING,
                                   BlockState.DONE):
            blk = reg.get(app_id)
            if blk.grant:
                held[blk.request.user] = (held.get(blk.request.user, 0)
                                          + blk.grant.n_chips)
        return held

    def _chip_seconds_by_user(self) -> Dict[str, float]:
        """Cumulative per-user compute spend, aggregated from the Monitor's
        per-block chip-second accounting (the quota budget input)."""
        used: Dict[str, float] = {}
        mon = self.ctl.monitor
        for blk in list(self.ctl.registry.apps.values()):
            if blk.block_id:
                s = mon.stats.get(blk.block_id)
                if s is not None:
                    used[blk.request.user] = (used.get(blk.request.user, 0.0)
                                              + s.chip_seconds)
        return used

    def _quota_blocked(self, unit: List[QueueEntry],
                       held: Dict[str, int],
                       used: Dict[str, float]) -> Optional[str]:
        """Policy consultation: may this admission unit (singleton or whole
        gang) be admitted under its users' quotas right now?  Returns the
        blocking reason, or None.  Blocked units stay waitlisted."""
        per_user: Dict[str, int] = {}
        for e in unit:
            per_user[e.user] = per_user.get(e.user, 0) + e.n_chips
        for user, req in per_user.items():
            reason = self.policy.admission_blocked(
                user, req, held.get(user, 0), used.get(user, 0.0))
            if reason:
                return reason
        return None

    def _service_estimate_s(self, entry: QueueEntry) -> float:
        """Estimated remaining service time for a waitlisted entry: the
        requester's declared ``est_steps`` (minus steps already run — a
        preempted victim resumes mid-job) times the Monitor's EWMA step
        time (the block's own when it has run, else the cluster mean).
        0.0 when nothing is declared or nothing has ever run, which
        degrades slack back to pure time-to-deadline."""
        blk = self.ctl.registry.get(entry.app_id)
        est = blk.request.est_steps
        if not est:
            return 0.0
        step_s = self.ctl.monitor.step_time_estimate(blk.block_id)
        if not step_s:
            return 0.0
        done = self.ctl.monitor.steps_done(blk.block_id)
        return max(0, est - done) * step_s

    def _entry_key(self, entry: QueueEntry, held: Dict[str, int],
                   now: float):
        return self.policy.waitlist_key(entry, held.get(entry.user, 0),
                                        now, self._service_estimate_s(entry))

    def ordered_waitlist(self, now: Optional[float] = None
                         ) -> List[QueueEntry]:
        """Fair-share admission order (policy's ``waitlist_key``): priority
        desc, then preempted victims ahead of their fair-share class (they
        already earned their slot once and paid an eviction), then fewest
        chips the user currently holds, then least effective deadline slack
        (time-to-deadline minus estimated time-to-complete), then FIFO."""
        now = now if now is not None else time.time()
        held = self._held_chips_by_user()
        return sorted(self.waitlist.values(),
                      key=lambda e: self._entry_key(e, held, now))

    def _units(self, now: float,
               held: Dict[str, int]) -> List[List[QueueEntry]]:
        """Admission units in fair-share order: singleton entries, plus
        gangs grouped into one all-or-nothing unit ranked by their best
        member.  Preempted gang members re-enter as a gang unit too —
        co-start holds across evictions, so a half-evicted gang co-resumes
        instead of trickling back one member at a time."""
        gangs: Dict[str, List[QueueEntry]] = {}
        units: List[List[QueueEntry]] = []
        for e in self.waitlist.values():
            if e.gang_id is not None:
                gangs.setdefault(e.gang_id, []).append(e)
            else:
                units.append([e])
        units.extend(gangs.values())

        def unit_key(unit: List[QueueEntry]):
            return min(self._entry_key(e, held, now) for e in unit)

        units.sort(key=unit_key)
        for unit in units:
            unit.sort(key=lambda e: e.seq)
        return units

    def requeue_preempted(self, app_id: str, seq: int) -> None:
        """Park an evicted block on the waitlist for auto-resume (the
        registry has already transitioned it to PREEMPTED and assigned the
        queue sequence number)."""
        blk = self.ctl.registry.get(app_id)
        self.waitlist[app_id] = QueueEntry(
            app_id=app_id, user=blk.request.user,
            n_chips=blk.grant.n_chips if blk.grant else blk.request.n_chips,
            priority=blk.request.priority, enqueued_at=blk.queued_at,
            seq=seq, pod=blk.request.pod, preempted=True,
            deadline_at=blk.deadline_at, gang_id=blk.request.gang_id)
        self.ctl.bus.publish("enqueued", app_id=app_id,
                             user=blk.request.user, block_id=blk.block_id,
                             priority=blk.request.priority, preempted=True)

    def _try_admit(self, entry: QueueEntry) -> Optional[BlockGrant]:
        try:
            if entry.preempted:
                # victim re-admission: restore, don't re-grant — the block
                # keeps its identity/token and resumes from its checkpoint
                return self.ctl.resume(entry.app_id)
            grant = self.ctl.grant_block(entry.app_id, entry.n_chips,
                                         pod=entry.pod)
        except AllocationError:
            return None
        if entry.job is not None:
            self.ctl.confirm(entry.app_id, grant.token)
            self.ctl.activate(entry.app_id, entry.job)
            self.ctl.run(entry.app_id)
        return grant

    def _try_admit_gang(self, unit: List[QueueEntry],
                        now: Optional[float] = None
                        ) -> Optional[Dict[str, BlockGrant]]:
        """Admit every member of a gang or none: ``grant_gang`` allocates
        all rectangles under one partitioner lock hold and rolls back on
        partial failure, so a None return leaves the inventory untouched."""
        try:
            grants = self.ctl.grant_gang([e.app_id for e in unit])
        except AllocationError:
            return None
        try:
            for e in unit:
                if e.job is not None:
                    self.ctl.confirm(e.app_id, grants[e.app_id].token)
                    self.ctl.activate(e.app_id, e.job)
                    self.ctl.run(e.app_id)
        except Exception:
            # co-start is all-or-nothing through boot too: a member whose
            # activation fails must not leave its siblings half-running —
            # terminate the whole gang (drain + release) and surface the
            # boot error
            for e in unit:
                try:
                    self.ctl.expire(e.app_id, now=now)
                except Exception:
                    pass
            raise
        return grants

    def _try_resume_gang(self, unit: List[QueueEntry],
                         now: Optional[float] = None
                         ) -> Optional[Dict[str, BlockGrant]]:
        """Co-resume every preempted member of a gang or none: the dry-run
        ``can_fit_many`` and the per-member ``resume`` allocations run the
        same first-fit search in the same order on the same single thread,
        so after the dry run passes each resume finds its rectangle.  On an
        unexpected mid-loop failure the already-resumed members are
        gracefully re-evicted (suspend + requeue), restoring the
        all-or-nothing property."""
        part = self.ctl.partitioner
        if not part.can_fit_many([(e.n_chips, e.pod) for e in unit]):
            return None
        grants: Dict[str, BlockGrant] = {}
        try:
            for e in unit:
                grants[e.app_id] = self.ctl.resume(e.app_id)
        except AllocationError:
            for a in list(grants):
                # the member never left the waitlist (entries are removed
                # only after the whole unit admits), and preempt() ->
                # requeue_preempted re-adds it — retire the stale entry's
                # accounting first or queue_depth inflates forever
                blk = self.ctl.registry.get(a)
                self.ctl.bus.publish("dequeued", app_id=a,
                                     user=blk.request.user)
                self.ctl.preempt(a, reason="gang co-resume rolled back",
                                 now=now)
            return None
        return grants

    def _admit_unit(self, unit: List[QueueEntry],
                    now: Optional[float] = None
                    ) -> Optional[Dict[str, BlockGrant]]:
        if len(unit) == 1:
            grant = self._try_admit(unit[0])
            return None if grant is None else {unit[0].app_id: grant}
        if all(e.preempted for e in unit):
            # evicted gang members co-resume as one unit (members of a
            # waitlisted-then-preempted mix cannot occur: a gang is either
            # entirely queued pre-admission or its evicted subset is
            # entirely PREEMPTED)
            return self._try_resume_gang(unit, now=now)
        return self._try_admit_gang(unit, now=now)

    def _unit_fits(self, unit: List[QueueEntry]) -> bool:
        if len(unit) == 1:
            return self.ctl.partitioner.can_fit(unit[0].n_chips, unit[0].pod)
        return self.ctl.partitioner.can_fit_many(
            [(e.n_chips, e.pod) for e in unit])

    def _prune_waitlist(self) -> None:
        """Drop entries whose application left the QUEUED (or, for evicted
        victims, PREEMPTED) state behind the scheduler's back (admin deny,
        forced expiry): admitting them would be an illegal transition and
        would leak their chips.  A pruned gang member takes its whole gang
        with it — the survivors could never co-start."""
        pruned_gangs = set()
        for app_id, entry in list(self.waitlist.items()):
            expect = (BlockState.PREEMPTED if entry.preempted
                      else BlockState.QUEUED)
            if self.ctl.registry.get(app_id).state != expect:
                del self.waitlist[app_id]
                self.ctl.bus.publish("dequeued", app_id=app_id,
                                     user=entry.user)
                if entry.gang_id is not None and not entry.preempted:
                    pruned_gangs.add(entry.gang_id)
        for app_id, entry in list(self.waitlist.items()):
            if entry.gang_id in pruned_gangs and not entry.preempted:
                del self.waitlist[app_id]
                self.ctl.bus.publish("dequeued", app_id=app_id,
                                     user=entry.user)
                self.ctl.registry.deny(
                    app_id, f"gang {entry.gang_id} member withdrawn")

    # the waitlist dict has no lock of its own by design: every mutation is
    # daemon-serialized, which REPRO_RACE_CHECK=1 asserts at runtime
    @runtime_check.guard_serialized("control-plane")
    def pump(self, now: Optional[float] = None,
             sample_util: bool = False) -> List[str]:
        """Admit waitlisted admission units that now fit, in fair-share +
        deadline-slack order (with backfill past units that don't fit or
        are quota-blocked).  When nothing fits and preemption is enabled,
        evict the cheapest sufficient set of strictly-lower-priority
        running blocks per round to make room for the best-ranked unit.
        Called from ``tick()`` and after every expiry/shrink.

        ``sample_util=True`` (the tick path) additionally publishes one
        pod-utilization event computed from the held-chips snapshot the
        admission loop already builds — the Monitor's utilization sampling
        rides the pump's own bookkeeping instead of a second inventory
        scan per tick (which matters once the autostep engine has the
        pump looping at step cadence)."""
        if not TRACER.enabled:
            return self._pump_body(now, sample_util)
        with TRACER.span("sched.pump", cat="sched") as sp:
            admitted = self._pump_body(now, sample_util)
            sp.set(admitted=len(admitted))
            return admitted

    def _pump_body(self, now: Optional[float],
                   sample_util: bool) -> List[str]:
        admitted: List[str] = []
        # `now or time.time()` would swap wall clock in for model-time 0.0
        # and corrupt wait accounting under a simulated clock
        now = now if now is not None else time.time()
        self._prune_waitlist()
        while True:
            progress = False
            held = self._held_chips_by_user()
            used = self._chip_seconds_by_user()
            for unit in self._units(now, held):
                if self._quota_blocked(unit, held, used) is not None:
                    continue     # stays waitlisted until usage drops
                if not self._unit_fits(unit):
                    continue
                if self._admit_unit(unit, now) is None:
                    continue
                for e in unit:
                    del self.waitlist[e.app_id]
                    wait_s = max(0.0, now - e.enqueued_at)
                    # a resume is not a second SLO outcome: the job's
                    # deadline hit/miss was recorded at first admission
                    slack = (None if e.deadline_at is None or e.preempted
                             else e.deadline_at - now)
                    blk = self.ctl.registry.get(e.app_id)
                    self.ctl.bus.publish(
                        "admitted", app_id=e.app_id, block_id=blk.block_id,
                        user=e.user, now=now, wait_s=wait_s,
                        priority=e.priority, slack_s=slack,
                        resumed=e.preempted)
                    admitted.append(e.app_id)
                progress = True
                break    # holdings changed: recompute fair-share order
            if not progress and self.preemption_enabled:
                progress = self._preempt_for_waiters(now, held, used)
            if not progress:
                break
        if sample_util:
            # final-iteration `held` is current (that iteration admitted
            # nothing); its sum is exactly the chips blocks hold right now
            self.ctl.bus.publish(
                "utilization", now=now,
                used_chips=sum(held.values()),
                total_chips=self.ctl.total_chips())
        return admitted

    # ----------------------------------------------------------- preemption
    def _preempt_for_waiters(self, now: Optional[float] = None,
                             held: Optional[Dict[str, int]] = None,
                             used: Optional[Dict[str, float]] = None) -> bool:
        """Evict running block(s) so the best-ranked admission unit that
        cannot currently fit gets room.  Returns True when victims were
        suspended (the caller's next fair-share pass then admits the
        unit)."""
        now = now if now is not None else time.time()
        held = held if held is not None else self._held_chips_by_user()
        used = used if used is not None else self._chip_seconds_by_user()
        for unit in self._units(now, held):
            if self._quota_blocked(unit, held, used) is not None:
                continue     # never evict for a unit quota forbids admitting
            victims = self._select_victims(unit, held, used, now)
            if not victims:
                continue
            label = (unit[0].gang_id if len(unit) > 1 else unit[0].app_id)
            with TRACER.span("sched.evict", cat="sched", target=label,
                             victims=len(victims)):
                for victim in victims:
                    self.ctl.preempt(
                        victim, reason=f"evicted for {label} "
                                       f"(priority {unit[0].priority})",
                        now=now)
            return True
        return False

    def _victim_remaining_s(self, blk) -> float:
        """Estimated service time the victim still needs (declared
        ``est_steps`` minus steps run, times its EWMA step time); 0.0 when
        undeclared — its deadline slack then stands in alone."""
        est = blk.request.est_steps
        if not est or blk.block_id is None:
            return 0.0
        step_s = self.ctl.monitor.step_time_estimate(blk.block_id)
        if not step_s:
            return 0.0
        done = self.ctl.monitor.steps_done(blk.block_id)
        return max(0, est - done) * step_s

    def _select_victims(self, unit: List[QueueEntry],
                        held: Dict[str, int],
                        used: Dict[str, float],
                        now: Optional[float] = None) -> List[str]:
        """Victim choice for an admission unit: among running/active blocks
        of *strictly* lower priority than every member (the no-churn guard
        — equal-priority blocks can never evict each other in a loop),
        ranked by the policy's victim key — quota-busting blocks first,
        then (priority, deadline headroom desc, progress-lost = steps since
        the victim's last checkpoint, held chips): least important, least
        SLO-pressured, cheapest-to-stop, smallest.  A victim the eviction
        would push into a deadline miss it would not otherwise have had
        (on-track, headroom under the policy margin) is exempt entirely.
        Prefer a single victim whose chips let the whole unit
        fit; a footprint spanning several smaller blocks gets the shortest
        rank-order prefix of victims that frees enough contiguous room for
        *every* member (gang admission evicts for the whole gang or not at
        all).  Returns [] (and nothing is evicted) when even the full
        eligible set would not make the unit fit."""
        now = now if now is not None else time.time()
        reg = self.ctl.registry
        part = self.ctl.partitioner
        floor = min(e.priority for e in unit)
        footprint = [(e.n_chips, e.pod) for e in unit]
        eligible: List[Tuple[Tuple, str, str]] = []
        for app_id in reg.by_state(BlockState.RUNNING, BlockState.ACTIVE):
            blk = reg.get(app_id)
            if blk.grant is None or blk.request.priority >= floor:
                continue
            remaining_s = self._victim_remaining_s(blk)
            if self.policy.victim_deadline_exempt(blk.deadline_at, now,
                                                  remaining_s):
                continue
            rt = self.ctl.runtimes.get(app_id)
            progress_lost = int(getattr(rt, "progress_lost", 0) or 0)
            over = self.policy.over_quota(
                blk.request.user, held.get(blk.request.user, 0),
                used.get(blk.request.user, 0.0))
            key = self.policy.victim_key(
                over, blk.request.priority, progress_lost,
                blk.grant.n_chips,
                headroom_s=self.policy.victim_headroom(
                    blk.deadline_at, now, remaining_s))
            eligible.append((key, app_id, blk.grant.block_id))
        eligible.sort()
        for _, app_id, block_id in eligible:
            if part.can_fit_many(footprint, [block_id]):
                return [app_id]
        chosen: List[str] = []
        freed: List[str] = []
        for _, app_id, block_id in eligible:
            chosen.append(app_id)
            freed.append(block_id)
            if part.can_fit_many(footprint, freed):
                break
        else:
            return []
        # prune: a rank-order prefix can include victims whose chips don't
        # actually contribute to the fit (wrong pod / outside the found
        # rectangle) — never evict a block the waiter doesn't need
        for app_id, block_id in list(zip(chosen, freed))[:-1]:
            without = [b for b in freed if b != block_id]
            if part.can_fit_many(footprint, without):
                chosen.remove(app_id)
                freed.remove(block_id)
        return chosen

    def queue_depth(self) -> int:
        self._prune_waitlist()
        return len(self.waitlist)

    # ------------------------------------------------------------- dispatch
    @runtime_check.guard_serialized("control-plane")
    def run_dispatch(self, targets: Union[int, Mapping[str, int]],
                     max_inflight: Optional[int] = None,
                     ) -> Dict[str, List[Dict[str, float]]]:
        """Event-driven stepping of RUNNING blocks.

        ``targets`` is either a per-app step count or a single int applied
        to every RUNNING block.  Completions feed the Monitor as they land.
        """
        reg = self.ctl.registry
        if isinstance(targets, int):
            targets = {a: targets for a in reg.by_state(BlockState.RUNNING)}
        runtimes = {a: self.ctl.runtimes[a] for a in targets
                    if a in self.ctl.runtimes}

        def on_step(app_id: str, rec: Dict[str, float]) -> None:
            blk = reg.get(app_id)
            metrics = {k: v for k, v in rec.items() if k != "step_s"}
            self.ctl.bus.publish("step", app_id=app_id,
                                 block_id=blk.block_id,
                                 user=blk.request.user,
                                 step_s=rec["step_s"],
                                 n_chips=blk.grant.n_chips,
                                 metrics=metrics or None)

        # `max_inflight or ...` would turn an explicit 0 ("dispatch
        # nothing") into the scheduler default — same falsy-zero trap as
        # the model-time `now` parameters
        return drive(runtimes, targets,
                     max_inflight=(max_inflight if max_inflight is not None
                                   else self.max_inflight),
                     on_step=on_step)


# ---------------------------------------------------------------- simulation
class SimRuntime(InflightWindow):
    """Wall-clock model of a block runtime: steps are serially dependent
    within the block (each becomes ready ``step_s`` after its predecessor)
    and concurrent across blocks — the paper's disjoint-sub-mesh model.

    Shares the in-flight window protocol (``dispatch``/``poll``/``drain``)
    with BlockRuntime via InflightWindow; a completion token here is the
    model-time interval ``(start, ready_at)``.  Also models the preemption
    surface — periodic checkpoints every ``ckpt_every`` steps feeding
    ``progress_lost``, plus ``suspend``/``resume`` — so scheduler tests and
    benchmarks exercise the full eviction path without devices."""

    def __init__(self, step_s: float, ckpt_every: int = 0):
        self.step_s = step_s
        self.step_count = 0
        self.ckpt_every = ckpt_every       # 0 = checkpoint only on suspend
        self.last_saved_step = 0
        self.suspended = False
        self._chain_free_at = 0.0          # when the serial chain is idle
        self._init_window()

    # --------------------------------------------- InflightWindow hooks
    def _launch(self):
        now = time.perf_counter()
        start = max(now, self._chain_free_at)
        self._chain_free_at = start + self.step_s
        return (start, self._chain_free_at)

    def _token_ready(self, token) -> bool:
        return time.perf_counter() >= token[1]

    def _token_wait(self, token) -> None:
        now = time.perf_counter()
        if now < token[1]:
            time.sleep(token[1] - now)

    def _completion_record(self, dispatch_t: float, token) -> Dict[str, float]:
        start, ready_at = token
        self.step_count += 1
        if self.ckpt_every and self.step_count % self.ckpt_every == 0:
            self.last_saved_step = self.step_count   # periodic checkpoint
        # model execution time only (not wait-behind-predecessor): the same
        # serial-chain accounting BlockRuntime's completions use
        return {"step_s": ready_at - start}

    # ------------------------------------------------------- preemption
    @property
    def progress_lost(self) -> int:
        return max(0, self.step_count - self.last_saved_step)

    def suspend(self) -> Dict[str, float]:
        drained = self.drain()
        self.last_saved_step = self.step_count   # graceful synchronous save
        self.suspended = True
        return {"step": self.step_count, "drained_steps": len(drained)}

    def resume(self, grant, devices) -> int:
        assert self.suspended, "resume() is only legal after suspend()"
        self.suspended = False
        self._chain_free_at = 0.0
        return self.step_count

    def step(self) -> Dict[str, float]:
        """Synchronous step (old round-robin semantics)."""
        self.dispatch()
        return self.poll(block=True)[0]
