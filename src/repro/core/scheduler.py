"""BlockScheduler — admission queue + event-driven multi-block dispatch.

The paper's public-cluster property (and its follow-ups: "Multi and
Independent Block Approach", arXiv:0708.3446; openPC, arXiv:1012.2499) is
that one shared master absorbs *competing* block requests automatically.
The seed controller had neither piece: ``Partitioner.allocate`` raised
``AllocationError`` when the pod was full, and ``step_all`` round-robined
with a fixed-order ``block_until_ready`` so one slow block gated every
other block's next dispatch on the host thread.

Two subsystems fix that:

* **Admission queue** — ``submit()`` tries to allocate immediately; when
  the pod cannot fit the request the application is parked on a waitlist
  (registry state QUEUED) instead of raising.  ``pump()`` re-examines the
  waitlist whenever capacity frees (block expiry via ``tick()``, explicit
  ``expire()``, elastic shrink) and admits entries in fair-share order:
  priority first, then fewest currently-held chips per user, then FIFO.
  Entries that fit are backfilled past ones that don't, so a large stuck
  request doesn't idle chips a small request could use.

* **Event-driven dispatch** — ``drive()`` keeps up to ``max_inflight``
  async steps outstanding per block (dispatch-depth backpressure) and
  harvests completions in whatever order the devices finish, blocking only
  when every window is full and nothing is ready.  A slow block therefore
  never stalls a fast block's next dispatch on the host thread.

* **Checkpoint-backed preemption** — when a waitlisted entry outranks a
  running block (strictly higher priority) and no free rectangle fits it,
  ``pump()`` picks a victim by (priority asc, progress-lost = steps since
  its last checkpoint asc, held chips asc), suspends it (drain in-flight →
  synchronous checkpoint → release chips) and admits the waiter.  The
  victim re-enters the waitlist *ahead of its fair-share class* and is
  auto-resumed by ``tick()`` — on a possibly different chip set / mesh
  geometry — as capacity frees.  The strict-priority requirement is the
  no-churn guard: two equal-priority blocks can never evict each other in
  a loop.

``SimRuntime`` is a wall-clock model of a block's serial step chain used
by the scheduler benchmarks and tests (no devices required).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Mapping, Optional, Union

from repro.core.block import BlockGrant, BlockState
from repro.core.inflight import InflightWindow
from repro.core.partition import AllocationError


@dataclasses.dataclass
class QueueEntry:
    app_id: str
    user: str
    n_chips: int
    priority: int
    enqueued_at: float
    seq: int                          # registry FIFO sequence number
    pod: Optional[int] = None
    job: Optional[object] = None      # JobSpec -> auto activate+run on admit
    preempted: bool = False           # evicted victim awaiting auto-resume


# ----------------------------------------------------------------- dispatch
def drive(runtimes: Mapping[str, object], targets: Mapping[str, int],
          max_inflight: int = 2,
          on_step: Optional[Callable[[str, Dict[str, float]], None]] = None,
          ) -> Dict[str, List[Dict[str, float]]]:
    """Run each runtime for ``targets[app_id]`` steps, event-driven.

    Runtimes need the in-flight window protocol: ``dispatch()``,
    ``poll(block=False)``, ``inflight_depth``, ``oldest_dispatch_t()``.
    Steps are dispatched while a
    block's window has room and harvested as they finish; when every window
    is full and nothing is ready, we block on the runtime with the oldest
    outstanding dispatch rather than spinning.
    """
    remaining = {a: int(n) for a, n in targets.items()
                 if a in runtimes and n > 0}
    out: Dict[str, List[Dict[str, float]]] = {a: [] for a in remaining}

    def harvest(app_id: str, block: bool = False) -> int:
        n = 0
        for rec in runtimes[app_id].poll(block=block):
            out[app_id].append(rec)
            if on_step is not None:
                on_step(app_id, rec)
            n += 1
        return n

    while True:
        dispatched = 0
        for app_id in list(remaining):
            rt = runtimes[app_id]
            while remaining[app_id] > 0 and rt.inflight_depth < max_inflight:
                rt.dispatch()
                remaining[app_id] -= 1
                dispatched += 1
        harvested = sum(harvest(a) for a in out)
        busy = [a for a in out if runtimes[a].inflight_depth > 0]
        if not busy and all(v == 0 for v in remaining.values()):
            return out
        if dispatched == 0 and harvested == 0 and busy:
            # every window full / work pending: wait on the oldest dispatch
            oldest = min(busy, key=lambda a: runtimes[a].oldest_dispatch_t())
            harvest(oldest, block=True)


class BlockScheduler:
    """Admission queue + dispatch loop over a ClusterController."""

    def __init__(self, ctl, max_inflight: int = 2,
                 preemption_enabled: bool = True):
        self.ctl = ctl
        self.max_inflight = max_inflight
        self.preemption_enabled = preemption_enabled
        self.waitlist: Dict[str, QueueEntry] = {}   # app_id -> entry

    # ------------------------------------------------------------ admission
    def submit(self, app_id: str, job: Optional[object] = None,
               priority: Optional[int] = None,
               pod: Optional[int] = None) -> Optional[BlockGrant]:
        """Admit a registered application now, or park it on the waitlist.

        Returns the grant on immediate admission, None when queued.  With a
        ``job`` the block is auto-confirmed, activated and run on admission
        (immediately or later from ``pump()``), so a caller can fire
        arbitrary request traffic at the cluster and let it absorb the load.
        """
        blk = self.ctl.registry.get(app_id)
        if not self.ctl.partitioner.shape_possible(blk.request.n_chips):
            # never admissible (invalid size / exceeds pod geometry):
            # waitlisting would park it forever, so reject up front
            self.ctl.registry.deny(
                app_id, f"{blk.request.n_chips} chips can never fit this pod")
            return None
        # persist overrides onto the request: after admission the request is
        # the canonical record, and preemption (victim selection, requeue)
        # must see the same priority/pod that admission used
        if priority is not None:
            blk.request.priority = priority
        if pod is not None:
            blk.request.pod = pod
        entry = QueueEntry(
            app_id=app_id, user=blk.request.user,
            n_chips=blk.request.n_chips,
            priority=blk.request.priority,
            enqueued_at=time.time(), seq=0, pod=blk.request.pod, job=job)
        # admit the existing waitlist first so a newcomer can't jump a
        # higher-ranked entry that also fits
        self.pump()
        if not self.waitlist:
            grant = self._try_admit(entry)
            if grant is not None:
                return grant
        entry.seq = self.ctl.registry.enqueue(
            app_id, f"waitlisted: {entry.n_chips} chips unavailable")
        entry.enqueued_at = self.ctl.registry.get(app_id).queued_at
        self.waitlist[app_id] = entry
        self.ctl.monitor.record_enqueue(app_id)
        # backfill: the newcomer may fit even though higher-ranked entries
        # don't (pump admits in fair-share order with skip-past)
        self.pump()
        if app_id not in self.waitlist:
            return self.ctl.registry.get(app_id).grant
        return None

    def _held_chips_by_user(self) -> Dict[str, int]:
        held: Dict[str, int] = {}
        reg = self.ctl.registry
        for app_id in reg.by_state(BlockState.APPROVED, BlockState.CONFIRMED,
                                   BlockState.ACTIVE, BlockState.RUNNING,
                                   BlockState.DONE):
            blk = reg.get(app_id)
            if blk.grant:
                held[blk.request.user] = (held.get(blk.request.user, 0)
                                          + blk.grant.n_chips)
        return held

    def ordered_waitlist(self) -> List[QueueEntry]:
        """Fair-share admission order: priority desc, then preempted victims
        ahead of their fair-share class (they already earned their slot once
        and paid an eviction), then fewest chips the user currently holds,
        then FIFO."""
        held = self._held_chips_by_user()
        return sorted(self.waitlist.values(),
                      key=lambda e: (-e.priority, not e.preempted,
                                     held.get(e.user, 0), e.seq))

    def requeue_preempted(self, app_id: str, seq: int) -> None:
        """Park an evicted block on the waitlist for auto-resume (the
        registry has already transitioned it to PREEMPTED and assigned the
        queue sequence number)."""
        blk = self.ctl.registry.get(app_id)
        self.waitlist[app_id] = QueueEntry(
            app_id=app_id, user=blk.request.user,
            n_chips=blk.grant.n_chips if blk.grant else blk.request.n_chips,
            priority=blk.request.priority, enqueued_at=blk.queued_at,
            seq=seq, pod=blk.request.pod, preempted=True)
        self.ctl.monitor.record_enqueue(app_id)

    def _try_admit(self, entry: QueueEntry) -> Optional[BlockGrant]:
        try:
            if entry.preempted:
                # victim re-admission: restore, don't re-grant — the block
                # keeps its identity/token and resumes from its checkpoint
                return self.ctl.resume(entry.app_id)
            grant = self.ctl.grant_block(entry.app_id, entry.n_chips,
                                         pod=entry.pod)
        except AllocationError:
            return None
        if entry.job is not None:
            self.ctl.confirm(entry.app_id, grant.token)
            self.ctl.activate(entry.app_id, entry.job)
            self.ctl.run(entry.app_id)
        return grant

    def _prune_waitlist(self) -> None:
        """Drop entries whose application left the QUEUED (or, for evicted
        victims, PREEMPTED) state behind the scheduler's back (admin deny,
        forced expiry): admitting them would be an illegal transition and
        would leak their chips."""
        for app_id, entry in list(self.waitlist.items()):
            expect = (BlockState.PREEMPTED if entry.preempted
                      else BlockState.QUEUED)
            if self.ctl.registry.get(app_id).state != expect:
                del self.waitlist[app_id]
                self.ctl.monitor.record_dequeue(app_id)

    def pump(self, now: Optional[float] = None) -> List[str]:
        """Admit waitlisted applications that now fit, in fair-share order
        (with backfill past entries that still don't fit).  When nothing
        fits and preemption is enabled, evict the cheapest sufficient set
        of strictly-lower-priority running blocks per round to make room
        for the best-ranked waiter.  Called from ``tick()`` and after
        every expiry/shrink."""
        admitted: List[str] = []
        now = now or time.time()
        self._prune_waitlist()
        while True:
            progress = False
            for entry in self.ordered_waitlist():
                if not self.ctl.partitioner.can_fit(entry.n_chips, entry.pod):
                    continue
                grant = self._try_admit(entry)
                if grant is None:
                    continue
                del self.waitlist[entry.app_id]
                wait_s = max(0.0, now - entry.enqueued_at)
                self.ctl.monitor.record_admission(entry.app_id, wait_s,
                                                  priority=entry.priority)
                if entry.preempted:
                    self.ctl.monitor.record_resume(entry.app_id, wait_s)
                admitted.append(entry.app_id)
                progress = True
                break    # holdings changed: recompute fair-share order
            if not progress and self.preemption_enabled:
                progress = self._preempt_for_waiters()
            if not progress:
                return admitted

    # ----------------------------------------------------------- preemption
    def _preempt_for_waiters(self) -> bool:
        """Evict running block(s) so the best-ranked waiter that cannot
        currently fit gets room.  Returns True when victims were suspended
        (the caller's next fair-share pass then admits the waiter)."""
        for entry in self.ordered_waitlist():
            victims = self._select_victims(entry)
            if not victims:
                continue
            for victim in victims:
                self.ctl.preempt(
                    victim, reason=f"evicted for {entry.app_id} "
                                   f"(priority {entry.priority})")
            return True
        return False

    def _select_victims(self, entry: QueueEntry) -> List[str]:
        """Victim choice for ``entry``: among running/active blocks of
        *strictly* lower priority (the no-churn guard — equal-priority
        blocks can never evict each other in a loop), ranked by (priority,
        progress-lost = steps since the victim's last checkpoint, held
        chips) — least important, cheapest-to-stop, smallest first.  Prefer
        a single victim whose chips let the entry fit; a waiter whose
        footprint spans several smaller blocks gets the shortest rank-order
        prefix of victims that frees enough contiguous room.  Returns []
        (and nothing is evicted) when even the full eligible set would not
        make the entry fit."""
        reg = self.ctl.registry
        part = self.ctl.partitioner
        eligible = []
        for app_id in reg.by_state(BlockState.RUNNING, BlockState.ACTIVE):
            blk = reg.get(app_id)
            if blk.grant is None or blk.request.priority >= entry.priority:
                continue
            rt = self.ctl.runtimes.get(app_id)
            progress_lost = int(getattr(rt, "progress_lost", 0) or 0)
            eligible.append((blk.request.priority, progress_lost,
                             blk.grant.n_chips, app_id, blk.grant.block_id))
        eligible.sort()
        for _, _, _, app_id, block_id in eligible:
            if part.can_fit_excluding(entry.n_chips, [block_id], entry.pod):
                return [app_id]
        chosen: List[str] = []
        freed: List[str] = []
        for _, _, _, app_id, block_id in eligible:
            chosen.append(app_id)
            freed.append(block_id)
            if part.can_fit_excluding(entry.n_chips, freed, entry.pod):
                break
        else:
            return []
        # prune: a rank-order prefix can include victims whose chips don't
        # actually contribute to the fit (wrong pod / outside the found
        # rectangle) — never evict a block the waiter doesn't need
        for app_id, block_id in list(zip(chosen, freed))[:-1]:
            without = [b for b in freed if b != block_id]
            if part.can_fit_excluding(entry.n_chips, without, entry.pod):
                chosen.remove(app_id)
                freed.remove(block_id)
        return chosen

    def queue_depth(self) -> int:
        self._prune_waitlist()
        return len(self.waitlist)

    # ------------------------------------------------------------- dispatch
    def run_dispatch(self, targets: Union[int, Mapping[str, int]],
                     max_inflight: Optional[int] = None,
                     ) -> Dict[str, List[Dict[str, float]]]:
        """Event-driven stepping of RUNNING blocks.

        ``targets`` is either a per-app step count or a single int applied
        to every RUNNING block.  Completions feed the Monitor as they land.
        """
        reg = self.ctl.registry
        if isinstance(targets, int):
            targets = {a: targets for a in reg.by_state(BlockState.RUNNING)}
        runtimes = {a: self.ctl.runtimes[a] for a in targets
                    if a in self.ctl.runtimes}

        def on_step(app_id: str, rec: Dict[str, float]) -> None:
            blk = reg.get(app_id)
            self.ctl.monitor.record_step(blk.block_id, rec["step_s"],
                                         blk.grant.n_chips)

        return drive(runtimes, targets,
                     max_inflight=max_inflight or self.max_inflight,
                     on_step=on_step)


# ---------------------------------------------------------------- simulation
class SimRuntime(InflightWindow):
    """Wall-clock model of a block runtime: steps are serially dependent
    within the block (each becomes ready ``step_s`` after its predecessor)
    and concurrent across blocks — the paper's disjoint-sub-mesh model.

    Shares the in-flight window protocol (``dispatch``/``poll``/``drain``)
    with BlockRuntime via InflightWindow; a completion token here is the
    model-time interval ``(start, ready_at)``.  Also models the preemption
    surface — periodic checkpoints every ``ckpt_every`` steps feeding
    ``progress_lost``, plus ``suspend``/``resume`` — so scheduler tests and
    benchmarks exercise the full eviction path without devices."""

    def __init__(self, step_s: float, ckpt_every: int = 0):
        self.step_s = step_s
        self.step_count = 0
        self.ckpt_every = ckpt_every       # 0 = checkpoint only on suspend
        self.last_saved_step = 0
        self.suspended = False
        self._chain_free_at = 0.0          # when the serial chain is idle
        self._init_window()

    # --------------------------------------------- InflightWindow hooks
    def _launch(self):
        now = time.perf_counter()
        start = max(now, self._chain_free_at)
        self._chain_free_at = start + self.step_s
        return (start, self._chain_free_at)

    def _token_ready(self, token) -> bool:
        return time.perf_counter() >= token[1]

    def _token_wait(self, token) -> None:
        now = time.perf_counter()
        if now < token[1]:
            time.sleep(token[1] - now)

    def _completion_record(self, dispatch_t: float, token) -> Dict[str, float]:
        start, ready_at = token
        self.step_count += 1
        if self.ckpt_every and self.step_count % self.ckpt_every == 0:
            self.last_saved_step = self.step_count   # periodic checkpoint
        # model execution time only (not wait-behind-predecessor): the same
        # serial-chain accounting BlockRuntime's completions use
        return {"step_s": ready_at - start}

    # ------------------------------------------------------- preemption
    @property
    def progress_lost(self) -> int:
        return max(0, self.step_count - self.last_saved_step)

    def suspend(self) -> Dict[str, float]:
        drained = self.drain()
        self.last_saved_step = self.step_count   # graceful synchronous save
        self.suspended = True
        return {"step": self.step_count, "drained_steps": len(drained)}

    def resume(self, grant, devices) -> int:
        assert self.suspended, "resume() is only legal after suspend()"
        self.suspended = False
        self._chain_free_at = 0.0
        return self.step_count

    def step(self) -> Dict[str, float]:
        """Synchronous step (old round-robin semantics)."""
        self.dispatch()
        return self.poll(block=True)[0]
