"""Torus-aware block partitioner: carve disjoint contiguous sub-meshes out of
the pod complex, track chip health, support elastic resize.

Contiguity is the TPU-native isolation property (DESIGN.md §2): a contiguous
rectangle owns all ICI links in its interior, so concurrent blocks share zero
fabric.  The allocator therefore only hands out axis-aligned rectangles
(first-fit, smallest-waste), never fragments.
"""
from __future__ import annotations

import dataclasses
import math
import threading
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.core.topology import Coord, Topology, rect_coords


class AllocationError(RuntimeError):
    pass


def mesh_shape_for(n_chips: int) -> Tuple[int, int]:
    """(data, model) factorization: closest-to-square, model <= 16."""
    best = (n_chips, 1)
    for m in range(1, min(n_chips, 16) + 1):
        if n_chips % m == 0:
            d = n_chips // m
            if abs(math.log(d / m)) <= abs(math.log(best[0] / best[1])):
                best = (d, m)
    return best


@dataclasses.dataclass
class ChipInfo:
    coord: Coord
    healthy: bool = True
    owner: Optional[str] = None      # block_id or None (free)


class Partitioner:
    """Thread-safe chip inventory + contiguous rectangle allocator."""

    def __init__(self, topo: Topology):
        self.topo = topo
        self._lock = threading.RLock()
        self.chips: Dict[Coord, ChipInfo] = {
            c: ChipInfo(c) for c in topo.coords()}

    # ----------------------------------------------------------- inventory
    def free_chips(self, pod: Optional[int] = None) -> List[Coord]:
        with self._lock:
            return [c for c, info in self.chips.items()
                    if info.owner is None and info.healthy
                    and (pod is None or c[0] == pod)]

    def owner_of(self, coord: Coord) -> Optional[str]:
        with self._lock:
            return self.chips[coord].owner

    def mark_unhealthy(self, coord: Coord) -> Optional[str]:
        """Chip failure: returns the owning block_id (to be failed over)."""
        with self._lock:
            info = self.chips[coord]
            info.healthy = False
            return info.owner

    def mark_healthy(self, coord: Coord) -> None:
        with self._lock:
            self.chips[coord].healthy = True

    # ------------------------------------------------------------ allocate
    def _rect_free(self, pod: int, x0: int, y0: int, w: int, h: int) -> bool:
        if x0 + w > self.topo.pod_x or y0 + h > self.topo.pod_y:
            return False
        for c in rect_coords(pod, x0, y0, w, h):
            info = self.chips[c]
            if info.owner is not None or not info.healthy:
                return False
        return True

    def _candidate_shapes(self, n_chips: int) -> List[Tuple[int, int]]:
        if n_chips < 1:
            raise AllocationError(f"invalid block size {n_chips}")
        shapes = []
        for w in range(1, self.topo.pod_x + 1):
            if n_chips % w == 0 and n_chips // w <= self.topo.pod_y:
                shapes.append((w, n_chips // w))
        if not shapes:
            raise AllocationError(f"{n_chips} chips has no rectangular shape")
        # prefer near-square (best locality / bisection)
        shapes.sort(key=lambda s: abs(math.log(s[0] / s[1])))
        return shapes

    def _find_rect(self, n_chips: int, pod: Optional[int]
                   ) -> Optional[Tuple[int, int, int, int, int]]:
        """First free (pod, x0, y0, w, h) rectangle, or None.  Caller holds
        the lock (or accepts a racy dry-run answer, as can_fit does)."""
        shapes = self._candidate_shapes(n_chips)
        pods = [pod] if pod is not None else list(range(self.topo.n_pods))
        for p in pods:
            for w, h in shapes:
                for x0 in range(self.topo.pod_x - w + 1):
                    for y0 in range(self.topo.pod_y - h + 1):
                        if self._rect_free(p, x0, y0, w, h):
                            return (p, x0, y0, w, h)
        return None

    def allocate(self, n_chips: int, block_id: str,
                 pod: Optional[int] = None) -> List[Coord]:
        """First-fit contiguous rectangle of >= n_chips (exact when n_chips
        factors into a rectangle that fits; raises otherwise)."""
        with self._lock:
            found = self._find_rect(n_chips, pod)
            if found is not None:
                p, x0, y0, w, h = found
                coords = rect_coords(p, x0, y0, w, h)
                for c in coords:
                    self.chips[c].owner = block_id
                return coords
        raise AllocationError(
            f"no contiguous {n_chips}-chip rectangle free "
            f"(free={len(self.free_chips())})")

    def can_fit(self, n_chips: int, pod: Optional[int] = None) -> bool:
        """Admission dry-run: would ``allocate`` succeed right now?  Does not
        mutate the inventory."""
        with self._lock:
            try:
                return self._find_rect(n_chips, pod) is not None
            except AllocationError:
                return False

    def allocate_many(self, specs: Sequence[Tuple[int, str, Optional[int]]]
                      ) -> Dict[str, List[Coord]]:
        """Gang allocation: find a rectangle for *every* ``(n_chips,
        block_id, pod)`` spec under one lock hold, committing only when all
        fit.  On any failure every partial placement is rolled back and the
        inventory is bit-identical to before the call — the all-or-nothing
        property multi-block (gang) admission requires."""
        with self._lock:
            placed: Dict[str, List[Coord]] = {}
            try:
                for n_chips, block_id, pod in specs:
                    if block_id in placed:
                        raise AllocationError(
                            f"duplicate gang block id {block_id}")
                    found = self._find_rect(n_chips, pod)
                    if found is None:
                        raise AllocationError(
                            f"gang member {block_id} needs {n_chips} chips: "
                            f"no contiguous rectangle free")
                    coords = rect_coords(*found)
                    for c in coords:
                        self.chips[c].owner = block_id
                    placed[block_id] = coords
            except AllocationError:
                for coords in placed.values():
                    for c in coords:
                        self.chips[c].owner = None
                raise
            return placed

    def can_fit_many(self, specs: Sequence[Tuple[int, Optional[int]]],
                     freed_block_ids: Sequence[str] = ()) -> bool:
        """Gang admission dry-run (optionally a preemption what-if with
        ``freed_block_ids``' chips treated as free): would ``allocate_many``
        succeed right now?  Places each rectangle under temporary dry-run
        ownership so members can't double-count the same free region; the
        inventory is unchanged when this returns."""
        with self._lock:
            saved: Dict[Coord, str] = {}
            freed = set(freed_block_ids)
            for c, info in self.chips.items():
                if info.owner in freed:
                    saved[c] = info.owner
                    info.owner = None
            marked: List[Coord] = []
            ok = True
            try:
                for i, (n_chips, pod) in enumerate(specs):
                    try:
                        found = self._find_rect(n_chips, pod)
                    except AllocationError:
                        found = None
                    if found is None:
                        ok = False
                        break
                    for c in rect_coords(*found):
                        self.chips[c].owner = f"_dryrun_{i}"
                        marked.append(c)
            finally:
                for c in marked:
                    self.chips[c].owner = None
                for c, owner in saved.items():
                    self.chips[c].owner = owner
            return ok

    def can_fit_excluding(self, n_chips: int, freed_block_ids: Sequence[str],
                          pod: Optional[int] = None) -> bool:
        """Preemption what-if for a single rectangle: would ``allocate``
        succeed if these blocks' chips were freed first?  The inventory is
        unchanged when this returns."""
        return self.can_fit_many([(n_chips, pod)], freed_block_ids)

    def shape_possible(self, n_chips: int) -> bool:
        """Could this request *ever* fit (valid size with a rectangular
        shape inside one pod)?  False means waitlisting it is pointless."""
        try:
            self._candidate_shapes(n_chips)
            return True
        except AllocationError:
            return False

    def free_capacity(self, pod: Optional[int] = None) -> int:
        """Free healthy chips (upper bound on what can be admitted; actual
        admission also needs a contiguous rectangle — see can_fit)."""
        return len(self.free_chips(pod))

    def retag(self, old_id: str, new_id: str) -> int:
        """Atomically re-assign every chip owned by ``old_id`` to ``new_id``
        (grant finalization: pending reservation -> real block id).  Holding
        the lock across the whole sweep means a concurrent allocate can never
        observe the chips as free mid-retag."""
        with self._lock:
            n = 0
            for info in self.chips.values():
                if info.owner == old_id:
                    info.owner = new_id
                    n += 1
            return n

    def release(self, block_id: str) -> int:
        with self._lock:
            n = 0
            for info in self.chips.values():
                if info.owner == block_id:
                    info.owner = None
                    n += 1
            return n

    def owned_by(self, block_id: str) -> List[Coord]:
        with self._lock:
            return [c for c, info in self.chips.items()
                    if info.owner == block_id]

    def placements(self) -> Dict[str, List[Coord]]:
        """Snapshot of current ownership: ``{block_id: coords}``.  Feeds the
        federation placer's interference scoring (core/interference.py)."""
        with self._lock:
            out: Dict[str, List[Coord]] = {}
            for c, info in self.chips.items():
                if info.owner is not None:
                    out.setdefault(info.owner, []).append(c)
            return out

    def suspend_owners(self, block_ids: Sequence[str]) -> Dict[Coord, str]:
        """Temporarily free these blocks' chips for a preemption what-if and
        return the saved ownership for ``restore_owners``.  The federation's
        gang dry-run uses this pair instead of reaching into ``chips``."""
        with self._lock:
            ids = set(block_ids)
            saved: Dict[Coord, str] = {}
            for c, info in self.chips.items():
                if info.owner in ids:
                    saved[c] = info.owner
                    info.owner = None
            return saved

    def restore_owners(self, saved: Dict[Coord, str]) -> None:
        with self._lock:
            for c, owner in saved.items():
                self.chips[c].owner = owner

    # ------------------------------------------------------------- elastic
    def resize(self, block_id: str, new_n_chips: int,
               pod: Optional[int] = None) -> List[Coord]:
        """Elastic grow/shrink, atomic under one lock hold: the replacement
        rectangle is searched with the block's *own* chips treated as free
        — so growing 4→8 in place works whenever the block's rectangle plus
        adjacent free chips form a valid 8-rect — and ownership flips
        old→new only after a rectangle is found.  On failure the block
        keeps its old chips; there is never a window where it holds
        nothing."""
        with self._lock:
            mine = [c for c, info in self.chips.items()
                    if info.owner == block_id]
            for c in mine:
                self.chips[c].owner = None
            found = None
            try:
                found = self._find_rect(new_n_chips, pod)
            finally:
                if found is None:
                    for c in mine:
                        self.chips[c].owner = block_id
            if found is None:
                raise AllocationError(
                    f"no contiguous {new_n_chips}-chip rectangle for "
                    f"resize of {block_id} (even counting its own chips)")
            coords = rect_coords(*found)
            for c in coords:
                self.chips[c].owner = block_id
            return coords

    # ---------------------------------------------------------- invariants
    def check_invariants(self) -> None:
        """Every chip has <= 1 owner; owners' chip sets are disjoint (by
        construction of the map, but kept as an explicit verifiable claim —
        the paper's 'interferences completely avoided')."""
        with self._lock:
            seen: Dict[str, Set[Coord]] = {}
            for c, info in self.chips.items():
                if info.owner is not None:
                    seen.setdefault(info.owner, set()).add(c)
            ids = list(seen)
            for i in range(len(ids)):
                for j in range(i + 1, len(ids)):
                    inter = seen[ids[i]] & seen[ids[j]]
                    assert not inter, f"blocks {ids[i]},{ids[j]} share {inter}"
