"""Shared in-flight dispatch window — the protocol ``scheduler.drive``
targets.

Both the real per-tenant engine (``BlockRuntime``) and the wall-clock
simulation (``SimRuntime``) keep a bounded window of asynchronously
dispatched steps and harvest completions oldest-first.  The window
bookkeeping (depth, oldest-dispatch ordering, poll/drain loop, serial-chain
step accounting) is identical in both; only *what* a step is differs.
Subclasses implement three hooks:

* ``_launch() -> token``     — start one async step, return a completion
  token (a jax array whose readiness signals completion, or a model-time
  tuple for the simulator).
* ``_token_ready(token)``    — has the step completed (non-blocking)?
* ``_token_wait(token)``     — block until the step completes.

and may override ``_completion_record(dispatch_t, token)`` when wall-clock
measurement is not the right accounting (the simulator reports model time).

``step_s`` accounting: steps within a block form a serial chain, so each
completion is measured from max(its dispatch, the previous step's observed
completion) — counting each step from its own dispatch would bill the wait
behind its predecessor twice at dispatch depth > 1 (inflating EWMA/
straggler/chip-second accounting by ~the window depth).
"""
from __future__ import annotations

import collections
import time
from typing import Any, Deque, Dict, List, Tuple


class InflightWindow:
    """Mixin: bounded async dispatch window with oldest-first harvesting."""

    _inflight: Deque[Tuple[float, Any]]
    _last_ready_t: float

    def _init_window(self) -> None:
        # (dispatch wall-time, completion token) per step not yet observed
        self._inflight = collections.deque()
        self._last_ready_t = 0.0

    # ------------------------------------------------------------- hooks
    def _launch(self) -> Any:
        raise NotImplementedError

    def _token_ready(self, token: Any) -> bool:
        raise NotImplementedError

    def _token_wait(self, token: Any) -> None:
        raise NotImplementedError

    def _completion_record(self, dispatch_t: float,
                           token: Any) -> Dict[str, float]:
        now = time.perf_counter()
        rec = {"step_s": now - max(dispatch_t, self._last_ready_t)}
        self._last_ready_t = now
        return rec

    # ---------------------------------------------------------- protocol
    @property
    def inflight_depth(self) -> int:
        return len(self._inflight)

    def oldest_dispatch_t(self) -> float:
        """Dispatch wall-time of the oldest in-flight step (the scheduler
        blocks on the runtime with the smallest value when every window is
        full).  +inf when nothing is in flight."""
        return self._inflight[0][0] if self._inflight else float("inf")

    def dispatch(self) -> None:
        """Dispatch one async step and track its completion token.  The
        scheduler caps how many of these are outstanding per block
        (dispatch-depth backpressure) so host runahead stays bounded."""
        t0 = time.perf_counter()
        token = self._launch()
        self._inflight.append((t0, token))

    def poll(self, block: bool = False) -> List[Dict[str, float]]:
        """Harvest completed in-flight steps (oldest first).  With
        ``block=True``, waits for the head step if nothing is ready yet —
        the scheduler's no-busy-spin fallback."""
        out: List[Dict[str, float]] = []
        while self._inflight:
            t0, token = self._inflight[0]
            if block and not out:
                self._token_wait(token)
            if not self._token_ready(token):
                break
            self._inflight.popleft()
            out.append(self._completion_record(t0, token))
        return out

    def drain(self) -> List[Dict[str, float]]:
        """Block until every in-flight step has completed."""
        out: List[Dict[str, float]] = []
        while self._inflight:
            out.extend(self.poll(block=True))
        return out
