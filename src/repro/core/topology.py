"""Torus topology model for the pod complex.

A pod is a 2D (16x16) chip grid with ICI links between +/-x, +/-y neighbors
(wraparound at the pod boundary); pods are joined by a lower-bandwidth
inter-pod fabric (DCN).  This is the structural substrate for the paper's
interference question: which physical links does each tenant block use, and
do concurrent blocks share any.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, FrozenSet, Iterable, List, Sequence, Set, Tuple

Coord = Tuple[int, int, int]          # (pod, x, y)
Link = Tuple[Coord, Coord]            # canonical: min endpoint first


@dataclasses.dataclass(frozen=True)
class Topology:
    n_pods: int = 2
    pod_x: int = 16
    pod_y: int = 16
    wrap: bool = True                 # torus wraparound within a pod

    @property
    def n_chips(self) -> int:
        return self.n_pods * self.pod_x * self.pod_y

    def coords(self) -> List[Coord]:
        return [(p, x, y)
                for p in range(self.n_pods)
                for x in range(self.pod_x)
                for y in range(self.pod_y)]

    def chip_index(self, c: Coord) -> int:
        p, x, y = c
        return (p * self.pod_x + x) * self.pod_y + y

    def neighbors(self, c: Coord) -> List[Coord]:
        p, x, y = c
        out = []
        for dx, dy in ((1, 0), (-1, 0), (0, 1), (0, -1)):
            nx, ny = x + dx, y + dy
            if self.wrap:
                nx %= self.pod_x
                ny %= self.pod_y
                out.append((p, nx, ny))
            elif 0 <= nx < self.pod_x and 0 <= ny < self.pod_y:
                out.append((p, nx, ny))
        return out

    def canonical_link(self, a: Coord, b: Coord) -> Link:
        return (a, b) if a <= b else (b, a)

    def links(self) -> Set[Link]:
        out: Set[Link] = set()
        for c in self.coords():
            for n in self.neighbors(c):
                out.add(self.canonical_link(c, n))
        return out

    # ------------------------------------------------------------ routing
    def route(self, a: Coord, b: Coord) -> List[Link]:
        """Dimension-ordered shortest path (X then Y); inter-pod hops are
        represented as a single abstract 'pod link'."""
        links: List[Link] = []
        cur = a
        if a[0] != b[0]:
            # abstract DCN hop: (pod boundary)
            links.append(self.canonical_link(cur, (b[0], cur[1], cur[2])))
            cur = (b[0], cur[1], cur[2])

        def step_towards(v, t, size):
            if v == t:
                return v
            if not self.wrap:
                return v + 1 if t > v else v - 1
            fwd = (t - v) % size
            bwd = (v - t) % size
            return (v + 1) % size if fwd <= bwd else (v - 1) % size

        while cur[1] != b[1]:
            nxt = (cur[0], step_towards(cur[1], b[1], self.pod_x), cur[2])
            links.append(self.canonical_link(cur, nxt))
            cur = nxt
        while cur[2] != b[2]:
            nxt = (cur[0], cur[1], step_towards(cur[2], b[2], self.pod_y))
            links.append(self.canonical_link(cur, nxt))
            cur = nxt
        return links

    def ring_links(self, chips: Sequence[Coord]) -> Dict[Link, int]:
        """Links (with multiplicity) used by a ring collective over ``chips``
        in the given order — the traffic footprint of one all-reduce round."""
        use: Dict[Link, int] = {}
        n = len(chips)
        for i in range(n):
            for l in self.route(chips[i], chips[(i + 1) % n]):
                use[l] = use.get(l, 0) + 1
        return use


def rect_coords(pod: int, x0: int, y0: int, w: int, h: int) -> List[Coord]:
    return [(pod, x, y) for x in range(x0, x0 + w) for y in range(y0, y0 + h)]


def min_bisection_links(coords: Sequence[Coord], topo: Topology) -> int:
    """Number of topology links crossing the best axis-aligned bisection of
    the chip set (contiguous rectangles: min(w, h) * rows-ish; general sets:
    evaluated over axis cuts)."""
    chips = set(coords)
    best = None
    xs = sorted({c[1] for c in chips})
    ys = sorted({c[2] for c in chips})
    # candidate cuts between consecutive x (or y) values splitting chips ~half
    for axis, vals in ((1, xs), (2, ys)):
        for cut in vals[1:]:
            left = {c for c in chips if c[axis] < cut}
            if not left or len(left) * 2 < len(chips) * 0.5:
                continue
            right = chips - left
            if not right:
                continue
            cross = 0
            for c in left:
                for n in topo.neighbors(c):
                    if n in right:
                        cross += 1
            if abs(len(left) - len(right)) <= max(1, len(chips) // 8):
                best = cross if best is None else min(best, cross)
    return best if best is not None else 0
