"""Link-contention / bisection-bandwidth model — the structural analogue of
the paper's Fig. 3 measurement.

The paper measured mpptest bisection bandwidth for one block alone vs. two
blocks concurrently, sharing a master node, and found "only slight" impact.
On a TPU torus the analogous question is physical: do two blocks' collective
footprints share ICI links?  For contiguous rectangular blocks the answer is
provably zero-shared-links; for fragmented placements this module quantifies
the contention and the resulting per-block effective bandwidth.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

from repro.core.topology import (Coord, Link, Topology, min_bisection_links)

LINK_BW = 50e9          # bytes/s per ICI link (v5e)
DCN_BW = 25e9           # bytes/s inter-pod (abstract pod link)


@dataclasses.dataclass
class InterferenceReport:
    block_links: Dict[str, int]              # links used per block
    shared_links: Dict[Tuple[str, str], int]  # pairwise shared-link counts
    slowdown: Dict[str, float]               # predicted collective slowdown

    @property
    def isolated(self) -> bool:
        return all(v == 0 for v in self.shared_links.values())


def analyze_blocks(topo: Topology,
                   blocks: Dict[str, Sequence[Coord]]) -> InterferenceReport:
    """Compute each block's ring-collective link footprint and all pairwise
    link sharing.  slowdown[b] = max over links used by b of (total users of
    that link) — 1.0 means perfectly isolated."""
    usage: Dict[str, Dict[Link, int]] = {
        bid: topo.ring_links(list(coords)) for bid, coords in blocks.items()}
    link_users: Dict[Link, int] = {}
    for bid, links in usage.items():
        for l in links:
            link_users[l] = link_users.get(l, 0) + 1
    shared: Dict[Tuple[str, str], int] = {}
    ids = sorted(usage)
    for i in range(len(ids)):
        for j in range(i + 1, len(ids)):
            inter = set(usage[ids[i]]) & set(usage[ids[j]])
            shared[(ids[i], ids[j])] = len(inter)
    slowdown = {}
    for bid, links in usage.items():
        slowdown[bid] = float(max((link_users[l] for l in links), default=1))
    return InterferenceReport(
        block_links={b: len(l) for b, l in usage.items()},
        shared_links=shared, slowdown=slowdown)


def bisection_bandwidth(coords: Sequence[Coord], topo: Topology,
                        *, contention: float = 1.0) -> float:
    """Aggregate bytes/s across the block's minimum bisection."""
    links = min_bisection_links(list(coords), topo)
    return links * LINK_BW / max(contention, 1.0)


def predicted_fig3(topo: Topology, block_a: Sequence[Coord],
                   block_b: Sequence[Coord],
                   message_sizes: Sequence[int],
                   *, host_overhead_s: float = 5e-6) -> List[Dict]:
    """Predicted mpptest-style bisection-bandwidth curves: block A alone vs.
    A with B running concurrently.  With contiguous placements the two curves
    differ only by the shared-host dispatch overhead — the paper's result.
    """
    rep = analyze_blocks(topo, {"a": list(block_a), "b": list(block_b)})
    bw_alone = bisection_bandwidth(block_a, topo)
    bw_shared = bisection_bandwidth(block_a, topo,
                                    contention=rep.slowdown["a"])
    rows = []
    for size in message_sizes:
        t_alone = size / bw_alone + host_overhead_s
        t_shared = size / bw_shared + 2 * host_overhead_s  # 2 blocks on host
        rows.append({
            "bytes": size,
            "bw_single_GBs": size / t_alone / 1e9,
            "bw_multi_GBs": size / t_shared / 1e9,
            "shared_links": rep.shared_links[("a", "b")],
        })
    return rows
