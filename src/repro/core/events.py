"""Event bus — the observable spine of the block lifecycle.

The paper's step (6) ("the administrator and automated system will monitor
the usage of all running users") and its web-interface companion
(arXiv:0711.0528) both assume the control plane *announces* what it does:
every lifecycle transition and every scheduling decision becomes a
``BlockEvent`` published on one bus, instead of the pre-daemon design where
the scheduler and controller called ``Monitor.record_*`` directly at a
dozen scattered sites.

Three consumer classes hang off the bus:

* the ``Monitor`` subscribes and translates semantic events (``admitted``,
  ``preempted``, ``step``, ...) into its accounting — same numbers as the
  old direct calls, now decoupled from the emitters;
* the web gateway's per-block event feed long-polls ``wait()`` so a
  browser (or ``examples/web_gateway_demo.py``) can watch a block move
  through the paper's lifecycle live;
* tests/benchmarks subscribe ad hoc (e.g. admit-to-event latency in
  ``benchmarks/gateway_throughput.py``).

Publishing is synchronous and in submission order: subscribers run on the
publishing thread before ``publish`` returns, so the deterministic
single-thread mode (tests, benchmarks) sees the exact same interleaving as
the pre-event-bus code.  The history ring buffer backs the long-poll feed;
``seq`` is a bus-wide monotonic cursor clients resume from.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Callable, Deque, Dict, List, Optional, Set

from repro.obs.trace import TRACER


# The declared event taxonomy — the single schema every producer literal,
# consumer match and the dashboard's SSE subscription list are checked
# against by ``python -m repro.analysis`` (events_check pass).  Emitted by
# scheduler/controller, consumed by the Monitor.  Registry lifecycle
# transitions are additionally published as kind="state" with the new state
# in the payload, so the per-block feed shows *every* transition even when
# no scheduling decision was involved.  Ordered: docs and the dashboard
# enumerate kinds in this order.
EVENT_KINDS = (
    "registered",   # application entered the registry
    "state",        # lifecycle transition (payload: state, note)
    "enqueued",     # parked on the admission waitlist
    "dequeued",     # left the waitlist without admission (deny/expiry)
    "admitted",     # chips granted (payload: wait_s, priority, slack_s,
                    #   immediate, resumed)
    "preempted",    # evicted (payload: progress_lost_steps, reason,
                    #   checkpoint_step)
    "resumed",      # rebuilt on a fresh grant after preemption
    "step",         # one completed runtime step (payload: step_s, n_chips)
    "compile",      # a step executable was built or reused from the
                    #   compile cache (payload: action = hit | miss, label)
    "utilization",  # periodic pod usage sample from the scheduler pump
    "autostep",     # engine opt-in lifecycle (payload: action = enabled |
                    #   disabled | paced | done, plus the drive config)
    "session",      # generate-session lifecycle on a paged serve block
                    #   (payload: action = submitted | admitted | evicted |
                    #   finished, session, plus per-action detail)
    "generate",     # one generated token from a continuous-batching decode
                    #   step (payload: session, token, index, done)
    "pod",          # federation pod lifecycle (payload: action = joined |
                    #   left | drained | degraded | dead | recovered, plus
                    #   pod, name, phase, n_chips)
    "migrated",     # a block came back on a different pod than it was
                    #   evicted from (payload: from_pod, to_pod, n_chips)
    "postmortem",   # the flight recorder wrote a crash artifact (payload:
                    #   reason, name, n_events, n_spans)
)

KINDS = frozenset(EVENT_KINDS)


@dataclasses.dataclass(frozen=True)
class BlockEvent:
    seq: int                       # bus-wide monotonic cursor
    t: float                       # model time when the emitter passed now=
    kind: str
    app_id: Optional[str] = None
    block_id: Optional[str] = None
    user: Optional[str] = None
    payload: Dict = dataclasses.field(default_factory=dict)

    def to_dict(self) -> Dict:
        return {"seq": self.seq, "t": self.t, "kind": self.kind,
                "app_id": self.app_id, "block_id": self.block_id,
                "user": self.user, **self.payload}


Subscriber = Callable[[BlockEvent], None]


class EventBus:
    """Synchronous pub/sub with a bounded replay history.

    Thread-safe: publishes may come from the daemon's pump thread while
    gateway worker threads long-poll ``wait``.  Sequence numbers and the
    history ring are updated under one lock; subscriber callbacks run on
    the publishing thread *outside* the lock (a subscriber that publishes
    or waits would otherwise deadlock), which is order-preserving as long
    as mutations are serialized — exactly what the ClusterDaemon's command
    queue guarantees.
    """

    def __init__(self, history: int = 8192, per_block_history: int = 1024,
                 max_app_rings: int = 4096):
        # RLock: wait() re-enters events_since while holding the condition
        self._lock = threading.RLock()
        self._cond = threading.Condition(self._lock)
        self._seq = 0
        self._history: Deque[BlockEvent] = collections.deque(maxlen=history)
        # per-block rings behind the global ring: one hot block's step
        # storm (autostep engine) evicts only its *own* replay history —
        # another block's per-app feed stays complete even when the global
        # ring has long since wrapped past its events
        self._per_block_history = per_block_history
        self._per_app: Dict[str, Deque[BlockEvent]] = {}
        # per-app rings are created lazily and never die with the block
        # (a DONE/EXPIRED block's feed is still replayable) — so bound
        # their *count*: past the cap the least-recently-active quarter
        # is dropped (long-quiet blocks; the global ring still covers
        # anything recent)
        self._max_app_rings = max_app_rings
        self._subs: List[tuple] = []   # (callback, kinds-or-None)

    # ------------------------------------------------------------- publish
    def publish(self, kind: str, app_id: Optional[str] = None,
                block_id: Optional[str] = None, user: Optional[str] = None,
                now: Optional[float] = None, **payload) -> BlockEvent:
        """Emit one event.  ``now`` keeps the timestamp on the model clock
        under a simulated-clock driver (same convention as scheduler/
        registry ``now=`` everywhere else)."""
        if TRACER.enabled and "request_id" not in payload:
            # correlate events with the gateway request that caused them:
            # the request id rides the tracer's thread-local span stack
            # from the HTTP handler down into whatever publishes.  Inert
            # when tracing is off — the payload is byte-identical.
            rid = TRACER.current_request_id()
            if rid is not None:
                payload["request_id"] = rid
        with self._cond:
            self._seq += 1
            ev = BlockEvent(seq=self._seq,
                            t=now if now is not None else time.time(),
                            kind=kind, app_id=app_id, block_id=block_id,
                            user=user, payload=payload)
            self._history.append(ev)
            if app_id is not None:
                ring = self._per_app.get(app_id)
                if ring is None:
                    if len(self._per_app) >= self._max_app_rings:
                        stale = sorted(self._per_app,
                                       key=lambda a:
                                       self._per_app[a][-1].seq)
                        for a in stale[:max(1, len(stale) // 4)]:
                            del self._per_app[a]
                    ring = self._per_app[app_id] = collections.deque(
                        maxlen=self._per_block_history)
                ring.append(ev)
            subs = list(self._subs)
            self._cond.notify_all()
        for fn, kinds in subs:
            if kinds is None or kind in kinds:
                fn(ev)
        return ev

    # ----------------------------------------------------------- subscribe
    def subscribe(self, fn: Subscriber,
                  kinds: Optional[Set[str]] = None) -> Subscriber:
        """Register a callback (optionally filtered to ``kinds``); returns
        ``fn`` so callers can keep a handle for ``unsubscribe``."""
        with self._lock:
            self._subs.append((fn, set(kinds) if kinds else None))
        return fn

    def unsubscribe(self, fn: Subscriber) -> None:
        with self._lock:
            self._subs = [(f, k) for f, k in self._subs if f is not fn]

    # ------------------------------------------------------------- history
    @property
    def latest_seq(self) -> int:
        with self._lock:
            return self._seq

    def events_since(self, after_seq: int = 0,
                     app_id: Optional[str] = None,
                     kinds: Optional[Set[str]] = None,
                     limit: int = 1000) -> List[BlockEvent]:
        """Replay history after the cursor, optionally filtered to one
        application and/or a kind set.  Events older than the ring buffer
        are gone — clients that fall that far behind simply resume from
        what remains (the registry snapshot is the source of truth for
        *current* state).  Per-application queries read the block's own
        ring, so a busy neighbour cannot have evicted their events."""
        with self._lock:
            if app_id is not None:
                source = self._per_app.get(app_id, ())
            else:
                source = self._history
            out = [ev for ev in source
                   if ev.seq > after_seq
                   and (kinds is None or ev.kind in kinds)]
        return out[:limit]

    def wait(self, after_seq: int = 0, app_id: Optional[str] = None,
             kinds: Optional[Set[str]] = None, timeout: float = 10.0,
             limit: int = 1000) -> List[BlockEvent]:
        """Long-poll: return matching events newer than ``after_seq``,
        blocking up to ``timeout`` seconds for the first one.  Returns []
        on timeout — the HTTP feed turns that into an empty page and the
        client re-polls with the same cursor."""
        deadline = time.monotonic() + max(0.0, timeout)
        while True:
            evs = self.events_since(after_seq, app_id=app_id, kinds=kinds,
                                    limit=limit)
            if evs:
                return evs
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                return []
            with self._cond:
                # re-check under the lock: a publish between events_since
                # and acquiring the condition must not be slept through
                if self._seq > after_seq and self.events_since(
                        after_seq, app_id=app_id, kinds=kinds, limit=1):
                    continue
                self._cond.wait(remaining)
