"""BlockRuntime — the per-tenant execution engine (the paper's "MPD ring").

Activating a block builds its private sub-mesh over the admin-assigned
devices, compiles the job's step function with the block's parallelism plan,
and installs sharded state.  Each block's runtime is fully independent of
every other block's (separate mesh, separate compiled executables, separate
checkpoint namespace) — the multi-daemon isolation property of the paper.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Deque, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint.manager import CheckpointManager
from repro.core.block import BlockGrant
from repro.data import pipeline
from repro.models import model as model_lib
from repro.models.config import ModelConfig, ShapeConfig
from repro.serve import serve_step as serve_lib
from repro.sharding import ctx as shard_ctx
from repro.sharding import plans
from repro.train import optimizer as opt_lib
from repro.train import train_step as train_lib


@dataclasses.dataclass
class JobSpec:
    cfg: ModelConfig
    shape: ShapeConfig
    kind: str = "train"              # train | serve
    opt: opt_lib.OptConfig = dataclasses.field(default_factory=opt_lib.OptConfig)
    seed: int = 0


class BlockRuntime:
    def __init__(self, grant: BlockGrant, job: JobSpec,
                 devices: Sequence[jax.Device], ckpt_root: str):
        assert len(devices) == int(np.prod(grant.mesh_shape)), (
            len(devices), grant.mesh_shape)
        self.grant = grant
        self.job = job
        self.devices = list(devices)
        self.mesh = Mesh(np.asarray(self.devices).reshape(grant.mesh_shape),
                         ("data", "model"))
        self.axes = plans.MeshAxes(dp=("data",), model="model")
        self.ctx = shard_ctx.ShardCtx(self.mesh, ("data",), "model")
        self.ckpt = CheckpointManager(ckpt_root, namespace=grant.block_id)
        self.state: Any = None
        self.cache: Any = None
        self.step_count = 0
        # in-flight dispatch window: (dispatch wall-time, ready token) per
        # async step not yet observed complete
        self._inflight: Deque[Tuple[float, Any]] = collections.deque()
        self._last_ready_t = 0.0
        self._build()

    # ------------------------------------------------------------ compile
    def _build(self) -> None:
        job = self.job
        if job.kind == "train":
            state_abs = train_lib.abstract_train_state(job.cfg, job.opt)
            p_spec = plans.param_specs(state_abs["params"], self.mesh, self.axes)
            state_spec = {"params": p_spec,
                          "opt": plans.opt_state_specs(state_abs["opt"], p_spec)}
            self.state_shardings = plans.to_shardings(state_spec, self.mesh)
            batch_abs = pipeline.input_specs(job.cfg, job.shape)
            b_spec = plans.batch_specs(batch_abs, self.mesh, self.axes)
            self.batch_shardings = plans.to_shardings(b_spec, self.mesh)
            step = train_lib.make_train_step(job.cfg, job.shape, job.opt)

            def fn(state, batch):
                with shard_ctx.use(self.ctx):
                    return step(state, batch)

            self._step = jax.jit(fn, in_shardings=(self.state_shardings,
                                                   self.batch_shardings),
                                 out_shardings=(self.state_shardings, None),
                                 donate_argnums=(0,))
            self.data = pipeline.DataIterator(job.cfg, job.shape,
                                              seed=job.seed,
                                              shardings=self.batch_shardings)
        else:
            params_abs = model_lib.abstract_params(job.cfg)
            p_spec = plans.param_specs(params_abs, self.mesh, self.axes)
            self.state_shardings = {"params": plans.to_shardings(p_spec,
                                                                 self.mesh)}
            dec = serve_lib.make_decode_step(job.cfg)

            def fn(params, token, cache, cache_len):
                with shard_ctx.use(self.ctx):
                    return dec(params, token, cache, cache_len)

            self._step = jax.jit(fn, donate_argnums=(2,))

    # --------------------------------------------------------------- state
    def init_state(self) -> None:
        job = self.job
        key = jax.random.PRNGKey(job.seed)
        if job.kind == "train":
            init = jax.jit(
                lambda k: train_lib.make_train_state(job.cfg, k, job.opt),
                out_shardings=self.state_shardings)
            self.state = init(key)
        else:
            params = jax.jit(
                lambda k: model_lib.init_params(job.cfg, k),
                out_shardings=self.state_shardings["params"])(key)
            cache = model_lib.init_cache(job.cfg, job.shape.global_batch,
                                         job.shape.seq_len)
            self.state = {"params": params}
            self.cache = cache
            self.cache_len = jnp.int32(0)
            self.token = jnp.zeros((job.shape.global_batch, 1), jnp.int32)

    # ---------------------------------------------------------------- step
    def step(self) -> Dict[str, float]:
        t0 = time.perf_counter()
        if self.job.kind == "train":
            batch = self.data.batch(self.step_count)
            self.state, metrics = self._step(self.state, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
        else:
            self.token, self.cache = self._step(self.state["params"],
                                                self.token, self.cache,
                                                self.cache_len)
            self.cache_len = self.cache_len + 1
            metrics = {}
        jax.block_until_ready(jax.tree.leaves(self.state)[0])
        self.step_count += 1
        metrics["step_s"] = time.perf_counter() - t0
        return metrics

    def step_async(self):
        """Dispatch one step without blocking (async dispatch overlap across
        blocks on the shared host — the paper's shared-master execution)."""
        if self.job.kind == "train":
            batch = self.data.batch(self.step_count)
            self.state, metrics = self._step(self.state, batch)
        else:
            self.token, self.cache = self._step(self.state["params"],
                                                self.token, self.cache,
                                                self.cache_len)
            self.cache_len = self.cache_len + 1
            metrics = {}
        self.step_count += 1
        return metrics

    # ------------------------------------------------- in-flight dispatch
    @property
    def inflight_depth(self) -> int:
        return len(self._inflight)

    def oldest_dispatch_t(self) -> float:
        """Dispatch wall-time of the oldest in-flight step (the scheduler
        blocks on the runtime with the smallest value when every window is
        full).  +inf when nothing is in flight."""
        return self._inflight[0][0] if self._inflight else float("inf")

    def dispatch(self) -> None:
        """Dispatch one async step and track its completion token.  The
        scheduler caps how many of these are outstanding per block
        (dispatch-depth backpressure) so host runahead stays bounded."""
        t0 = time.perf_counter()
        self.step_async()
        token = (jax.tree.leaves(self.state)[0]
                 if self.job.kind == "train" else self.token)
        self._inflight.append((t0, token))

    def poll(self, block: bool = False) -> List[Dict[str, float]]:
        """Harvest completed in-flight steps (oldest first).  With
        ``block=True``, waits for the head step if nothing is ready yet —
        the scheduler's no-busy-spin fallback.

        ``step_s`` is measured from max(dispatch, previous step's observed
        completion): steps within a block form a serial chain, so counting
        each one from its own dispatch would bill the wait behind its
        predecessor twice at dispatch depth > 1 (inflating EWMA/straggler/
        chip-second accounting by ~the window depth)."""
        out: List[Dict[str, float]] = []
        while self._inflight:
            t0, token = self._inflight[0]
            if block and not out:
                jax.block_until_ready(token)
            is_ready = getattr(token, "is_ready", None)
            if is_ready is not None and not is_ready():
                break
            self._inflight.popleft()
            now = time.perf_counter()
            out.append({"step_s": now - max(t0, self._last_ready_t)})
            self._last_ready_t = now
        return out

    def drain(self) -> List[Dict[str, float]]:
        """Block until every in-flight step has completed."""
        out: List[Dict[str, float]] = []
        while self._inflight:
            out.extend(self.poll(block=True))
        return out

    # ----------------------------------------------------------- persist
    def save(self, async_: bool = True) -> None:
        payload = {"state": self.state, "step_count": self.step_count}
        if async_:
            self.ckpt.save_async(self.step_count, payload)
        else:
            self.ckpt.save(self.step_count, payload)

    def restore(self, step: Optional[int] = None) -> int:
        like = {"state": self.state, "step_count": self.step_count}
        shardings = {"state": self.state_shardings
                     if self.job.kind == "train"
                     else self.state_shardings, "step_count": None}
        restored, at = self.ckpt.restore(like, step=step, shardings=shardings)
        self.state = restored["state"]
        self.step_count = int(restored["step_count"])
        return at

    @classmethod
    def rebuild(cls, old: "BlockRuntime", grant: BlockGrant,
                devices: Sequence[jax.Device], ckpt_root: str
                ) -> "BlockRuntime":
        """Failure migration / elastic resize: new runtime on new devices,
        state restored from the old block's checkpoints (resharded onto the
        new mesh by the checkpoint manager)."""
        rt = cls(grant, old.job, devices, ckpt_root)
        rt.init_state()
        old.ckpt.wait()
        if old.ckpt.latest_step() is not None:
            rt.ckpt = old.ckpt      # same namespace: adopt checkpoint history
            rt.restore()
        return rt
