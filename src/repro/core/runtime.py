"""BlockRuntime — the per-tenant execution engine (the paper's "MPD ring").

Activating a block builds its private sub-mesh over the admin-assigned
devices, compiles the job's step function with the block's parallelism plan,
and installs sharded state.  Each block's runtime is fully independent of
every other block's (separate mesh, separate compiled executables, separate
checkpoint namespace) — the multi-daemon isolation property of the paper.

Preemption support: ``suspend()`` drains the in-flight window, writes a
synchronous checkpoint and drops every device reference, so the chips can
be re-granted to another block; ``resume(grant, devices)`` rebuilds the
runtime on a possibly *different* chip set / mesh geometry and restores the
suspended state from the checkpoint (host leaves are resharded onto the new
mesh by the checkpoint manager).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint.manager import CheckpointManager
from repro.core.block import BlockGrant
from repro.core.inflight import InflightWindow
from repro.data import pipeline
from repro.models import model as model_lib
from repro.models.config import ModelConfig, ShapeConfig
from repro.serve import serve_step as serve_lib
from repro.sharding import ctx as shard_ctx
from repro.sharding import plans
from repro.train import compile_cache
from repro.train import optimizer as opt_lib
from repro.train import train_step as train_lib


@dataclasses.dataclass
class JobSpec:
    cfg: ModelConfig
    shape: ShapeConfig
    kind: str = "train"              # train | serve
    opt: opt_lib.OptConfig = dataclasses.field(default_factory=opt_lib.OptConfig)
    seed: int = 0
    decode_sample: bool = False      # serve: sample instead of greedy argmax
    collect_metrics: bool = False    # carry step metrics (loss, grad_norm)
                                     # through the async window into each
                                     # completion record (extra host
                                     # transfers per step — drivers that
                                     # log per-step opt in)
    ckpt_namespace: Optional[str] = None  # stable checkpoint namespace so a
                                          # relaunched driver can --resume;
                                          # default: the (random) block id
    ckpt_every: int = 0              # periodic checkpoint interval under
                                     # daemon-side autostep (client-driven
                                     # drivers call save() between batches
                                     # themselves; the engine reads this)
    # ---- serve: continuous batching over a paged KV cache ----
    paged: bool = False              # serve: slot-batched generate sessions
                                     # over a shared page pool instead of the
                                     # single dense prefill/decode context
    page_size: int = 16              # rows per KV page
    n_pages: int = 0                 # pool size; 0 derives full residency
                                     # (max_slots * pages_per_seq + trash)
    max_slots: int = 8               # concurrent decode batch width
    max_seq_len: int = 0             # per-session context cap; 0 -> shape.seq_len


@dataclasses.dataclass
class SimJobSpec:
    """Device-free stand-in for a JobSpec: activating a block with one
    boots a ``scheduler.SimRuntime`` (wall-clock step model with the full
    suspend/resume preemption surface) instead of compiling a real
    runtime.  The web gateway's ``{"kind": "sim"}`` jobs, the gateway
    tests and the throughput benchmarks drive the identical lifecycle —
    admission, dispatch, preemption, expiry — without XLA in the loop."""
    step_s: float = 0.001
    ckpt_every: int = 0


class BlockRuntime(InflightWindow):
    def __init__(self, grant: BlockGrant, job: JobSpec,
                 devices: Sequence[jax.Device], ckpt_root: str):
        self.job = job
        self.ckpt = CheckpointManager(
            ckpt_root, namespace=job.ckpt_namespace or grant.block_id)
        self.state: Any = None
        self.cache: Any = None
        self.sessions = None         # paged serve: the DecodeScheduler
        self._emissions: list = []   # paged serve: buffered generate events
        self.step_count = 0
        self.last_saved_step = 0     # step_count at the last checkpoint
        self.suspended = False
        self._init_window()
        self._attach(grant, devices)

    def _attach(self, grant: BlockGrant,
                devices: Sequence[jax.Device]) -> None:
        """Bind to a chip set: build the sub-mesh and (re)compile the step
        function.  Called at activation and again on resume-after-preemption
        (possibly with different chips / a different mesh geometry)."""
        assert len(devices) == int(np.prod(grant.mesh_shape)), (
            len(devices), grant.mesh_shape)
        self.grant = grant
        self.devices = list(devices)
        self.mesh = Mesh(np.asarray(self.devices).reshape(grant.mesh_shape),
                         ("data", "model"))
        self.axes = plans.MeshAxes(dp=("data",), model="model")
        self.ctx = shard_ctx.ShardCtx(self.mesh, ("data",), "model")
        self._build()

    # ------------------------------------------------------------ compile
    def _cache_key(self, family: str, *extra) -> tuple:
        """Logical build signature: everything the jitted step's trace can
        depend on.  ``seed``/checkpoint fields deliberately excluded — they
        never reach the compiled computation."""
        job = self.job
        return (family, compile_cache.freeze(job.cfg),
                compile_cache.freeze(job.shape),
                compile_cache.mesh_fingerprint(self.mesh)) + extra

    def _cached(self, key, builder, label: str):
        return compile_cache.GLOBAL.get(
            key, builder, label=label, block_id=self.grant.block_id)

    def _build(self) -> None:
        job = self.job
        if job.kind == "train":
            state_abs = train_lib.abstract_train_state(job.cfg, job.opt)
            p_spec = plans.param_specs(state_abs["params"], self.mesh, self.axes)
            state_spec = {"params": p_spec,
                          "opt": plans.opt_state_specs(state_abs["opt"], p_spec)}
            self.state_shardings = plans.to_shardings(state_spec, self.mesh)
            batch_abs = pipeline.input_specs(job.cfg, job.shape)
            b_spec = plans.batch_specs(batch_abs, self.mesh, self.axes)
            self.batch_shardings = plans.to_shardings(b_spec, self.mesh)

            def build_train():
                # everything the closure captures (ctx, shardings) is a
                # pure function of the cache key, so a rebuild with the
                # same key can adopt this wrapper — and jax's own jit
                # cache makes re-attach on the same chips recompile-free
                step = train_lib.make_train_step(job.cfg, job.shape, job.opt)
                ctx, st_sh, b_sh = (self.ctx, self.state_shardings,
                                    self.batch_shardings)

                def fn(state, batch):
                    with shard_ctx.use(ctx):
                        return step(state, batch)

                return jax.jit(fn, in_shardings=(st_sh, b_sh),
                               out_shardings=(st_sh, None),
                               donate_argnums=(0,))

            self._step = self._cached(
                self._cache_key("train_step", compile_cache.freeze(job.opt),
                                ("donate", 0)),
                build_train, "train_step")
            self.data = pipeline.DataIterator(job.cfg, job.shape,
                                              seed=job.seed,
                                              shardings=self.batch_shardings)
        else:
            params_abs = model_lib.abstract_params(job.cfg)
            p_spec = plans.param_specs(params_abs, self.mesh, self.axes)
            self.state_shardings = {"params": plans.to_shardings(p_spec,
                                                                 self.mesh)}
            if job.paged:
                # the DecodeScheduler owns its own jitted prefill/decode;
                # built in init_state (it needs the params) or on restore
                self._step = None
                self._prefill_fn = None
                self._rng = jax.random.PRNGKey(job.seed + 1)
                return
            def build_decode():
                dec = serve_lib.make_decode_step(job.cfg,
                                                 sample=job.decode_sample)
                ctx = self.ctx

                if job.decode_sample:
                    def fn(params, token, cache, cache_len, key):
                        with shard_ctx.use(ctx):
                            return dec(params, token, cache, cache_len, key)
                else:
                    def fn(params, token, cache, cache_len):
                        with shard_ctx.use(ctx):
                            return dec(params, token, cache, cache_len)

                return jax.jit(fn, donate_argnums=(2,))

            self._step = self._cached(
                self._cache_key("decode_step", job.decode_sample,
                                ("donate", 2)),
                build_decode, "decode_step")
            self._prefill_fn = None   # compiled lazily on first prefill()
            self._rng = jax.random.PRNGKey(job.seed + 1)

    # --------------------------------------------------------------- state
    def init_state(self) -> None:
        job = self.job
        key = jax.random.PRNGKey(job.seed)
        if job.kind == "train":
            init = jax.jit(
                lambda k: train_lib.make_train_state(job.cfg, k, job.opt),
                out_shardings=self.state_shardings)
            self.state = init(key)
        else:
            params = jax.jit(
                lambda k: model_lib.init_params(job.cfg, k),
                out_shardings=self.state_shardings["params"])(key)
            self.state = {"params": params}
            if job.paged:
                self.sessions = self._make_scheduler(params)
                self.token = self.sessions.last_tokens_dev
                return
            cache = model_lib.init_cache(job.cfg, job.shape.global_batch,
                                         job.shape.seq_len)
            self.cache = cache
            self.cache_len = jnp.int32(0)
            self.token = jnp.zeros((job.shape.global_batch, 1), jnp.int32)

    def _paged_geometry(self) -> Dict[str, int]:
        job = self.job
        return dict(page_size=job.page_size, n_pages=job.n_pages,
                    max_slots=job.max_slots,
                    max_seq_len=job.max_seq_len or job.shape.seq_len)

    def _make_scheduler(self, params, init_pool: bool = True):
        from repro.serve.decode_scheduler import DecodeScheduler
        job = self.job
        return DecodeScheduler(job.cfg, params, sample=job.decode_sample,
                               seed=job.seed, init_pool=init_pool,
                               **self._paged_geometry())

    def prefill(self, batch: Dict[str, Any]) -> None:
        """Serve blocks: process a prompt batch into the KV cache and seed
        the decode loop with the first generated token (the batched-prefill
        half of the serving driver, run on the block's own sub-mesh).  The
        prefill executable is compiled lazily — resume-after-preemption
        restores the decode context from the checkpoint and never needs
        it."""
        assert self.job.kind == "serve", "prefill is a serve-block op"
        if self._prefill_fn is None:
            def build_prefill():
                pf = serve_lib.make_prefill_step(self.job.cfg)
                ctx = self.ctx

                def fn(params, batch, cache):
                    with shard_ctx.use(ctx):
                        return pf(params, batch, cache)

                return jax.jit(fn)

            self._prefill_fn = self._cached(
                self._cache_key("prefill_step"), build_prefill,
                "prefill_step")
        logits, self.cache = self._prefill_fn(self.state["params"], batch,
                                              self.cache)
        self.token = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        self.cache_len = jnp.int32(batch["tokens"].shape[1])

    # ------------------------------------------------- generate sessions
    # (paged serve only: the continuous-batching session surface the
    # daemon's "generate" command and the autostep engine drive)
    def start_session(self, prompt: Sequence[int], max_new_tokens: int = 16,
                      eos_id: Optional[int] = None) -> str:
        """Queue a generate session; tokens are emitted by subsequent decode
        steps and drained with ``harvest()`` (engine-driven) or returned
        directly by ``feed()`` (client-driven)."""
        if self.sessions is None:
            raise ValueError("block has no generate surface "
                             "(needs a paged serve job)")
        return self.sessions.submit(prompt, max_new_tokens=max_new_tokens,
                                    eos_id=eos_id)

    def feed(self, rounds: int = 1) -> list:
        """Client-driven decode: run ``rounds`` continuous-batching steps
        synchronously and return their emissions (buffered ones first)."""
        assert self.sessions is not None, "feed() needs a paged serve job"
        out = self.harvest()
        for _ in range(rounds):
            out.extend(self.sessions.step())
            self.step_count += 1
        return out

    def harvest(self) -> list:
        """Drain emissions buffered by engine-dispatched decode steps."""
        out, self._emissions = self._emissions, []
        return out

    @property
    def idle_serve(self) -> bool:
        """True when engine-dispatched steps would be no-ops (paged serve
        with no active or queued session) — the autostep engine skips
        dispatching to keep the step/event stream quiet until a generate
        arrives."""
        return self.sessions is not None and not self.sessions.has_work

    # ---------------------------------------------------------------- step
    def _decode_once(self):
        if self.job.paged:
            self._emissions.extend(self.sessions.step())
            self.token = self.sessions.last_tokens_dev
            return
        if self.job.decode_sample:
            self._rng, key = jax.random.split(self._rng)
            self.token, self.cache = self._step(self.state["params"],
                                                self.token, self.cache,
                                                self.cache_len, key)
        else:
            self.token, self.cache = self._step(self.state["params"],
                                                self.token, self.cache,
                                                self.cache_len)
        self.cache_len = self.cache_len + 1

    def step(self) -> Dict[str, float]:
        t0 = time.perf_counter()
        if self.job.kind == "train":
            batch = self.data.batch(self.step_count)
            self.state, metrics = self._step(self.state, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
        else:
            self._decode_once()
            metrics = {}
        jax.block_until_ready(jax.tree.leaves(self.state)[0])
        self.step_count += 1
        metrics["step_s"] = time.perf_counter() - t0
        return metrics

    def step_async(self):
        """Dispatch one step without blocking (async dispatch overlap across
        blocks on the shared host — the paper's shared-master execution)."""
        if self.job.kind == "train":
            batch = self.data.batch(self.step_count)
            self.state, metrics = self._step(self.state, batch)
        else:
            self._decode_once()
            metrics = {}
        self.step_count += 1
        return metrics

    # ------------------------------------------------- in-flight dispatch
    # window bookkeeping (dispatch/poll/drain/inflight_depth) lives in
    # InflightWindow; a step's completion token is a device array whose
    # readiness signals the whole step finished
    def _launch(self):
        metrics = self.step_async()
        # the completion token must be an output the *next* dispatch cannot
        # donate away: the train state is donated (argnums=0), so a state
        # leaf from step N is deleted the moment step N+1 dispatches and
        # its readiness can no longer be polled at window depth >= 2.  The
        # metrics scalars (and the decode token) are plain outputs of the
        # same executable — ready exactly when the step is.
        token = (jax.tree.leaves(metrics)[0]
                 if self.job.kind == "train" else self.token)
        if self.job.collect_metrics:
            # carry the step's metric arrays with the token: they are
            # outputs of the same executable, so by the time the token is
            # ready they are too and float() below costs one host transfer
            return (token, metrics)
        return token

    @staticmethod
    def _token_array(token):
        return token[0] if isinstance(token, tuple) else token

    def _token_ready(self, token) -> bool:
        is_ready = getattr(self._token_array(token), "is_ready", None)
        return is_ready is None or is_ready()

    def _token_wait(self, token) -> None:
        jax.block_until_ready(self._token_array(token))

    def _completion_record(self, dispatch_t: float, token) -> Dict[str, float]:
        rec = super()._completion_record(dispatch_t, token)
        if isinstance(token, tuple):
            rec.update({k: float(v) for k, v in token[1].items()})
        return rec

    # ----------------------------------------------------------- persist
    def _decode_ctx(self) -> Dict[str, Any]:
        """A serve block's generation context — without it a restored
        decoder would silently restart from an empty cache at position 0.
        Paged serve checkpoints the whole continuous-batching plane (page
        pool, page tables, per-slot lengths, session metadata) so in-flight
        generate sessions survive preemption."""
        if self.job.paged:
            return {"paged": self.sessions.state_tree()}
        return {"cache": self.cache, "token": self.token,
                "cache_len": self.cache_len}

    def _abstract_like(self) -> Dict[str, Any]:
        """Restore targets without materializing state on device (resume
        path: a full random init just to overwrite it would put a model-init
        compile on the preemption-resume critical path)."""
        job = self.job
        if job.kind == "train":
            return train_lib.abstract_train_state(job.cfg, job.opt)
        return {"params": model_lib.abstract_params(job.cfg)}

    def _payload(self) -> Dict[str, Any]:
        payload = {"state": self.state, "step_count": self.step_count}
        if self.job.kind == "serve":
            payload["decode"] = self._decode_ctx()
        return payload

    def save(self, async_: bool = True) -> None:
        payload = self._payload()
        if async_:
            self.ckpt.save_async(self.step_count, payload)
        else:
            self.ckpt.save(self.step_count, payload)
        self.last_saved_step = self.step_count

    @property
    def progress_lost(self) -> int:
        """Steps of work beyond the last checkpoint — what a *non-graceful*
        eviction of this block would throw away.  The scheduler's victim
        selection minimizes this (suspend() itself checkpoints, so graceful
        preemption loses nothing; the metric bounds the drain/save cost and
        the loss if the host dies mid-suspend)."""
        return max(0, self.step_count - self.last_saved_step)

    def suspend(self) -> Dict[str, float]:
        """Preemption: drain in-flight dispatches, checkpoint synchronously,
        and drop every device reference so the chips can be re-granted.
        The runtime object survives (job spec + checkpoint namespace) and
        can be rebuilt on any chip set with ``resume``."""
        drained = self.drain()
        self.ckpt.wait()                 # an async save may still be landing
        self.save(async_=False)
        self.state = None
        self.cache = None
        if self.job.kind == "serve":
            self.token = None
            self.cache_len = None
            self._prefill_fn = None
            self.sessions = None     # device pool + jits dropped; host
                                     # session state lives in the checkpoint
        self._step = None
        self.mesh = None
        self.devices = []
        self.suspended = True
        return {"step": self.step_count, "drained_steps": len(drained)}

    def resume(self, grant: BlockGrant,
               devices: Sequence[jax.Device]) -> int:
        """Rebuild after preemption on ``devices`` (possibly different chips
        and/or a different mesh geometry than suspend-time) and restore the
        checkpointed state, resharded onto the new mesh.  Returns the step
        the block resumed at."""
        assert self.suspended, "resume() is only legal after suspend()"
        self._attach(grant, devices)
        # no init_state(): restore targets are abstract (shape/dtype), so
        # resume skips the model-init compile entirely
        at = self.restore()
        self.suspended = False
        return at

    def restore(self, step: Optional[int] = None) -> int:
        like = {"state": (self.state if self.state is not None
                          else self._abstract_like()),
                "step_count": self.step_count}
        shardings = {"state": self.state_shardings, "step_count": None}
        if self.job.kind == "serve":
            have_ctx = (self.sessions is not None if self.job.paged
                        else self.cache is not None)
            decode_like = (self._decode_ctx() if have_ctx
                           else self._abstract_decode())
            like["decode"] = decode_like
            # decode context restores to default placement (the same the
            # init path uses); None per leaf keeps the trees congruent
            shardings["decode"] = jax.tree.map(lambda _: None, decode_like)
        restored, at = self.ckpt.restore(like, step=step, shardings=shardings)
        self.state = restored["state"]
        if self.job.kind == "serve":
            dec = restored["decode"]
            if self.job.paged:
                if self.sessions is None:   # resume: rebuild without a
                    self.sessions = self._make_scheduler(   # throwaway pool
                        self.state["params"], init_pool=False)
                self.sessions.params = self.state["params"]
                self.sessions.load_state(dec["paged"])
                self.token = self.sessions.last_tokens_dev
            else:
                self.cache = dec["cache"]
                self.token = dec["token"]
                self.cache_len = dec["cache_len"]
        self.step_count = int(restored["step_count"])
        self.last_saved_step = self.step_count   # state == checkpoint now
        return at

    def _abstract_decode(self) -> Dict[str, Any]:
        # eval_shape: shape/dtype targets only — materializing a real cache
        # here would double peak device memory on the resume critical path
        if self.job.paged:
            from repro.serve.decode_scheduler import DecodeScheduler
            return {"paged": DecodeScheduler.abstract_state(
                self.job.cfg, **self._paged_geometry())}
        shape = self.job.shape
        return jax.eval_shape(lambda: {
            "cache": model_lib.init_cache(self.job.cfg, shape.global_batch,
                                          shape.seq_len),
            "token": jnp.zeros((shape.global_batch, 1), jnp.int32),
            "cache_len": jnp.int32(0),
        })

    @classmethod
    def rebuild(cls, old: "BlockRuntime", grant: BlockGrant,
                devices: Sequence[jax.Device], ckpt_root: str
                ) -> "BlockRuntime":
        """Failure migration / elastic resize: new runtime on new devices,
        state restored from the old block's checkpoints (resharded onto the
        new mesh by the checkpoint manager)."""
        rt = cls(grant, old.job, devices, ckpt_root)
        rt.init_state()
        old.ckpt.wait()
        if old.ckpt.latest_step() is not None:
            rt.ckpt = old.ckpt      # same namespace: adopt checkpoint history
            rt.restore()
        return rt
