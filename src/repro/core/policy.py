"""SchedulingPolicy — the tenancy policy layer the BlockScheduler consults.

The paper's follow-ups make the missing multi-tenant pieces explicit:
"Multi and Independent Block Approach in Public Cluster" (arXiv:0708.3446)
requires jobs that span *several* blocks at once, and openPC
(arXiv:1012.2499) moves per-user ownership limits from the administrator
into the toolkit itself.  This module is where those rules live, separated
from the scheduler's mechanics so operators can swap or tune policy without
touching admission/dispatch code.  The scheduler consults it at three
points:

* **submit time** — ``admission_blocked`` decides whether a request (or a
  whole gang) may be admitted at all under the user's quota.  Over-quota is
  a *waitlist* outcome, never a denial: the request becomes admissible
  again as the user's running blocks retire.
* **pump time** — ``waitlist_key`` orders the waitlist.  Within a
  fair-share class (priority, then preempted victims, then held chips)
  entries are ordered by least deadline slack instead of FIFO, so a
  tight-deadline request submitted late still beats a loose one submitted
  early.
* **preempt time** — ``victim_key`` ranks eviction candidates.  Blocks
  whose user is currently *over* quota (caps can be lowered at runtime, and
  chip-second budgets run out while a block is running) are preferred
  victims ahead of the usual (priority, progress-lost, chips) key.

Quota accounting inputs are the scheduler's own held-chips map and the
per-user chip-seconds aggregated from ``Monitor.chip_seconds``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple


@dataclasses.dataclass
class UserQuota:
    """Hard per-user caps.  ``None`` means uncapped.

    * ``max_chips`` — chips the user may hold concurrently across all of
      their blocks (openPC's per-user node-ownership limit).
    * ``max_chip_seconds`` — cumulative compute budget; once spent, new
      admissions wait until the budget is raised.
    """
    max_chips: Optional[int] = None
    max_chip_seconds: Optional[float] = None


class SchedulingPolicy:
    """Quotas + deadline-slack ordering + victim preference.

    ``deadline_ordering=False`` degrades the within-class order back to
    plain FIFO (the PR-1 behavior) — the policy-vs-FIFO comparison knob
    ``benchmarks/policy_admission.py`` flips.  ``completion_aware=False``
    degrades slack back to pure time-to-deadline (ignoring the estimated
    remaining service time).  ``preempt_slack_margin_s`` is the headroom
    below which an on-track deadlined block is exempt from eviction (see
    ``victim_deadline_exempt``).
    """

    def __init__(self, default_quota: Optional[UserQuota] = None,
                 deadline_ordering: bool = True,
                 completion_aware: bool = True,
                 deadline_aware_preemption: bool = True,
                 preempt_slack_margin_s: float = 60.0):
        self.quotas: Dict[str, UserQuota] = {}
        self.default_quota = default_quota or UserQuota()
        self.deadline_ordering = deadline_ordering
        self.completion_aware = completion_aware
        self.deadline_aware_preemption = deadline_aware_preemption
        self.preempt_slack_margin_s = preempt_slack_margin_s

    # -------------------------------------------------------------- quotas
    def set_quota(self, user: str, max_chips: Optional[int] = None,
                  max_chip_seconds: Optional[float] = None) -> UserQuota:
        q = UserQuota(max_chips=max_chips, max_chip_seconds=max_chip_seconds)
        self.quotas[user] = q
        return q

    def quota_for(self, user: str) -> UserQuota:
        return self.quotas.get(user, self.default_quota)

    def admission_blocked(self, user: str, requested_chips: int,
                          held_chips: int,
                          used_chip_seconds: float) -> Optional[str]:
        """None when admissible; otherwise the human-readable reason the
        request must stay waitlisted (recorded in the registry history)."""
        q = self.quota_for(user)
        if q.max_chips is not None and \
                held_chips + requested_chips > q.max_chips:
            return (f"quota: {user} holds {held_chips} chips, "
                    f"+{requested_chips} exceeds cap {q.max_chips}")
        if q.max_chip_seconds is not None and \
                used_chip_seconds >= q.max_chip_seconds:
            return (f"quota: {user} spent {used_chip_seconds:.1f} "
                    f"chip-seconds of {q.max_chip_seconds:.1f} budget")
        return None

    def over_quota(self, user: str, held_chips: int,
                   used_chip_seconds: float) -> bool:
        """Is the user currently *above* either cap?  Admission enforces the
        caps, so this only becomes true while blocks run: a budget is spent
        step by step, and an operator can lower a cap under a running
        block.  Such blocks are the preferred preemption victims."""
        q = self.quota_for(user)
        if q.max_chips is not None and held_chips > q.max_chips:
            return True
        if q.max_chip_seconds is not None and \
                used_chip_seconds >= q.max_chip_seconds:
            return True
        return False

    # ------------------------------------------------------------ ordering
    @staticmethod
    def slack(deadline_at: Optional[float], now: float) -> float:
        """Seconds until the deadline; +inf when the entry has none (so
        deadline-less entries sort after every deadlined one in-class)."""
        return math.inf if deadline_at is None else deadline_at - now

    def waitlist_key(self, entry, held_chips: int, now: float,
                     service_s: float = 0.0) -> Tuple:
        """Admission order: priority desc, preempted victims ahead of their
        fair-share class, fewest held chips, then least *effective* slack,
        then FIFO sequence as the final tie-break.

        Effective slack is time-to-deadline minus the estimated remaining
        service time (``service_s``, from the requester's declared
        ``est_steps`` x the Monitor's EWMA step time): two entries with the
        same deadline no longer tie — the one with more work left is the
        one actually at risk and goes first."""
        slack = (self.slack(entry.deadline_at, now)
                 if self.deadline_ordering else math.inf)
        if self.completion_aware and math.isfinite(slack):
            slack -= service_s
        return (-entry.priority, not entry.preempted, held_chips,
                slack, entry.seq)

    # ----------------------------------------------------------- preemption
    def victim_headroom(self, deadline_at: Optional[float], now: float,
                        est_remaining_s: float = 0.0) -> float:
        """The victim's own deadline headroom if it kept running: slack
        minus its estimated remaining service time.  +inf without an SLO."""
        if deadline_at is None:
            return math.inf
        return deadline_at - now - est_remaining_s

    def victim_deadline_exempt(self, deadline_at: Optional[float],
                               now: float,
                               est_remaining_s: float = 0.0) -> bool:
        """Never evict a block into a deadline miss it would not otherwise
        have had: a victim currently *on track* (headroom >= 0) whose
        headroom could not absorb an eviction round-trip
        (< ``preempt_slack_margin_s``) is exempt.  A block already past
        recovery (headroom < 0) is not protected — eviction creates no
        *new* miss — and neither is a deadline-less block."""
        if not self.deadline_aware_preemption or deadline_at is None:
            return False
        headroom = deadline_at - now - est_remaining_s
        return 0.0 <= headroom < self.preempt_slack_margin_s

    def victim_key(self, over_quota: bool, priority: int,
                   progress_lost: int, n_chips: int,
                   headroom_s: float = math.inf) -> Tuple:
        """Eviction rank: quota-busting blocks first, then least important,
        most deadline headroom (a deadline-less block sorts ahead of any
        deadlined one — evicting it risks no SLO), cheapest-to-stop,
        smallest."""
        return (not over_quota, priority, -headroom_s, progress_lost,
                n_chips)
