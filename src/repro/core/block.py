"""Block model: the paper's per-tenant unit of allocation.

A *block* = an admin-assigned, disjoint set of chips + its own parallel
runtime configuration ("MPD ring" in the paper: per-user daemon + config
files).  Here: BlockRequest (the user's application), BlockGrant (the
admin's assignment: chip coords, mesh shape, capability token) and the
lifecycle state machine of Fig. 2 of the paper, extended with the
admission waitlist (QUEUED) and checkpoint-backed preemption (PREEMPTED).

Lifecycle state machine::

    REQUESTED --> DENIED
        |  \\
        |   +--> QUEUED ----------> DENIED | EXPIRED
        v           |
    APPROVED <------+
        |  \\
        |   +--> DENIED | EXPIRED
        v
    CONFIRMED --> EXPIRED
        |
        v
      ACTIVE <------------------+----------------+
        |  \\                    |                |
        |   +--> EXPIRED|FAILED |                | resume (re-grant,
        v                       |                |  possibly different
      RUNNING --> DONE --> EXPIRED               |  chips / mesh shape)
        |   \\                                   |
        |    +--> FAILED --> ACTIVE (recover)    |
        v                                        |
    PREEMPTED (drained + checkpointed, chips released) --> EXPIRED
        ^
        '-- scheduler evicts a lower-priority running block so a
            higher-priority waiter can be admitted; the victim re-enters
            the waitlist ahead of its fair-share class and is auto-resumed
            by ``tick()`` when capacity frees.  FAILED --> PREEMPTED covers
            deferred recovery: a chip-failed block whose replacement
            rectangle cannot be carved *right now* is checkpointed and
            parked for auto-resume instead of dying FAILED holding nothing.
"""
from __future__ import annotations

import dataclasses
import enum
import secrets
import time
from typing import Dict, List, Optional, Tuple

from repro.core.topology import Coord


class BlockState(str, enum.Enum):
    REQUESTED = "requested"       # (1) user registered an application
    QUEUED = "queued"             # (1b) admitted to the waitlist: pod full
    APPROVED = "approved"         # (2) admin reviewed, nodes assigned
    CONFIRMED = "confirmed"       # (3) user reconfirmed the assignment
    ACTIVE = "active"             # (3b) nodes powered, daemons up (runtime built)
    RUNNING = "running"           # (5) program uploaded and executing
    PREEMPTED = "preempted"       # (5b) evicted for a higher-priority block:
                                  #      drained, checkpointed, chips released
    DONE = "done"                 # (7) finished, results downloadable
    EXPIRED = "expired"           # usage period over, nodes shut down
    FAILED = "failed"             # chip failure / fatal error
    DENIED = "denied"             # admin rejected the application


# legal transitions of the lifecycle state machine
TRANSITIONS = {
    BlockState.REQUESTED: {BlockState.APPROVED, BlockState.DENIED,
                           BlockState.QUEUED},
    BlockState.QUEUED: {BlockState.APPROVED, BlockState.DENIED,
                        BlockState.EXPIRED},
    BlockState.APPROVED: {BlockState.CONFIRMED, BlockState.DENIED,
                          BlockState.EXPIRED},
    BlockState.CONFIRMED: {BlockState.ACTIVE, BlockState.EXPIRED},
    BlockState.ACTIVE: {BlockState.RUNNING, BlockState.EXPIRED,
                        BlockState.FAILED, BlockState.PREEMPTED},
    BlockState.RUNNING: {BlockState.DONE, BlockState.FAILED,
                         BlockState.EXPIRED, BlockState.ACTIVE,
                         BlockState.PREEMPTED},
    BlockState.PREEMPTED: {BlockState.ACTIVE, BlockState.EXPIRED},
    BlockState.FAILED: {BlockState.ACTIVE, BlockState.EXPIRED,
                        BlockState.PREEMPTED},
    BlockState.DONE: {BlockState.EXPIRED, BlockState.RUNNING},
}


@dataclasses.dataclass
class BlockRequest:
    user: str
    job_description: str
    n_chips: int
    arch: str = ""                    # architecture config id
    shape: str = "train_4k"           # input-shape cell
    duration_s: float = 3600.0        # requested usage period
    priority: int = 0                 # admission priority (higher = sooner)
    pod: Optional[int] = None         # admin pod pinning (None = any pod)
    deadline_s: Optional[float] = None  # SLO: wanted done this many seconds
                                        # after submission (None = no SLO)
    est_steps: Optional[int] = None   # user-declared work size; with the
                                      # Monitor's EWMA step time this gives
                                      # the admission-time completion
                                      # estimate slack ordering uses
    gang_id: Optional[str] = None     # co-scheduled set this block belongs
                                      # to (all-or-nothing admission)


@dataclasses.dataclass
class BlockGrant:
    block_id: str
    coords: List[Coord]               # admin-assigned chips (user-immutable)
    mesh_shape: Tuple[int, int]       # (data, model) within the block
    token: str                        # capability token (paper: MPD_SECRETWORD)
    expires_at: float                 # end of usage period

    @staticmethod
    def new(coords: List[Coord], mesh_shape: Tuple[int, int],
            duration_s: float) -> "BlockGrant":
        return BlockGrant(
            block_id=f"blk_{secrets.token_hex(4)}",
            coords=list(coords),
            mesh_shape=mesh_shape,
            token=secrets.token_hex(16),
            expires_at=time.time() + duration_s,
        )

    @property
    def n_chips(self) -> int:
        return len(self.coords)


@dataclasses.dataclass
class Block:
    request: BlockRequest
    state: BlockState = BlockState.REQUESTED
    grant: Optional[BlockGrant] = None
    history: List[Tuple[float, str]] = dataclasses.field(default_factory=list)
    result_path: Optional[str] = None
    failure_reason: Optional[str] = None
    queued_at: Optional[float] = None   # when the app entered the waitlist
    deadline_at: Optional[float] = None  # absolute SLO deadline, fixed at
                                         # submission (deadline_s is relative)
    # checkpoint-backed preemption bookkeeping (persisted by the Registry):
    # one record per eviction with the victim's progress state at that moment
    preemptions: List[Dict] = dataclasses.field(default_factory=list)

    @property
    def preempt_count(self) -> int:
        return len(self.preemptions)

    def record_preemption(self, reason: str, progress_lost_steps: int,
                          checkpoint_step: Optional[int],
                          from_state: str) -> None:
        self.preemptions.append({
            "t": time.time(),
            "reason": reason,
            "progress_lost_steps": int(progress_lost_steps),
            "checkpoint_step": checkpoint_step,
            "from_state": from_state,    # resume returns the block here
        })

    def transition(self, new_state: BlockState, note: str = "") -> None:
        if new_state not in TRANSITIONS.get(self.state, set()):
            raise ValueError(
                f"illegal transition {self.state.value} -> {new_state.value} "
                f"({self.request.user}: {note})")
        self.state = new_state
        self.history.append((time.time(), f"{new_state.value}: {note}"))

    @property
    def block_id(self) -> Optional[str]:
        return self.grant.block_id if self.grant else None
