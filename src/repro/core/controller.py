"""ClusterController — the paper's master node + administrator, TPU-native.

Owns the chip inventory (Partitioner), the application workflow (Registry),
per-block runtimes, the Monitor, and the BlockScheduler.  One controller
process drives *all* blocks concurrently (the shared-master property the
paper's Fig. 3 measures); dispatch is event-driven with per-block in-flight
windows, and requests the pod cannot fit are waitlisted and auto-admitted
as capacity frees (``submit``/``tick``) instead of raising.

Fault tolerance: chip-failure injection marks chips unhealthy, fails the
owning block, re-carves a fresh sub-mesh from the free pool and restores the
block's state from its checkpoint namespace.  Elastic resize uses the same
re-carve + reshard-restore path.

Preemption: ``preempt`` suspends a running block (drain → synchronous
checkpoint → release chips under the partitioner lock) and re-enters it on
the waitlist ahead of its fair-share class; ``resume`` re-grants chips
(possibly a different set / geometry) and restores from the checkpoint.
``tick()`` drives auto-resume as capacity frees.  The scheduler invokes the
same pair automatically when a strictly-higher-priority waiter can't fit.

Tenancy policy: the scheduler consults a ``SchedulingPolicy`` for per-user
quotas, deadline-slack ordering and preferred-victim choice;
``submit_gang``/``grant_gang`` admit multi-block jobs atomically
(all-or-nothing) via ``Partitioner.allocate_many``.

Observability: every lifecycle transition and scheduling decision is
published on the controller's ``EventBus`` (``repro.core.events``); the
``Monitor`` subscribes for its accounting and the web gateway's long-poll
feeds replay the same stream.  Callers outside ``repro.core`` should go
through the ``ClusterDaemon`` service layer rather than constructing a
controller directly.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax

from repro.analysis import runtime_check
from repro.core import interference
from repro.core.block import (Block, BlockGrant, BlockRequest, BlockState,
                              TRANSITIONS)
from repro.core.events import EventBus
from repro.core.monitor import Monitor
from repro.core.partition import AllocationError, mesh_shape_for
from repro.core.registry import Registry
from repro.core.runtime import BlockRuntime, JobSpec, SimJobSpec
from repro.core.scheduler import BlockScheduler, SimRuntime
from repro.core.topology import Coord, Topology
from repro.federation import (FederatedPartitioner, FederatedPlacer,
                              HealthMonitor, PodRegistry)
from repro.federation.pods import POD_DEAD, POD_READY, to_local
from repro.obs.flight import RECORDER
from repro.obs.trace import TRACER
from repro.train import compile_cache

# lifecycle states that hold chips (a PREEMPTED block holds nothing)
_HOLDING = (BlockState.APPROVED, BlockState.CONFIRMED, BlockState.ACTIVE,
            BlockState.RUNNING, BlockState.DONE)


class ClusterController:
    def __init__(self, topo: Topology, devices: Optional[Sequence] = None,
                 ckpt_root: str = "artifacts/ckpt",
                 state_path: Optional[str] = None,
                 bus: Optional[EventBus] = None,
                 placer: Optional[FederatedPlacer] = None):
        self.topo = topo
        self.devices = list(devices) if devices is not None else jax.devices()
        if len(self.devices) < topo.n_chips:
            raise ValueError(
                f"topology needs {topo.n_chips} devices, have "
                f"{len(self.devices)} (set xla_force_host_platform_device_count)")
        # the event bus is the observable spine: the registry publishes
        # every lifecycle transition, scheduler/controller publish the
        # scheduling decisions, and the Monitor subscribes instead of
        # being called directly
        self.bus = bus or EventBus()
        self.monitor = Monitor()
        self.monitor.subscribe_to(self.bus)
        # compile-cache hit/miss events flow onto this controller's bus
        # (process-wide cache: reuse spans every block the host runs)
        compile_cache.GLOBAL.set_bus(self.bus)
        # the boot topology is carved into one federation pod per paper pod
        # (pod p owns the matching contiguous device slice, preserving the
        # pre-federation chip_index device mapping); more pods attach and
        # detach at runtime via attach_pod/detach_pod
        self.pods = PodRegistry(bus=self.bus)
        pod_chips = topo.pod_x * topo.pod_y
        for p in range(topo.n_pods):
            self.pods.attach(
                topo.pod_x, topo.pod_y,
                self.devices[p * pod_chips:(p + 1) * pod_chips],
                name=f"boot{p}", boot=True, pod_id=p)
        self.placer = placer or FederatedPlacer()
        self.partitioner = FederatedPartitioner(self.pods, self.placer)
        self.health = HealthMonitor(self.pods)
        self.registry = Registry(state_path=state_path, bus=self.bus)
        # re-attach runtime pods recorded in the registry snapshot (their
        # devices are not persistable — they come back as sim pods on the
        # host's first device, the same replication the CI smokes use)
        for entry in self.registry.pods_snapshot():
            pid = int(entry["pod_id"])
            if (entry.get("boot") or entry.get("phase") == POD_DEAD
                    or self.pods.get(pid) is not None):
                continue
            px, py = int(entry["pod_x"]), int(entry["pod_y"])
            self.pods.attach(px, py, [self.devices[0]] * (px * py),
                             name=entry.get("name"), pod_id=pid,
                             power_budget_chips=entry.get(
                                 "power_budget_chips"))
            phase = entry.get("phase", POD_READY)
            if phase != POD_READY:        # draining/degraded survives reboot
                self.pods.set_phase(pid, phase)
        self.runtimes: Dict[str, BlockRuntime] = {}   # app_id -> runtime
        self.ckpt_root = ckpt_root
        self.scheduler = BlockScheduler(self)
        # installed by the ClusterDaemon: the autostep engine, consulted so
        # a preemption harvests (publishes) an engine-driven victim's
        # in-flight completions instead of silently discarding them
        self.engine = None

    # -------------------------------------------------- device mapping
    def devices_for(self, coords: Sequence[Coord]) -> List:
        out = []
        for c in coords:
            pod = self.pods.pod(c[0])
            out.append(pod.devices[pod.topo.chip_index((0, c[1], c[2]))])
        return out

    def total_chips(self) -> int:
        """Federation-wide capacity (live pods only)."""
        return self.pods.total_chips()

    # -------------------------------------------------- workflow (Fig. 2)
    def register(self, user: str, job_description: str, n_chips: int,
                 arch: str = "", shape: str = "train_4k",
                 duration_s: float = 3600.0, priority: int = 0,
                 deadline_s: Optional[float] = None,
                 est_steps: Optional[int] = None) -> str:
        return self.registry.register(BlockRequest(
            user=user, job_description=job_description, n_chips=n_chips,
            arch=arch, shape=shape, duration_s=duration_s,
            priority=priority, deadline_s=deadline_s, est_steps=est_steps))

    def submit(self, user: str, job_description: str, n_chips: int,
               job: Optional[JobSpec] = None, priority: int = 0,
               pod: Optional[int] = None, now: Optional[float] = None,
               **register_kw):
        """Automated admission (no admin in the loop): register and either
        admit now or waitlist until capacity frees.  Returns
        ``(app_id, grant-or-None)``; with a ``job`` the block is activated
        and run the moment it is admitted.  ``now`` keeps deadline/wait
        accounting on the model clock under a simulated-clock driver."""
        app_id = self.register(user, job_description, n_chips,
                               priority=priority, **register_kw)
        grant = self.scheduler.submit(app_id, job=job, pod=pod, now=now)
        return app_id, grant

    def submit_gang(self, user: str, members: Sequence[Tuple],
                    priority: int = 0, pod: Optional[int] = None,
                    deadline_s: Optional[float] = None,
                    now: Optional[float] = None, **register_kw):
        """Atomic multi-block submission (paper follow-up arXiv:0708.3446:
        jobs spanning several blocks at once).  ``members`` is a sequence of
        ``(job_description, n_chips)`` or ``(job_description, n_chips,
        JobSpec-or-None)`` tuples.  Every member is admitted together — all
        co-start — or the whole gang is waitlisted as one unit.  Returns
        ``(app_ids, {app_id: grant} or None)``."""
        app_ids: List[str] = []
        jobs: Dict[str, JobSpec] = {}
        for member in members:
            desc, n_chips = member[0], member[1]
            job = member[2] if len(member) > 2 else None
            app_id = self.register(user, desc, n_chips, priority=priority,
                                   deadline_s=deadline_s, **register_kw)
            app_ids.append(app_id)
            if job is not None:
                jobs[app_id] = job
        grants = self.scheduler.submit_gang(app_ids, jobs=jobs, pod=pod,
                                            now=now)
        return app_ids, grants

    def grant_block(self, app_id: str, n_chips: int,
                    pod: Optional[int] = None) -> BlockGrant:
        """Grant finalization (shared by admin review and scheduler
        admission): allocate under a pending reservation, mint the grant,
        re-tag the chips to the real block id atomically — a concurrent
        allocate must never observe them as free mid-retag — and approve.
        Raises AllocationError (leaving no chips held) when nothing fits."""
        blk = self.registry.get(app_id)
        tmp_grant_id = f"pending_{app_id}"
        coords = self.partitioner.allocate(n_chips, tmp_grant_id, pod=pod)
        grant = BlockGrant.new(coords, mesh_shape_for(n_chips),
                               blk.request.duration_s)
        self.partitioner.retag(tmp_grant_id, grant.block_id)
        try:
            self.registry.approve(app_id, grant)
        except Exception:
            # e.g. illegal transition (review of an already-approved app):
            # give the chips back instead of leaking them under an orphan id
            self.partitioner.release(grant.block_id)
            raise
        return grant

    def grant_gang(self, app_ids: Sequence[str]) -> Dict[str, BlockGrant]:
        """Gang grant finalization: every member's rectangle is found under
        ONE partitioner lock hold (``allocate_many``) and rolled back on
        partial failure, so either every member gets a grant or the
        inventory is bit-identical to before the call.  Member states are
        validated up front so the post-allocation approve loop cannot fail
        halfway through."""
        for app_id in app_ids:
            blk = self.registry.get(app_id)
            if BlockState.APPROVED not in TRANSITIONS.get(blk.state, set()):
                raise ValueError(
                    f"gang member {app_id} in state {blk.state.value} "
                    f"cannot be approved")
        specs = [(self.registry.get(a).request.n_chips, f"pending_{a}",
                  self.registry.get(a).request.pod) for a in app_ids]
        alloc = self.partitioner.allocate_many(specs)
        grants: Dict[str, BlockGrant] = {}
        try:
            for app_id in app_ids:
                blk = self.registry.get(app_id)
                coords = alloc[f"pending_{app_id}"]
                grant = BlockGrant.new(coords, mesh_shape_for(len(coords)),
                                       blk.request.duration_s)
                self.partitioner.retag(f"pending_{app_id}", grant.block_id)
                try:
                    self.registry.approve(app_id, grant)
                except Exception:
                    self.partitioner.release(grant.block_id)
                    raise
                grants[app_id] = grant
        except Exception:
            # all-or-nothing extends to grant finalization: an approve that
            # raises mid-loop (e.g. registry persist I/O error) must not
            # leave earlier members holding chips or later members' pending
            # reservations leaked.  Denies are best-effort (the registry's
            # persist may be the very thing failing); chip release is what
            # must never be skipped.
            for a in app_ids:
                self.partitioner.release(f"pending_{a}")
            for a, g in grants.items():
                self.partitioner.release(g.block_id)
            for a in app_ids:
                blk = self.registry.get(a)
                # includes the member whose approve raised *after* its
                # APPROVED transition: it must not stay APPROVED holding a
                # grant whose chips were just released
                if a in grants or blk.state == BlockState.APPROVED:
                    try:
                        self.registry.deny(a, "gang grant finalization failed")
                    except Exception:
                        pass
            raise
        return grants

    def review(self, app_id: str, *, approve: bool = True,
               pod: Optional[int] = None, n_chips: Optional[int] = None) -> Optional[BlockGrant]:
        """Admin review: assign a contiguous block (possibly a different size
        than requested — the admin has full control, paper §3)."""
        blk = self.registry.get(app_id)
        if not approve:
            self.registry.deny(app_id, "admin denied")
            return None
        return self.grant_block(app_id, n_chips or blk.request.n_chips,
                                pod=pod)

    def confirm(self, app_id: str, token: str) -> None:
        self.registry.confirm(app_id, token)

    def activate(self, app_id: str, job):
        """Power on the block's chips and boot its runtime (paper: switch
        nodes on + activate the user's MPD daemons).  A ``SimJobSpec``
        boots the device-free wall-clock simulator instead of a real
        runtime — the gateway's sim jobs and scheduler benchmarks drive
        the identical lifecycle without XLA."""
        blk = self.registry.get(app_id)
        assert blk.grant is not None
        with TRACER.span("ctl.activate", cat="ctl", app_id=app_id,
                         user=blk.request.user):
            if isinstance(job, SimJobSpec):
                rt = SimRuntime(job.step_s, ckpt_every=job.ckpt_every)
            else:
                devices = self.devices_for(blk.grant.coords)
                rt = BlockRuntime(blk.grant, job, devices, self.ckpt_root)
                rt.init_state()
                self._attach_roofline(blk, rt)
            self.runtimes[app_id] = rt
            self.registry.set_state(app_id, BlockState.ACTIVE,
                                    "runtime built")
            return rt

    def _attach_roofline(self, blk, rt) -> None:
        """Give the Monitor this block's roofline model (useful FLOPs per
        step + modeled step-time floor) so its step-time EWMA reads back as
        achieved-vs-peak utilization.  Re-run on every rebuild: a resume on
        fewer chips changes the denominator."""
        job = getattr(rt, "job", None)
        if job is None or blk.block_id is None:
            return
        try:
            from repro.launch import hlo_analysis
            self.monitor.set_roofline(
                blk.block_id,
                hlo_analysis.block_roofline(job.cfg, job.shape,
                                            len(blk.grant.coords)))
        except Exception:
            pass    # monitoring garnish: never block activation on it

    def run(self, app_id: str) -> None:
        self.registry.set_state(app_id, BlockState.RUNNING, "job started")

    def download(self, app_id: str) -> Dict:
        """Step (7): the user collects results (metrics + checkpoint path)."""
        blk = self.registry.get(app_id)
        rt = self.runtimes.get(app_id)
        stats = self.monitor.stats.get(blk.block_id or "", None)
        if blk.state == BlockState.RUNNING:
            self.registry.set_state(app_id, BlockState.DONE, "results ready")
        ckpt = getattr(rt, "ckpt", None)      # SimRuntime has no manager
        return {
            "steps": rt.step_count if rt else 0,
            "metrics": stats.last_metrics if stats else {},
            "checkpoints": ckpt.steps() if ckpt else [],
            "checkpoint_dir": ckpt.dir if ckpt else None,
        }

    def expire(self, app_id: str, now: Optional[float] = None) -> None:
        """Usage period over: shut nodes down, free the block, and admit
        whatever the freed capacity now fits from the waitlist.  (A block
        whose period ends while PREEMPTED holds no chips — it simply never
        resumes.)  The runtime is drained *before* its chips are released:
        async dispatches could otherwise still be executing on chips the
        next ``pump()`` hands to another block.  ``now`` (model time under
        a simulated clock) flows through to the pump's wait accounting."""
        blk = self.registry.get(app_id)
        rt = self.runtimes.pop(app_id, None)
        if rt is not None:
            drain = getattr(rt, "drain", None)
            if drain is not None:
                drain()
        if blk.grant:
            self.partitioner.release(blk.grant.block_id)
        self.registry.set_state(app_id, BlockState.EXPIRED, "period over")
        self.scheduler.pump(now)

    # ------------------------------------------------------- preemption
    def preempt(self, app_id: str, reason: str = "admin preempt",
                now: Optional[float] = None) -> None:
        """Evict a running/active block: drain its in-flight dispatches,
        checkpoint synchronously (suspend), release its chips — the
        partitioner's lock makes the release atomic w.r.t. concurrent
        allocates — and park it on the waitlist (PREEMPTED) ahead of its
        fair-share class for auto-resume."""
        blk = self.registry.get(app_id)
        # validate before any irreversible step: suspend/release must not
        # run if the PREEMPTED transition would be rejected afterwards
        if blk.state not in (BlockState.RUNNING, BlockState.ACTIVE):
            raise ValueError(
                f"cannot preempt {app_id} in state {blk.state.value}")
        assert blk.grant is not None, f"{app_id} holds no grant"
        with TRACER.span("ctl.preempt", cat="ctl", app_id=app_id,
                         user=blk.request.user, reason=reason):
            self._preempt_body(app_id, blk, reason, now)

    def _preempt_body(self, app_id: str, blk, reason: str,
                      now: Optional[float]) -> None:
        rt = self.runtimes.get(app_id)
        if self.engine is not None:
            # engine-driven victims: publish the in-flight completions as
            # step events before the suspend discards them (the drive
            # stays armed and re-arms itself when the block resumes)
            self.engine.drain_block(app_id, now=now)
        # progress measured *before* the suspend-save: what a non-graceful
        # kill would have lost, and what victim selection minimized
        progress_lost = int(getattr(rt, "progress_lost", 0) or 0)
        info = rt.suspend() if rt is not None else {}
        self.partitioner.release(blk.grant.block_id)
        seq = self.registry.mark_preempted(
            app_id, reason, progress_lost_steps=progress_lost,
            checkpoint_step=(int(info["step"]) if info else None),
            now=now)
        self.bus.publish("preempted", app_id=app_id, block_id=blk.block_id,
                         user=blk.request.user, now=now, reason=reason,
                         progress_lost_steps=progress_lost,
                         checkpoint_step=(int(info["step"]) if info
                                          else None))
        self.scheduler.requeue_preempted(app_id, seq)

    def resume(self, app_id: str,
               n_chips: Optional[int] = None) -> BlockGrant:
        """Re-admit a PREEMPTED block: carve a fresh sub-mesh (possibly
        different chips; pass ``n_chips`` to resume on a different
        geometry), rebuild the runtime there and restore from the
        checkpoint.  Keeps the block's identity, token and expiry.  Raises
        AllocationError — holding nothing — when the pod can't fit it yet
        (the scheduler then keeps it queued)."""
        blk = self.registry.get(app_id)
        assert blk.state == BlockState.PREEMPTED, (app_id, blk.state)
        assert blk.grant is not None
        with TRACER.span("ctl.resume", cat="ctl", app_id=app_id,
                         user=blk.request.user):
            return self._resume_body(app_id, blk, n_chips)

    def _resume_body(self, app_id: str, blk,
                     n_chips: Optional[int]) -> BlockGrant:
        old = blk.grant
        old_pod = old.coords[0][0] if old.coords else None
        n = n_chips or old.n_chips
        coords = self.partitioner.allocate(n, old.block_id,
                                           pod=blk.request.pod)
        new_grant = BlockGrant(block_id=old.block_id, coords=coords,
                               mesh_shape=mesh_shape_for(n),
                               token=old.token, expires_at=old.expires_at)
        rt = self.runtimes.get(app_id)
        if rt is not None:
            try:
                rt.resume(new_grant, self.devices_for(coords))
            except Exception:
                self.partitioner.release(old.block_id)
                raise
        blk.grant = new_grant
        if rt is not None:
            self._attach_roofline(blk, rt)   # chip count may have changed
        self.registry.set_state(
            app_id, BlockState.ACTIVE,
            f"resumed on {n} chips at step "
            f"{rt.step_count if rt is not None else 0}")
        # return to the pre-preemption lifecycle position: a block that was
        # only ACTIVE (user never started the job) must not come back RUNNING
        if blk.preemptions and blk.preemptions[-1].get("from_state") == \
                BlockState.RUNNING.value:
            self.registry.set_state(app_id, BlockState.RUNNING, "resumed")
        self.bus.publish("resumed", app_id=app_id,
                         block_id=new_grant.block_id, user=blk.request.user,
                         n_chips=n,
                         step=(rt.step_count if rt is not None else 0))
        if old_pod is not None and coords and coords[0][0] != old_pod:
            # cross-pod resume: the block migrated toward other capacity
            self.bus.publish("migrated", app_id=app_id,
                             block_id=new_grant.block_id,
                             user=blk.request.user, from_pod=old_pod,
                             to_pod=coords[0][0], n_chips=n)
        return new_grant

    @runtime_check.guard_serialized("control-plane")
    def tick(self, now: Optional[float] = None) -> List[str]:
        """Periodic housekeeping: auto-expire blocks past their period,
        advance pod health (evicting residents of newly dead pods), admit
        from the waitlist (including auto-resume of preempted blocks),
        sample federation utilization."""
        expired = self.registry.expired(now)
        for app_id in expired:
            self.expire(app_id, now=now)
        for pod_id in self.health.check(now):
            self.fail_pod(pod_id, reason="missed heartbeats", now=now)
        # sample_util: the pump publishes the utilization sample from the
        # held-chips snapshot it already computes per round — no second
        # inventory scan here (one sample per tick, as before)
        self.scheduler.pump(now, sample_util=True)
        return expired

    # ---------------------------------------------------------- federation
    def attach_pod(self, pod_x: int, pod_y: int, name: Optional[str] = None,
                   devices: Optional[Sequence] = None,
                   power_budget_chips: Optional[float] = None,
                   now: Optional[float] = None) -> Dict:
        """Attach capacity at runtime.  The pump runs immediately after, so
        QUEUED and PREEMPTED blocks migrate toward the new pod without the
        daemon restarting.  Without explicit ``devices`` the pod replicates
        the host's first device (a sim pod — the CI dashboard idiom)."""
        n = pod_x * pod_y
        pod = self.pods.attach(
            pod_x, pod_y,
            list(devices) if devices is not None else [self.devices[0]] * n,
            name=name, power_budget_chips=power_budget_chips, now=now)
        self.registry.store_pods(self.pods.snapshot())
        self.scheduler.pump(now)
        return pod.describe()

    def drain_pod(self, pod_id: int, now: Optional[float] = None) -> Dict:
        """Stop placing new blocks on the pod; residents keep running."""
        pod = self.pods.set_phase(pod_id, "draining", now=now)
        self.registry.store_pods(self.pods.snapshot())
        return pod.describe()

    def detach_pod(self, pod_id: int, force: bool = False,
                   now: Optional[float] = None) -> Dict:
        """Remove a pod.  Refuses while blocks are resident unless
        ``force``, which evicts them first (preempt + migrate, the same
        path a pod death takes — graceful, so nothing is lost)."""
        pod = self.pods.pod(pod_id)            # KeyError -> unknown pod
        residents = self.pod_residents(pod_id)
        if residents and not force:
            raise ValueError(
                f"pod {pod_id} has {len(residents)} resident block(s); "
                f"drain first or detach with force")
        if residents:
            # drain before evicting: a READY pod would satisfy the
            # migration's resize *in place* and the residents would never
            # leave the pod being removed
            self.pods.set_phase(pod_id, "draining", now=now)
            self._evict_pod_residents(pod_id, f"pod {pod.name} detached",
                                      now=now)
        self.pods.detach(pod_id, now=now)
        self.registry.store_pods(self.pods.snapshot())
        self.scheduler.pump(now)
        return pod.describe()

    def fail_pod(self, pod_id: int, reason: str = "pod died",
                 now: Optional[float] = None) -> List[str]:
        """A pod (and every chip in it) is gone: mark it dead, evict every
        resident block into PREEMPTED via its checkpoint, and migrate them
        toward surviving capacity.  Returns the evicted app ids."""
        self.pods.set_phase(pod_id, POD_DEAD, now=now)
        self.registry.store_pods(self.pods.snapshot())
        victims = self._evict_pod_residents(pod_id, reason, now=now)
        # postmortem after the eviction sweep: the victims' final
        # preempted/state events and spans are in the recorder's ring by
        # now, so the artifact captures each one's last moments
        RECORDER.dump("pod_death", apps=victims, now=now,
                      detail={"pod": pod_id, "reason": reason})
        self.scheduler.pump(now)
        return victims

    def pod_heartbeat(self, pod_id: int,
                      now: Optional[float] = None) -> Dict:
        """Health heartbeat from a pod agent; first beat arms monitoring."""
        return self.health.beat(pod_id, now=now).describe()

    def pod_residents(self, pod_id: int) -> List[str]:
        """App ids currently holding chips on this pod."""
        out = []
        for app_id in self.registry.by_state(*_HOLDING):
            blk = self.registry.get(app_id)
            if (blk.grant is not None and blk.grant.coords
                    and blk.grant.coords[0][0] == pod_id):
                out.append(app_id)
        return out

    def _evict_pod_residents(self, pod_id: int, reason: str,
                             now: Optional[float] = None) -> List[str]:
        """Clear every resident block off a pod, leaking nothing: executing
        blocks preempt (checkpoint, release, requeue ahead of class);
        non-executing holders migrate their grant to another pod, or
        terminate cleanly when nothing fits anywhere."""
        victims = []
        for app_id in self.pod_residents(pod_id):
            blk = self.registry.get(app_id)
            victims.append(app_id)
            if blk.state in (BlockState.ACTIVE, BlockState.RUNNING):
                self.preempt(app_id, reason=reason, now=now)
                continue
            # APPROVED/CONFIRMED/DONE: chips but no executing job — same
            # handling as a chip failure before activation
            try:
                coords = self.partitioner.resize(blk.grant.block_id,
                                                 blk.grant.n_chips)
                blk.grant = BlockGrant(block_id=blk.grant.block_id,
                                       coords=coords,
                                       mesh_shape=blk.grant.mesh_shape,
                                       token=blk.grant.token,
                                       expires_at=blk.grant.expires_at)
                old_rt = self.runtimes.get(app_id)
                if old_rt is not None:
                    self.runtimes[app_id] = BlockRuntime.rebuild(
                        old_rt, blk.grant, self.devices_for(coords),
                        self.ckpt_root)
                self.registry.persist()
                self.bus.publish("migrated", app_id=app_id,
                                 block_id=blk.block_id,
                                 user=blk.request.user, now=now,
                                 from_pod=pod_id, to_pod=coords[0][0],
                                 n_chips=len(coords))
            except AllocationError:
                rt = self.runtimes.pop(app_id, None)
                drain = getattr(rt, "drain", None)
                if drain is not None:
                    drain()
                self.partitioner.release(blk.grant.block_id)
                self.registry.set_state(
                    app_id, BlockState.EXPIRED,
                    f"{reason}; no replacement rectangle free — resubmit")
        return victims

    # ------------------------------------------------ concurrent execution
    def step_all(self, rounds: int = 1, sync_every: int = 1) -> Dict[str, List[Dict]]:
        """Step every RUNNING block ``rounds`` times, event-driven.

        Delegates to the BlockScheduler's dispatch loop: completions are
        harvested in device-finish order with per-block in-flight windows
        (``sync_every`` = dispatch depth), so a slow block no longer stalls
        fast blocks on the host thread the way the old fixed-order
        round-robin ``block_until_ready`` did.
        """
        return self.scheduler.run_dispatch(
            rounds, max_inflight=max(1, sync_every))

    # ------------------------------------------------------ fault handling
    def inject_chip_failure(self, coord: Coord,
                            now: Optional[float] = None) -> Optional[str]:
        """Simulate a chip failure.  Returns the app_id that was failed over
        (recovered now, or requeued for deferred recovery), if any block
        owned the chip."""
        block_id = self.partitioner.mark_unhealthy(coord)
        if block_id is None:
            return None
        app_id = self.registry.by_block_id(block_id)
        if app_id is None:
            return None
        blk = self.registry.get(app_id)
        pre_failure_state = blk.state
        blk.failure_reason = f"chip {coord} failed"
        if pre_failure_state in (BlockState.ACTIVE, BlockState.RUNNING):
            self.registry.set_state(app_id, BlockState.FAILED, str(coord))
            self.recover_block(app_id, from_state=pre_failure_state.value,
                               now=now)
            return app_id
        # non-executing holder (APPROVED/CONFIRMED own chips from grant
        # time but have no runtime; a DONE block keeps one for result
        # download) — FAILED is not even a legal transition here.  Re-carve
        # the grant in place; when nothing healthy fits, terminate the
        # grant cleanly instead of leaving the block stranded on a dead
        # chip.
        try:
            coords = self.partitioner.resize(block_id, blk.grant.n_chips,
                                             pod=blk.request.pod)
            blk.grant = BlockGrant(block_id=block_id, coords=coords,
                                   mesh_shape=blk.grant.mesh_shape,
                                   token=blk.grant.token,
                                   expires_at=blk.grant.expires_at)
            old_rt = self.runtimes.get(app_id)
            if old_rt is not None:
                # a DONE block's runtime must follow its grant onto the new
                # chips — DONE -> RUNNING is legal, so a stale device set
                # would execute on the dead chip if the job were restarted
                self.runtimes[app_id] = BlockRuntime.rebuild(
                    old_rt, blk.grant, self.devices_for(coords),
                    self.ckpt_root)
            self.registry.persist()
        except AllocationError:
            rt = self.runtimes.pop(app_id, None)
            drain = getattr(rt, "drain", None)
            if drain is not None:
                drain()
            self.partitioner.release(block_id)
            self.registry.set_state(
                app_id, BlockState.EXPIRED,
                f"chip {coord} failed before activation, no replacement "
                f"rectangle free — resubmit")
            self.scheduler.pump(now)
        return app_id

    def recover_block(self, app_id: str,
                      from_state: Optional[str] = None,
                      now: Optional[float] = None
                      ) -> Optional[BlockRuntime]:
        """Re-carve a healthy sub-mesh and restore from checkpoint.

        The replacement rectangle is found with the block's own (healthy)
        chips treated as free, under one partitioner lock hold
        (``Partitioner.resize`` at the same size) — the old
        release-before-allocate sequence opened a window where a concurrent
        ``submit()``/``pump()`` could steal the freed chips and recovery
        died with AllocationError, leaving the block FAILED holding nothing
        and never requeued.  When no healthy rectangle exists *right now*,
        the block is checkpointed and requeued (PREEMPTED) for auto-resume
        once capacity frees, and None is returned.  ``from_state`` is the
        pre-*failure* lifecycle state (so a deferred auto-resume returns an
        ACTIVE block to ACTIVE, not RUNNING)."""
        blk = self.registry.get(app_id)
        old_rt = self.runtimes.get(app_id)
        assert blk.grant is not None and old_rt is not None
        try:
            coords = self.partitioner.resize(blk.grant.block_id,
                                             blk.grant.n_chips,
                                             pod=blk.request.pod)
        except AllocationError:
            # deferred recovery: suspend (drain -> sync checkpoint -> drop
            # device refs), free the remains, park for auto-resume — the
            # pre-failure position was RUNNING, so resume returns it there
            progress_lost = int(getattr(old_rt, "progress_lost", 0) or 0)
            info = old_rt.suspend()
            self.partitioner.release(blk.grant.block_id)
            seq = self.registry.mark_preempted(
                app_id, "recovery deferred: no healthy rectangle free",
                progress_lost_steps=progress_lost,
                checkpoint_step=(int(info["step"]) if info else None),
                from_state=from_state or BlockState.RUNNING.value,
                now=now)
            self.bus.publish("preempted", app_id=app_id,
                             block_id=blk.block_id, user=blk.request.user,
                             now=now,
                             reason="recovery deferred: no healthy "
                                    "rectangle free",
                             progress_lost_steps=progress_lost,
                             checkpoint_step=(int(info["step"]) if info
                                              else None))
            self.scheduler.requeue_preempted(app_id, seq)
            return None
        new_grant = BlockGrant(block_id=blk.grant.block_id, coords=coords,
                               mesh_shape=blk.grant.mesh_shape,
                               token=blk.grant.token,
                               expires_at=blk.grant.expires_at)
        blk.grant = new_grant
        rt = BlockRuntime.rebuild(old_rt, new_grant,
                                  self.devices_for(coords), self.ckpt_root)
        self.runtimes[app_id] = rt
        self.registry.set_state(app_id, BlockState.ACTIVE, "recovered")
        # return to the pre-failure lifecycle position: an ACTIVE block
        # whose job was never started must not come back RUNNING
        if from_state is None or from_state == BlockState.RUNNING.value:
            self.registry.set_state(app_id, BlockState.RUNNING, "resumed")
        return rt

    def resize_block(self, app_id: str, new_n_chips: int) -> BlockRuntime:
        """Elastic scaling: grow/shrink a running block; state is resharded
        onto the new sub-mesh via checkpoint restore."""
        blk = self.registry.get(app_id)
        old_rt = self.runtimes[app_id]
        old_rt.save(async_=False)
        coords = self.partitioner.resize(blk.grant.block_id, new_n_chips)
        new_grant = BlockGrant(block_id=blk.grant.block_id, coords=coords,
                               mesh_shape=mesh_shape_for(new_n_chips),
                               token=blk.grant.token,
                               expires_at=blk.grant.expires_at)
        blk.grant = new_grant
        rt = BlockRuntime.rebuild(old_rt, new_grant,
                                  self.devices_for(coords), self.ckpt_root)
        self.runtimes[app_id] = rt
        self._attach_roofline(blk, rt)       # new chip-count denominator
        self.scheduler.pump()   # a shrink may free room for queued blocks
        return rt

    # ------------------------------------------------------- interference
    def interference_report(self) -> interference.InterferenceReport:
        """Link contention among executing blocks, analyzed per pod in each
        pod's own geometry (blocks in different pods share zero fabric by
        construction — only the abstract DCN — so cross-pod pairs are
        recorded as zero shared links)."""
        by_pod: Dict[int, Dict[str, List[Coord]]] = {}
        for app_id in self.registry.by_state(BlockState.ACTIVE,
                                             BlockState.RUNNING):
            blk = self.registry.get(app_id)
            pid = blk.grant.coords[0][0]
            by_pod.setdefault(pid, {})[blk.block_id] = to_local(
                blk.grant.coords)
        block_links: Dict[str, int] = {}
        shared: Dict[Tuple[str, str], int] = {}
        slowdown: Dict[str, float] = {}
        for pid, blocks in sorted(by_pod.items()):
            pod = self.pods.get(pid)
            if pod is None:
                continue
            rep = interference.analyze_blocks(pod.topo, blocks)
            block_links.update(rep.block_links)
            shared.update(rep.shared_links)
            slowdown.update(rep.slowdown)
        ids = sorted(block_links)
        for i in range(len(ids)):
            for j in range(i + 1, len(ids)):
                shared.setdefault((ids[i], ids[j]), 0)
        return interference.InterferenceReport(
            block_links=block_links, shared_links=shared, slowdown=slowdown)
