"""Monitoring: per-block heartbeats, step-time EWMA, straggler detection,
usage accounting.  The paper's step (6): "the administrator and automated
system will monitor the usage of all running users".

The Monitor is one observability consumer among several: it aggregates
in-process roll-ups (EWMAs, straggler sets, usage totals) that feed the
scheduler and dashboards, while ``repro.obs`` carries the rest of the
story — the metrics bridge turns the same bus events into Prometheus
series, the tracer records request-scoped spans, and the flight recorder
keeps the raw event tail for postmortems.  ``stragglers()`` is surfaced
both per-block (``daemon.status``) and as the ``repro_stragglers``
gauge.
"""
from __future__ import annotations

import dataclasses
import statistics
import threading
import time
from typing import Dict, List, Optional


@dataclasses.dataclass
class BlockStats:
    block_id: str
    steps: int = 0
    last_heartbeat: float = 0.0
    ewma_step_s: Optional[float] = None
    step_times: List[float] = dataclasses.field(default_factory=list)
    chip_seconds: float = 0.0
    last_metrics: Dict[str, float] = dataclasses.field(default_factory=dict)
    # roofline model (set once at runtime attach): model_flops, n_chips,
    # peak_flops, step_time_s (modeled floor), bottleneck, source
    roofline: Optional[Dict] = None


class Monitor:
    EWMA_ALPHA = 0.2
    STRAGGLER_FACTOR = 1.5
    HEARTBEAT_TIMEOUT_S = 60.0

    def __init__(self):
        self._lock = threading.RLock()
        self.stats: Dict[str, BlockStats] = {}
        # admission-queue accounting (BlockScheduler feeds these)
        self.queue_depth = 0
        self.enqueued_total = 0
        self.admitted_total = 0
        self.queue_waits: List[float] = []       # seconds queued per admission
        # admission waits keyed by the actual priority value (not a binary
        # high/normal bin — with >= 3 priority levels binning corrupts the
        # per-class p50s); preemption_report aggregates classes
        self.queue_waits_by_class: Dict[int, List[float]] = {}
        self.util_samples: List[float] = []      # fraction of chips in use
        # deadline/SLO accounting (scheduler feeds admission-time slack)
        self.deadline_hits = 0
        self.deadline_misses = 0
        self.admission_slacks: List[float] = []  # deadline - admission time
        # preemption accounting (controller.preempt / scheduler feed these)
        self.preempted_total = 0
        self.resumed_total = 0
        self.progress_lost_steps: List[int] = []  # per eviction, pre-save
        self.resume_waits: List[float] = []       # seconds evicted->resumed
        # compile-cache accounting (CompileCache publishes kind="compile")
        self.compile_hits_total = 0
        self.compile_misses_total = 0
        # federation accounting (pod lifecycle + cross-pod migration)
        self.pods_joined_total = 0
        self.pods_lost_total = 0                  # left or died
        self.pods_degraded_total = 0
        self.migrated_total = 0
        self.migrations: List[Dict] = []          # {app_id, from_pod, to_pod}

    def _get(self, block_id: str) -> BlockStats:
        with self._lock:
            if block_id not in self.stats:
                self.stats[block_id] = BlockStats(block_id)
            return self.stats[block_id]

    # -------------------------------------------------- event subscription
    def on_event(self, ev) -> None:
        """EventBus subscriber: translate semantic lifecycle events into
        the accounting the ``record_*`` API keeps.  The scheduler and
        controller publish events instead of calling the Monitor directly;
        this mapping preserves the old call-for-call behavior (e.g. an
        ``immediate`` admission only records its SLO outcome, exactly like
        the old bare ``record_deadline`` call did)."""
        p = ev.payload
        if ev.kind == "step":
            self.record_step(ev.block_id, p["step_s"], p["n_chips"],
                             metrics=p.get("metrics"))
        elif ev.kind == "enqueued":
            self.record_enqueue(ev.app_id)
        elif ev.kind == "dequeued":
            self.record_dequeue(ev.app_id)
        elif ev.kind == "admitted":
            if p.get("immediate"):
                if p.get("slack_s") is not None:
                    self.record_deadline(p["slack_s"])
            else:
                self.record_admission(ev.app_id, p["wait_s"],
                                      priority=p.get("priority", 0),
                                      slack_s=p.get("slack_s"))
                if p.get("resumed"):
                    self.record_resume(ev.app_id, p["wait_s"])
        elif ev.kind == "preempted":
            self.record_preemption(ev.block_id,
                                   p.get("progress_lost_steps", 0))
        elif ev.kind == "utilization":
            self.sample_utilization(p["used_chips"], p["total_chips"])
        elif ev.kind == "pod":
            self.record_pod_event(p.get("action", ""))
        elif ev.kind == "migrated":
            self.record_migration(ev.app_id, p.get("from_pod"),
                                  p.get("to_pod"))
        elif ev.kind == "compile":
            self.record_compile(p.get("action", ""))

    def subscribe_to(self, bus) -> None:
        bus.subscribe(self.on_event,
                      kinds={"step", "enqueued", "dequeued", "admitted",
                             "preempted", "utilization", "pod", "migrated",
                             "compile"})

    def record_step(self, block_id: str, step_s: float, n_chips: int,
                    metrics: Optional[Dict[str, float]] = None) -> None:
        with self._lock:
            s = self._get(block_id)
            s.steps += 1
            s.last_heartbeat = time.time()
            s.step_times.append(step_s)
            if len(s.step_times) > 512:
                s.step_times = s.step_times[-256:]
            s.ewma_step_s = (step_s if s.ewma_step_s is None else
                             (1 - self.EWMA_ALPHA) * s.ewma_step_s
                             + self.EWMA_ALPHA * step_s)
            s.chip_seconds += step_s * n_chips
            if metrics:
                s.last_metrics = dict(metrics)

    def heartbeat(self, block_id: str) -> None:
        # the store must happen under the same lock _get uses: the helper
        # releases it on return, and an unguarded store can race dead_blocks
        with self._lock:
            self._get(block_id).last_heartbeat = time.time()

    # ------------------------------------------------------ admission queue
    def record_enqueue(self, app_id: str) -> None:
        with self._lock:
            self.queue_depth += 1
            self.enqueued_total += 1

    def record_dequeue(self, app_id: str) -> None:
        """Left the queue without admission (denied / force-expired)."""
        with self._lock:
            self.queue_depth = max(0, self.queue_depth - 1)

    def record_admission(self, app_id: str, wait_s: float,
                         priority: int = 0,
                         slack_s: Optional[float] = None) -> None:
        """``slack_s`` is the entry's deadline slack at admission time
        (deadline - now); negative means the request was admitted already
        past its SLO — a deadline miss."""
        with self._lock:
            self.queue_depth = max(0, self.queue_depth - 1)
            self.admitted_total += 1
            self.queue_waits.append(wait_s)
            if len(self.queue_waits) > 2048:
                self.queue_waits = self.queue_waits[-1024:]
            waits = self.queue_waits_by_class.setdefault(int(priority), [])
            waits.append(wait_s)
            if len(waits) > 2048:
                self.queue_waits_by_class[int(priority)] = waits[-1024:]
            if slack_s is not None:
                self.record_deadline(slack_s)

    def record_deadline(self, slack_s: float) -> None:
        """SLO outcome at admission: non-negative slack is a hit.  Also fed
        directly for immediate admissions that never entered the queue —
        otherwise only queued requests would count and the miss rate would
        be overstated."""
        with self._lock:
            self.admission_slacks.append(float(slack_s))
            if len(self.admission_slacks) > 2048:
                self.admission_slacks = self.admission_slacks[-1024:]
            if slack_s >= 0.0:
                self.deadline_hits += 1
            else:
                self.deadline_misses += 1

    def deadline_report(self) -> Dict[str, float]:
        """SLO outcome: admissions that happened with non-negative deadline
        slack (hits) vs. past-deadline (misses), plus the slack spread."""
        with self._lock:
            total = self.deadline_hits + self.deadline_misses
            slacks = self.admission_slacks
            return {
                "deadline_hits": self.deadline_hits,
                "deadline_misses": self.deadline_misses,
                "deadline_miss_rate": (self.deadline_misses / total
                                       if total else 0.0),
                "mean_admission_slack_s": (statistics.mean(slacks)
                                           if slacks else 0.0),
                "min_admission_slack_s": min(slacks) if slacks else 0.0,
            }

    # ------------------------------------------------------------ preemption
    def record_preemption(self, block_id: str,
                          progress_lost_steps: int) -> None:
        with self._lock:
            self.preempted_total += 1
            self.progress_lost_steps.append(int(progress_lost_steps))
            if len(self.progress_lost_steps) > 2048:
                self.progress_lost_steps = self.progress_lost_steps[-1024:]

    def record_resume(self, app_id: str, wait_s: float) -> None:
        with self._lock:
            self.resumed_total += 1
            self.resume_waits.append(wait_s)
            if len(self.resume_waits) > 2048:
                self.resume_waits = self.resume_waits[-1024:]

    def preemption_report(self) -> Dict[str, float]:
        """Eviction counts, victim progress-lost bounds, and the
        high-priority admission-wait delta preemption buys."""
        with self._lock:
            lost = self.progress_lost_steps
            # aggregate the per-priority-value classes: "high" is any
            # positive priority, "normal" is <= 0
            hi = [w for p, ws in self.queue_waits_by_class.items()
                  if p > 0 for w in ws]
            lo = [w for p, ws in self.queue_waits_by_class.items()
                  if p <= 0 for w in ws]
            p50_hi = statistics.median(hi) if hi else 0.0
            p50_lo = statistics.median(lo) if lo else 0.0
            rep = {
                "preempted_total": self.preempted_total,
                "resumed_total": self.resumed_total,
                "mean_progress_lost_steps": (statistics.mean(lost)
                                             if lost else 0.0),
                "max_progress_lost_steps": max(lost) if lost else 0,
                "mean_resume_wait_s": (statistics.mean(self.resume_waits)
                                       if self.resume_waits else 0.0),
                "p50_wait_high_s": p50_hi,
                "p50_wait_normal_s": p50_lo,
                "wait_delta_s": p50_lo - p50_hi,
            }
            for p, ws in sorted(self.queue_waits_by_class.items()):
                rep[f"p50_wait_p{p}_s"] = statistics.median(ws) if ws else 0.0
            return rep

    # --------------------------------------------------------- compile cache
    def record_compile(self, action: str) -> None:
        """A step executable was requested from the compile cache: ``hit``
        reused a prior build (preemption resume / scheduler rebuild on an
        identical signature), ``miss`` paid for a fresh XLA compile."""
        with self._lock:
            if action == "hit":
                self.compile_hits_total += 1
            elif action == "miss":
                self.compile_misses_total += 1

    def compile_report(self) -> Dict[str, float]:
        with self._lock:
            total = self.compile_hits_total + self.compile_misses_total
            return {
                "compile_hits_total": self.compile_hits_total,
                "compile_misses_total": self.compile_misses_total,
                "compile_hit_rate": (self.compile_hits_total / total
                                     if total else 0.0),
            }

    # -------------------------------------------------------------- roofline
    def set_roofline(self, block_id: str, roofline: Dict) -> None:
        """Attach the block's roofline model (``launch.hlo_analysis.
        block_roofline``): useful FLOPs per step, chips, per-chip peak and
        the modeled step-time floor.  The step-time EWMA then yields
        achieved-vs-peak utilization without touching the hot path."""
        with self._lock:
            self._get(block_id).roofline = dict(roofline)

    def mfu(self, block_id: Optional[str]) -> Optional[float]:
        """Model FLOPs utilization: useful FLOPs per step / (EWMA step time
        x chips x per-chip peak).  None until the block has both a roofline
        model and at least one measured step."""
        with self._lock:
            s = self.stats.get(block_id) if block_id else None
            if s is None or s.roofline is None or not s.ewma_step_s:
                return None
            r = s.roofline
            denom = (s.ewma_step_s * max(1, r.get("n_chips", 1))
                     * r.get("peak_flops", 0.0))
            return r.get("model_flops", 0.0) / denom if denom else None

    def roofline_report(self) -> Dict[str, Dict]:
        """Per-block achieved-vs-modeled performance + the cluster mean.

        ``of_roofline`` compares the measured EWMA to the *modeled* step-
        time floor (1.0 = running at the roofline); ``mfu`` compares to the
        raw compute peak.  A block far under its roofline with a healthy
        queue is the migration/straggler signal with units attached."""
        with self._lock:
            blocks: Dict[str, Dict] = {}
            mfus = []
            for bid, s in self.stats.items():
                if s.roofline is None:
                    continue
                r = s.roofline
                ew = s.ewma_step_s
                peak = r.get("peak_flops", 0.0)
                chips = max(1, r.get("n_chips", 1))
                mfu = (r.get("model_flops", 0.0) / (ew * chips * peak)
                       if ew and peak else None)
                if mfu is not None:
                    mfus.append(mfu)
                blocks[bid] = {
                    "mfu": mfu,
                    "ewma_step_s": ew,
                    "modeled_step_s": r.get("step_time_s"),
                    "of_roofline": (r["step_time_s"] / ew
                                    if ew and r.get("step_time_s") else None),
                    "achieved_flops_s": (r.get("model_flops", 0.0) / ew
                                         if ew else None),
                    "bottleneck": r.get("bottleneck"),
                    "source": r.get("source"),
                }
            return {"blocks": blocks,
                    "mean_mfu": (statistics.mean(mfus) if mfus else 0.0),
                    "n_modeled": len(blocks)}

    # ------------------------------------------------------------ federation
    def record_pod_event(self, action: str) -> None:
        with self._lock:
            if action == "joined":
                self.pods_joined_total += 1
            elif action in ("left", "dead"):
                self.pods_lost_total += 1
            elif action == "degraded":
                self.pods_degraded_total += 1

    def record_migration(self, app_id: Optional[str], from_pod,
                         to_pod) -> None:
        with self._lock:
            self.migrated_total += 1
            self.migrations.append({"app_id": app_id, "from_pod": from_pod,
                                    "to_pod": to_pod})
            if len(self.migrations) > 2048:
                self.migrations = self.migrations[-1024:]

    def federation_report(self) -> Dict[str, float]:
        """Pod lifecycle + migration counters for the cluster report."""
        with self._lock:
            return {
                "pods_joined_total": self.pods_joined_total,
                "pods_lost_total": self.pods_lost_total,
                "pods_degraded_total": self.pods_degraded_total,
                "migrated_total": self.migrated_total,
            }

    def sample_utilization(self, used_chips: int, total_chips: int) -> None:
        with self._lock:
            self.util_samples.append(used_chips / max(1, total_chips))
            if len(self.util_samples) > 2048:
                self.util_samples = self.util_samples[-1024:]

    def queue_report(self) -> Dict[str, float]:
        """Queue depth / wait-time / utilization summary for operators."""
        with self._lock:
            waits = self.queue_waits
            return {
                "depth": self.queue_depth,
                "enqueued_total": self.enqueued_total,
                "admitted_total": self.admitted_total,
                "mean_wait_s": statistics.mean(waits) if waits else 0.0,
                "max_wait_s": max(waits) if waits else 0.0,
                "utilization": (statistics.mean(self.util_samples)
                                if self.util_samples else 0.0),
                "utilization_now": (self.util_samples[-1]
                                    if self.util_samples else 0.0),
            }

    # -------------------------------------------- completion estimation
    def step_time_estimate(self, block_id: Optional[str]) -> Optional[float]:
        """Best per-step service-time estimate for a block: its own EWMA
        when it has run (e.g. a preempted victim awaiting resume), else the
        cluster-wide mean EWMA as a prior, else None (nothing has run — the
        scheduler then falls back to deadline-only slack)."""
        with self._lock:
            s = self.stats.get(block_id) if block_id else None
            if s is not None and s.ewma_step_s:
                return s.ewma_step_s
            vals = [st.ewma_step_s for st in self.stats.values()
                    if st.ewma_step_s]
            return statistics.mean(vals) if vals else None

    def steps_done(self, block_id: Optional[str]) -> int:
        with self._lock:
            s = self.stats.get(block_id) if block_id else None
            return s.steps if s is not None else 0

    # ----------------------------------------------------------- stragglers
    def stragglers(self) -> List[str]:
        """Blocks whose EWMA step time exceeds STRAGGLER_FACTOR x their own
        median — the signal the controller uses to trigger migration."""
        out = []
        with self._lock:
            for s in self.stats.values():
                if s.ewma_step_s is None or len(s.step_times) < 8:
                    continue
                med = statistics.median(s.step_times)
                if med > 0 and s.ewma_step_s > self.STRAGGLER_FACTOR * med:
                    out.append(s.block_id)
        return out

    def dead_blocks(self, now: Optional[float] = None) -> List[str]:
        # `now or time.time()` would silently substitute wall clock for a
        # model-time 0.0 and corrupt heartbeat accounting under a
        # simulated clock
        now = now if now is not None else time.time()
        with self._lock:
            return [s.block_id for s in self.stats.values()
                    if s.steps > 0 and
                    now - s.last_heartbeat > self.HEARTBEAT_TIMEOUT_S]

    def report(self) -> Dict[str, Dict]:
        with self._lock:
            return {
                bid: {
                    "steps": s.steps,
                    "ewma_step_s": s.ewma_step_s,
                    "chip_seconds": round(s.chip_seconds, 3),
                    "last_metrics": s.last_metrics,
                    "mfu": (s.roofline.get("model_flops", 0.0)
                            / (s.ewma_step_s
                               * max(1, s.roofline.get("n_chips", 1))
                               * s.roofline["peak_flops"])
                            if s.roofline and s.ewma_step_s
                            and s.roofline.get("peak_flops") else None),
                }
                for bid, s in self.stats.items()
            }
