"""User/application registry — the paper's web-workflow state (Fig. 2),
persisted as JSON so an external UI/CLI can observe it.

Steps (paper §3): (1) register -> (2) admin review+assign -> (3) user
reconfirm -> (4) adjust program -> (5) upload+run -> (6) monitor ->
(7) download; auto-shutdown at period end.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
import threading
import time
from typing import Dict, List, Optional

from repro.core.block import Block, BlockGrant, BlockRequest, BlockState

# process umask, read once at import: os.umask(0)/os.umask(x) probing on
# every persist would leave a window where concurrent writers (e.g. the
# checkpoint manager's background thread) create world-writable files
_UMASK = os.umask(0)
os.umask(_UMASK)


class Registry:
    def __init__(self, state_path: Optional[str] = None, bus=None):
        self._lock = threading.RLock()
        self.apps: Dict[str, Block] = {}
        self._next_id = 1
        self._queue_seq = 0
        self._queue_order: Dict[str, int] = {}   # app_id -> enqueue sequence
        self.state_path = state_path
        self.bus = bus           # EventBus: every transition becomes a
                                 # kind="state" event for the live feed
        # gateway session state (profiles + event-feed cursors), persisted
        # inside the registry snapshot under the reserved "_sessions" key
        # so a restarted gateway rehydrates the paper's per-user
        # configuration instead of forgetting every session
        self._sessions: Dict = {}
        # federation pod directory, persisted under the reserved "_pods"
        # key so a restarted daemon re-attaches runtime pods (devices are
        # rebuilt on attach — only the directory state round-trips)
        self._pods: List = []
        if state_path and os.path.exists(state_path):
            try:
                with open(state_path) as f:
                    snap = json.load(f)
                self._sessions = snap.get("_sessions", {}) or {}
                self._pods = snap.get("_pods", []) or []
            except (OSError, ValueError):
                pass     # a corrupt snapshot must not block daemon boot

    # ------------------------------------------------------------- sessions
    def session_snapshot(self) -> Dict:
        """Deep copy of the stored gateway session state."""
        with self._lock:
            return json.loads(json.dumps(self._sessions, default=str))

    def store_sessions(self, sessions: Dict) -> None:
        """Replace the gateway session state and persist it with the next
        registry snapshot write."""
        with self._lock:
            self._sessions = dict(sessions)
            self._persist()

    # ----------------------------------------------------------------- pods
    def pods_snapshot(self) -> List:
        """Deep copy of the stored federation pod directory."""
        with self._lock:
            return json.loads(json.dumps(self._pods, default=str))

    def store_pods(self, pods: List) -> None:
        """Replace the federation pod directory and persist it with the
        next registry snapshot write."""
        with self._lock:
            self._pods = list(pods)
            self._persist()

    def _emit(self, app_id: str, note: str = "",
              now: Optional[float] = None) -> None:
        """Publish the block's (new) lifecycle state on the event bus —
        the per-block feed must show *every* transition, including ones no
        scheduling decision accompanies (confirm, run, done...)."""
        if self.bus is None:
            return
        blk = self.apps[app_id]
        self.bus.publish("state", app_id=app_id, block_id=blk.block_id,
                         user=blk.request.user, now=now,
                         state=blk.state.value, note=note)

    # ------------------------------------------------------------ workflow
    def register(self, request: BlockRequest) -> str:
        with self._lock:
            app_id = f"app_{self._next_id:04d}"
            self._next_id += 1
            self.apps[app_id] = Block(request=request)
            self.apps[app_id].history.append(
                (time.time(), f"registered by {request.user}"))
            self._persist()
            if self.bus is not None:
                self.bus.publish("registered", app_id=app_id,
                                 user=request.user,
                                 n_chips=request.n_chips,
                                 job=request.job_description)
            return app_id

    def approve(self, app_id: str, grant: BlockGrant) -> None:
        with self._lock:
            blk = self.apps[app_id]
            blk.grant = grant
            blk.transition(BlockState.APPROVED,
                           f"{grant.n_chips} chips assigned")
            self._persist()
            self._emit(app_id, f"{grant.n_chips} chips assigned")

    def enqueue(self, app_id: str, note: str = "pod full",
                now: Optional[float] = None) -> int:
        """Place an application on the admission waitlist (QUEUED state).
        Returns its FIFO sequence number (the base ordering the scheduler's
        fair-share policy refines).  ``now`` keeps queued_at on the model
        clock when the caller drives simulated time."""
        with self._lock:
            blk = self.apps[app_id]
            blk.transition(BlockState.QUEUED, note)
            blk.queued_at = now if now is not None else time.time()
            self._queue_seq += 1
            self._queue_order[app_id] = self._queue_seq
            self._persist()
            self._emit(app_id, note, now=now)
            return self._queue_order[app_id]

    def mark_preempted(self, app_id: str, note: str,
                       progress_lost_steps: int = 0,
                       checkpoint_step: Optional[int] = None,
                       from_state: Optional[str] = None,
                       now: Optional[float] = None) -> int:
        """Record an eviction: transition to PREEMPTED, append to the
        persisted preemption history, and re-enter the admission queue
        (preempted blocks keep their FIFO position machinery so the
        scheduler can order them for auto-resume).  Returns the new
        queue sequence number.  ``from_state`` overrides the recorded
        pre-eviction state (deferred chip-failure recovery passes the
        pre-*failure* state so auto-resume returns the block there)."""
        with self._lock:
            blk = self.apps[app_id]
            if from_state is None:
                from_state = blk.state.value
            blk.transition(BlockState.PREEMPTED, note)
            blk.record_preemption(note, progress_lost_steps, checkpoint_step,
                                  from_state)
            blk.queued_at = now if now is not None else time.time()
            self._queue_seq += 1
            self._queue_order[app_id] = self._queue_seq
            self._persist()
            self._emit(app_id, note, now=now)
            return self._queue_order[app_id]

    def queue_seq(self, app_id: str) -> int:
        with self._lock:
            return self._queue_order.get(app_id, 0)

    def queued(self) -> List[str]:
        """QUEUED applications in FIFO enqueue order."""
        with self._lock:
            ids = [a for a, b in self.apps.items()
                   if b.state == BlockState.QUEUED]
            return sorted(ids, key=lambda a: self._queue_order.get(a, 0))

    def deny(self, app_id: str, reason: str = "") -> None:
        with self._lock:
            self.apps[app_id].transition(BlockState.DENIED, reason)
            self._persist()
            self._emit(app_id, reason)

    def confirm(self, app_id: str, token: str) -> None:
        with self._lock:
            blk = self.apps[app_id]
            if blk.grant is None or token != blk.grant.token:
                raise PermissionError("bad block token")
            blk.transition(BlockState.CONFIRMED, "user reconfirmed")
            self._persist()
            self._emit(app_id, "user reconfirmed")

    def set_state(self, app_id: str, state: BlockState, note: str = "") -> None:
        with self._lock:
            self.apps[app_id].transition(state, note)
            self._persist()
            self._emit(app_id, note)

    # -------------------------------------------------------------- queries
    def get(self, app_id: str) -> Block:
        return self.apps[app_id]

    def by_state(self, *states: BlockState) -> List[str]:
        with self._lock:
            return [a for a, b in self.apps.items() if b.state in states]

    def by_block_id(self, block_id: str) -> Optional[str]:
        with self._lock:
            for a, b in self.apps.items():
                if b.grant and b.grant.block_id == block_id:
                    return a
            return None

    def expired(self, now: Optional[float] = None) -> List[str]:
        now = now if now is not None else time.time()   # 0.0 is model time
        with self._lock:
            return [a for a, b in self.apps.items()
                    if b.grant and now > b.grant.expires_at
                    and b.state in (BlockState.APPROVED, BlockState.CONFIRMED,
                                    BlockState.ACTIVE, BlockState.RUNNING,
                                    BlockState.DONE, BlockState.PREEMPTED)]

    # -------------------------------------------------------------- persist
    def persist(self) -> None:
        """Snapshot state out-of-band (e.g. after a grant re-carve that
        changes no lifecycle state)."""
        with self._lock:
            self._persist()

    def _persist(self) -> None:
        if not self.state_path:
            return
        # "_sessions"/"_pods" cannot collide with app ids (always "app_NNNN")
        snap: Dict = {"_sessions": self._sessions} if self._sessions else {}
        if self._pods:
            snap["_pods"] = self._pods
        for app_id, blk in self.apps.items():
            snap[app_id] = {
                "user": blk.request.user,
                "job": blk.request.job_description,
                "arch": blk.request.arch,
                "shape": blk.request.shape,
                "n_chips": blk.request.n_chips,
                # tenancy-policy metadata: a restarted scheduler (or the
                # external UI) must see the same priority/deadline/gang
                # facts admission ordering uses for QUEUED entries
                "priority": blk.request.priority,
                "deadline_s": blk.request.deadline_s,
                "deadline_at": blk.deadline_at,
                "gang_id": blk.request.gang_id,
                "state": blk.state.value,
                "block_id": blk.block_id,
                "coords": blk.grant.coords if blk.grant else None,
                "expires_at": blk.grant.expires_at if blk.grant else None,
                "history": blk.history[-20:],
                "failure": blk.failure_reason,
                "queued_at": blk.queued_at,
                "preempt_count": blk.preempt_count,
                "preemptions": blk.preemptions[-20:],
            }
        target_dir = os.path.dirname(self.state_path) or "."
        os.makedirs(target_dir, exist_ok=True)
        # Crash-safe write: unique temp file in the *same directory* (so the
        # rename cannot cross filesystems), fsync before the atomic
        # os.replace — a crash at any point leaves either the old state file
        # or the new one, never a truncated mix.  A fixed ".tmp" name would
        # also let two writers clobber each other's half-written file.
        fd, tmp = tempfile.mkstemp(prefix=".registry_", suffix=".tmp",
                                   dir=target_dir)
        try:
            # mkstemp creates 0600; restore umask-default permissions so
            # the external UI/CLI this file exists for can still read it
            os.fchmod(fd, 0o666 & ~_UMASK)
            with os.fdopen(fd, "w") as f:
                json.dump(snap, f, indent=1, default=str)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, self.state_path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
