"""Serving step factories: batched prefill and single-token decode.

``decode_32k`` / ``long_500k`` assignment cells lower ``serve_step`` — one new
token against a KV/recurrent cache of ``shape.seq_len`` tokens.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import model as model_lib
from repro.models.config import ModelConfig, ShapeConfig


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch, cache):
        return model_lib.prefill(params, cfg, batch, cache)
    return prefill_step


def make_decode_step(cfg: ModelConfig, *, sample: bool = False,
                     temperature: float = 1.0):
    def decode_step(params, token, cache, cache_len, key=None):
        logits, new_cache = model_lib.decode_step(params, cfg, token, cache,
                                                  cache_len)
        if sample:
            nxt = jax.random.categorical(key, logits / temperature, axis=-1)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        return nxt.astype(jnp.int32)[:, None], new_cache
    return decode_step


def abstract_cache(cfg: ModelConfig, batch: int, smax: int):
    return jax.eval_shape(lambda: model_lib.init_cache(cfg, batch, smax))
