"""DecodeScheduler — continuous batching over a paged KV cache.

The serve block's data plane: many users' generate sessions multiplex one
fixed-shape decode batch (``max_slots`` slots) over one shared page pool.
Every ``step()``:

1. **admit** queued sessions into free slots while pages last: the prompt
   is prefilled (dense, padded to a page multiple so XLA retraces per
   *bucket*, not per prompt length), scattered into freshly allocated pool
   pages, and the first generated token is emitted immediately — TTFT is
   admission time, not queue-drain time;
2. **decode** one token for every running slot in a single fixed-shape
   batched ``decode_step_paged`` call — throughput scales with batch
   occupancy, not session count;
3. **retire** slots that hit EOS / their token budget / the sequence cap,
   releasing their pages to the pool (freed pages re-admit the queue on the
   very next step).

Pages are allocated lazily: a slot gains its next page only when the write
position crosses a page boundary, so concurrent sessions share the pool at
block granularity with no per-session ``smax`` over-allocation.  When the
pool runs dry mid-decode the scheduler *evicts* the least-progressed
running session (its context re-queues as a longer prompt — generation
resumes where it left off after re-admission).

Page 0 is reserved as the trash page: idle slots' table rows point at it,
so their scatter writes and gathered garbage never touch live pages.

The scheduler is host-side bookkeeping plus three jitted device functions
(prefill, page-scatter, batched paged decode); it owns no thread — the
``BlockRuntime`` drives it synchronously from its step surface, and
``state_tree()``/``load_state()`` round-trip the whole thing (pool, page
table, per-slot lengths *and* host session metadata) through the
``CheckpointManager`` so in-flight sessions survive preemption.
"""
from __future__ import annotations

import collections
import dataclasses
import json
import time
from typing import Any, Deque, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as model_lib
from repro.models.config import ModelConfig
from repro.obs.trace import TRACER

#: fixed checkpoint budget for the JSON-encoded host session metadata (the
#: CheckpointManager requires static leaf shapes across save/restore)
META_CAP = 1 << 20


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


class PagePool:
    """Host-side free list over the device page pool.  Page 0 is reserved
    (the trash page idle slots write into) and never handed out."""

    def __init__(self, n_pages: int):
        assert n_pages >= 2, "pool needs at least one real page + the trash page"
        self.n_pages = n_pages
        self.free: List[int] = list(range(n_pages - 1, 0, -1))

    @property
    def available(self) -> int:
        return len(self.free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """All-or-nothing allocation of ``n`` pages (None = pool exhausted)."""
        if n > len(self.free):
            return None
        out = [self.free.pop() for _ in range(n)]
        return out

    def release(self, pages: Sequence[int]) -> None:
        for p in pages:
            assert 0 < p < self.n_pages, p
            self.free.append(p)


@dataclasses.dataclass
class GenSession:
    """One generate session.  ``prompt + generated`` is the full context;
    eviction re-queues the session with everything generated so far folded
    into the context, so re-admission resumes mid-generation."""
    sid: str
    prompt: List[int]
    max_new_tokens: int
    eos_id: Optional[int] = None
    state: str = "queued"            # queued | running | done
    generated: List[int] = dataclasses.field(default_factory=list)
    slot: Optional[int] = None
    pages: List[int] = dataclasses.field(default_factory=list)
    submitted_t: Optional[float] = None
    first_token_t: Optional[float] = None
    done_t: Optional[float] = None
    evictions: int = 0
    finish_reason: Optional[str] = None

    @property
    def context(self) -> List[int]:
        return self.prompt + self.generated

    def to_dict(self) -> Dict[str, Any]:
        return {"sid": self.sid, "prompt": self.prompt,
                "max_new_tokens": self.max_new_tokens, "eos_id": self.eos_id,
                "state": self.state, "generated": self.generated,
                "slot": self.slot, "pages": self.pages,
                "submitted_t": self.submitted_t,
                "first_token_t": self.first_token_t,
                "evictions": self.evictions}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "GenSession":
        return cls(sid=d["sid"], prompt=list(d["prompt"]),
                   max_new_tokens=int(d["max_new_tokens"]),
                   eos_id=d["eos_id"], state=d["state"],
                   generated=list(d["generated"]), slot=d["slot"],
                   pages=list(d["pages"]), submitted_t=d["submitted_t"],
                   first_token_t=d["first_token_t"],
                   evictions=int(d["evictions"]))


def paged_geometry(cfg: ModelConfig, *, page_size: int, n_pages: int,
                   max_slots: int, max_seq_len: int) -> Dict[str, int]:
    """Normalize a job's paged-cache geometry.  ``n_pages=0`` derives a
    full-residency pool (every slot can grow to ``max_seq_len`` without an
    eviction) plus the reserved trash page."""
    assert page_size >= 1 and max_slots >= 1 and max_seq_len >= 2
    pages_per_seq = _ceil_div(max_seq_len, page_size)
    if n_pages <= 0:
        n_pages = max_slots * pages_per_seq + 1
    return {"page_size": page_size, "n_pages": n_pages,
            "max_slots": max_slots, "max_seq_len": max_seq_len,
            "pages_per_seq": pages_per_seq}


class DecodeScheduler:
    def __init__(self, cfg: ModelConfig, params, *, page_size: int = 16,
                 n_pages: int = 0, max_slots: int = 8, max_seq_len: int = 128,
                 sample: bool = False, seed: int = 0, time_fn=time.monotonic,
                 init_pool: bool = True):
        model_lib.check_paged_support(cfg)
        self.cfg = cfg
        self.params = params
        geo = paged_geometry(cfg, page_size=page_size, n_pages=n_pages,
                             max_slots=max_slots, max_seq_len=max_seq_len)
        self.page_size = geo["page_size"]
        self.n_pages = geo["n_pages"]
        self.max_slots = geo["max_slots"]
        self.max_seq_len = geo["max_seq_len"]
        self.pages_per_seq = geo["pages_per_seq"]
        self.sample = sample
        self._time_fn = time_fn
        self._key = jax.random.PRNGKey(seed + 17)

        # device state
        self.pool = (model_lib.init_paged_cache(cfg, self.n_pages,
                                                self.page_size)
                     if init_pool else None)
        self.last_tokens_dev = jnp.zeros((self.max_slots, 1), jnp.int32)
        # host mirrors pushed to device each decode round
        self.page_table = np.zeros((self.max_slots, self.pages_per_seq),
                                   np.int32)
        self.seq_lens = np.zeros((self.max_slots,), np.int32)
        self.tokens = np.zeros((self.max_slots, 1), np.int32)

        # host bookkeeping
        self.pages = PagePool(self.n_pages)
        self.slots: List[Optional[GenSession]] = [None] * self.max_slots
        self.queued: Deque[GenSession] = collections.deque()
        self.sessions: Dict[str, GenSession] = {}
        self._next_id = 0
        self.tokens_generated = 0
        self.admissions = 0
        self.evictions = 0
        self.finished = 0
        self.ttft_s: List[float] = []

        # built through the process-wide compile cache: a scheduler rebuilt
        # after preemption/re-admission with the same (cfg, sample, paging)
        # signature adopts the previous wrapper and its compiled buckets
        # instead of re-tracing every (prompt-bucket, pages) pair from cold
        from repro.train import compile_cache
        self._decode = compile_cache.GLOBAL.get(
            ("paged_decode", compile_cache.freeze(cfg), sample),
            lambda: jax.jit(self._make_decode(), donate_argnums=(2,)),
            label="paged_decode")
        self._admit_fn = compile_cache.GLOBAL.get(
            ("paged_admit", compile_cache.freeze(cfg), self.page_size,
             sample),
            lambda: jax.jit(self._make_admit(), donate_argnums=(2,)),
            label="paged_admit")

    # ------------------------------------------------------------- compiled
    def _make_decode(self):
        cfg, sample = self.cfg, self.sample

        def fn(params, tokens, pool, page_table, seq_lens, key=None):
            logits, new_pool = model_lib.decode_step_paged(
                params, cfg, tokens, pool, page_table, seq_lens)
            if sample:
                nxt = jax.random.categorical(key, logits, axis=-1)
            else:
                nxt = jnp.argmax(logits, axis=-1)
            return nxt.astype(jnp.int32)[:, None], new_pool

        return fn

    def _make_admit(self):
        """One fused admission executable: zero temp cache + dense prefill
        + page scatter + first-token pick in a single dispatch (admission
        cost is on the continuous-batching hot path — one device call, one
        scalar sync).  Retraces per (bucket, n_pages-allocated) pair, both
        bounded by ``pages_per_seq``."""
        cfg, page_size, sample = self.cfg, self.page_size, self.sample

        def fn(params, tokens, pool, ids, last_idx, key=None):
            # prompt padded to a page multiple: causal masking keeps logits
            # at ``last_idx`` and cache rows [0, last_idx] identical to the
            # unpadded run; pad-token rows land past the live length and
            # are overwritten before the length mask ever exposes them
            cache = model_lib.init_cache(cfg, 1, tokens.shape[1])
            x = model_lib.embed_inputs(params, cfg, {"tokens": tokens})
            S = x.shape[1]
            logits, _, new_cache = model_lib.forward(
                params, cfg, x, positions=jnp.arange(S), cache=cache,
                cache_len=jnp.int32(0))
            pool = model_lib.write_prefill_to_pages(pool, new_cache, ids,
                                                    page_size)
            last = logits[0, last_idx]
            if sample:
                tok = jax.random.categorical(key, last, axis=-1)
            else:
                tok = jnp.argmax(last, axis=-1)
            return tok.astype(jnp.int32), pool

        return fn

    # --------------------------------------------------------------- submit
    def submit(self, prompt: Sequence[int], max_new_tokens: int = 16,
               eos_id: Optional[int] = None,
               sid: Optional[str] = None) -> str:
        prompt = [int(t) for t in prompt]
        if not prompt:
            raise ValueError("prompt must be non-empty")
        if len(prompt) >= self.max_seq_len:
            raise ValueError(
                f"prompt length {len(prompt)} >= max_seq_len "
                f"{self.max_seq_len}")
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if sid is None:
            sid = f"g{self._next_id:06d}"
            self._next_id += 1
        if sid in self.sessions:
            raise ValueError(f"duplicate session id {sid!r}")
        # serve.admit is the DecodeScheduler's admission decision; on the
        # generate path it nests under the daemon's serve.submit span
        with TRACER.span("serve.admit", cat="serve", session=sid,
                         prompt_tokens=len(prompt)):
            sess = GenSession(sid=sid, prompt=prompt,
                              max_new_tokens=int(max_new_tokens),
                              eos_id=(None if eos_id is None
                                      else int(eos_id)),
                              submitted_t=self._time_fn())
            self.sessions[sid] = sess
            self.queued.append(sess)
        return sess.sid

    @property
    def active_count(self) -> int:
        return sum(1 for s in self.slots if s is not None)

    @property
    def has_work(self) -> bool:
        return self.active_count > 0 or bool(self.queued)

    def stats(self) -> Dict[str, Any]:
        return {"tokens_generated": self.tokens_generated,
                "admissions": self.admissions, "evictions": self.evictions,
                "finished": self.finished, "active": self.active_count,
                "queued": len(self.queued),
                "free_pages": self.pages.available}

    # ----------------------------------------------------------------- step
    def step(self, now: Optional[float] = None) -> List[Dict[str, Any]]:
        """One continuous-batching round: admit, batch-decode, retire.
        Returns the round's emissions — ``{"event": "token", ...}`` per
        generated token plus ``admitted``/``evicted``/``finished``
        lifecycle markers (the engine maps these onto bus events)."""
        t = now if now is not None else self._time_fn()
        emissions: List[Dict[str, Any]] = []
        # on the engine path this nests under engine.dispatch (decode
        # rounds run synchronously inside the runtime's dispatch())
        with TRACER.span("serve.decode_round", cat="serve") as sp:
            self._admit(emissions, t)
            self._decode_round(emissions, t)
            sp.set(emissions=len(emissions))
        return emissions

    # ------------------------------------------------------------ admission
    def _admit(self, emissions: List[Dict[str, Any]], now: float) -> None:
        while self.queued:
            free_slots = [i for i, s in enumerate(self.slots) if s is None]
            if not free_slots:
                return
            sess = self.queued[0]
            plen = len(sess.context)
            # pages for the prompt *and* the first decode write position
            need = plen // self.page_size + 1
            pages = self.pages.alloc(need)
            if pages is None:
                return                      # admission refusal: pool full
            self.queued.popleft()
            slot = free_slots[0]

            bucket = need * self.page_size
            toks = np.zeros((1, bucket), np.int32)
            toks[0, :plen] = sess.context
            args = (self.params, jnp.asarray(toks), self.pool,
                    jnp.asarray(pages, jnp.int32), jnp.int32(plen - 1))
            if self.sample:
                self._key, key = jax.random.split(self._key)
                tok, self.pool = self._admit_fn(*args, key)
            else:
                tok, self.pool = self._admit_fn(*args)
            first = int(tok)

            sess.state = "running"
            sess.slot = slot
            sess.pages = pages
            self.slots[slot] = sess
            self.page_table[slot, :] = 0
            self.page_table[slot, :need] = pages
            self.seq_lens[slot] = plen
            self.tokens[slot, 0] = first
            sess.generated.append(first)
            if sess.first_token_t is None:
                sess.first_token_t = now
                self.ttft_s.append(now - sess.submitted_t)
            self.admissions += 1
            self.tokens_generated += 1
            emissions.append({"event": "admitted", "session": sess.sid,
                              "slot": slot, "prompt_tokens": plen,
                              "pages": len(pages)})
            done = self._is_done(sess, first)
            emissions.append(self._token_emission(sess, first, done))
            if done:
                self._finish(sess, emissions, now)

    def _is_done(self, sess: GenSession, token: int) -> bool:
        if sess.eos_id is not None and token == sess.eos_id:
            sess.finish_reason = "eos"
            return True
        if len(sess.generated) >= sess.max_new_tokens:
            sess.finish_reason = "length"
            return True
        return False

    def _token_emission(self, sess: GenSession, token: int,
                        done: bool) -> Dict[str, Any]:
        return {"event": "token", "session": sess.sid, "token": int(token),
                "index": len(sess.generated) - 1, "done": done}

    # --------------------------------------------------------------- decode
    def _ensure_pages(self, emissions: List[Dict[str, Any]],
                      now: float) -> None:
        """Grow each running slot's page table to cover this round's write
        position, evicting the least-progressed *other* session when the
        pool is dry (the requester itself only as a last resort)."""
        for i in range(self.max_slots):
            sess = self.slots[i]
            if sess is None:
                continue
            pos = int(self.seq_lens[i])
            if pos + 1 > self.max_seq_len:
                sess.finish_reason = "cap"
                self._finish(sess, emissions, now)
                continue
            idx = pos // self.page_size
            while idx >= len(sess.pages):
                got = self.pages.alloc(1)
                if got is not None:
                    self.page_table[i, len(sess.pages)] = got[0]
                    sess.pages.extend(got)
                    continue
                victims = [s for s in self.slots
                           if s is not None and s is not sess]
                victim = (min(victims, key=lambda s: len(s.generated))
                          if victims else sess)
                self._evict(victim, emissions, now)
                if victim is sess:
                    break

    def _decode_round(self, emissions: List[Dict[str, Any]],
                      now: float) -> None:
        self._ensure_pages(emissions, now)
        active = [i for i in range(self.max_slots)
                  if self.slots[i] is not None]
        if not active:
            return
        args = (self.params, jnp.asarray(self.tokens), self.pool,
                jnp.asarray(self.page_table), jnp.asarray(self.seq_lens))
        if self.sample:
            self._key, key = jax.random.split(self._key)
            nxt, self.pool = self._decode(*args, key)
        else:
            nxt, self.pool = self._decode(*args)
        self.last_tokens_dev = nxt
        nxt_host = np.asarray(nxt)          # host sync: EOS/feedback point
        for i in active:
            sess = self.slots[i]
            self.seq_lens[i] += 1
            token = int(nxt_host[i, 0])
            sess.generated.append(token)
            self.tokens[i, 0] = token
            self.tokens_generated += 1
            done = self._is_done(sess, token)
            emissions.append(self._token_emission(sess, token, done))
            if done:
                self._finish(sess, emissions, now)

    # ----------------------------------------------------------- retirement
    def _clear_slot(self, sess: GenSession) -> None:
        slot = sess.slot
        self.pages.release(sess.pages)
        sess.pages = []
        sess.slot = None
        self.slots[slot] = None
        self.page_table[slot, :] = 0
        self.seq_lens[slot] = 0
        self.tokens[slot, 0] = 0

    def _finish(self, sess: GenSession, emissions: List[Dict[str, Any]],
                now: float) -> None:
        self._clear_slot(sess)
        sess.state = "done"
        sess.done_t = now
        self.finished += 1
        emissions.append({"event": "finished", "session": sess.sid,
                          "n_tokens": len(sess.generated),
                          "reason": sess.finish_reason or "length"})

    def _evict(self, sess: GenSession, emissions: List[Dict[str, Any]],
               now: float) -> None:
        """Pool-pressure eviction: fold progress into the context and
        re-queue at the front — tokens already emitted stay emitted;
        re-admission prefills the longer context and generation continues
        from the next token."""
        freed = len(sess.pages)
        self._clear_slot(sess)
        sess.state = "queued"
        sess.evictions += 1
        self.evictions += 1
        self.queued.appendleft(sess)
        emissions.append({"event": "evicted", "session": sess.sid,
                          "pages_freed": freed,
                          "generated": len(sess.generated)})

    # ----------------------------------------------------------- checkpoint
    def state_tree(self) -> Dict[str, Any]:
        """The scheduler's full state as fixed-shape array leaves (the
        CheckpointManager contract).  Host session metadata rides as a
        length-prefixed JSON blob in a fixed ``META_CAP`` byte buffer."""
        live = [s.to_dict() for s in self.sessions.values()
                if s.state != "done"]
        meta = json.dumps({
            "next_id": self._next_id,
            "sessions": live,
            "queued": [s.sid for s in self.queued],
            "counters": [self.tokens_generated, self.admissions,
                         self.evictions, self.finished],
        }).encode()
        if len(meta) + 8 > META_CAP:
            raise ValueError(
                f"session metadata ({len(meta)}B) exceeds the checkpoint "
                f"budget ({META_CAP}B)")
        buf = np.zeros((META_CAP,), np.uint8)
        buf[:8] = np.frombuffer(np.uint64(len(meta)).tobytes(), np.uint8)
        buf[8:8 + len(meta)] = np.frombuffer(meta, np.uint8)
        return {"pool": self.pool,
                "page_table": self.page_table.copy(),
                "seq_lens": self.seq_lens.copy(),
                "tokens": self.tokens.copy(),
                "meta": buf}

    @classmethod
    def abstract_state(cls, cfg: ModelConfig, *, page_size: int,
                       n_pages: int, max_slots: int,
                       max_seq_len: int) -> Dict[str, Any]:
        """Shape/dtype targets for ``CheckpointManager.restore`` without
        materializing a pool (preemption-resume critical path)."""
        geo = paged_geometry(cfg, page_size=page_size, n_pages=n_pages,
                             max_slots=max_slots, max_seq_len=max_seq_len)
        pool = jax.eval_shape(lambda: model_lib.init_paged_cache(
            cfg, geo["n_pages"], geo["page_size"]))
        return {"pool": pool,
                "page_table": jax.ShapeDtypeStruct(
                    (geo["max_slots"], geo["pages_per_seq"]), jnp.int32),
                "seq_lens": jax.ShapeDtypeStruct((geo["max_slots"],),
                                                 jnp.int32),
                "tokens": jax.ShapeDtypeStruct((geo["max_slots"], 1),
                                               jnp.int32),
                "meta": jax.ShapeDtypeStruct((META_CAP,), jnp.uint8)}

    def load_state(self, tree: Dict[str, Any]) -> None:
        """Adopt a checkpointed state (cross-geometry resume: leaves arrive
        host-side or default-placed; the pool re-lands wherever the new
        runtime put it)."""
        self.pool = jax.tree.map(jnp.asarray, tree["pool"])
        self.page_table = np.asarray(tree["page_table"], np.int32).copy()
        self.seq_lens = np.asarray(tree["seq_lens"], np.int32).copy()
        self.tokens = np.asarray(tree["tokens"], np.int32).copy()
        self.last_tokens_dev = jnp.asarray(self.tokens)
        buf = np.asarray(tree["meta"], np.uint8)
        n = int(np.frombuffer(buf[:8].tobytes(), np.uint64)[0])
        meta = json.loads(buf[8:8 + n].tobytes().decode())
        self._next_id = int(meta["next_id"])
        (self.tokens_generated, self.admissions,
         self.evictions, self.finished) = meta["counters"]
        self.sessions = {d["sid"]: GenSession.from_dict(d)
                         for d in meta["sessions"]}
        self.slots = [None] * self.max_slots
        used = []
        for sess in self.sessions.values():
            if sess.state == "running":
                self.slots[sess.slot] = sess
                used.extend(sess.pages)
        self.queued = collections.deque(self.sessions[sid]
                                        for sid in meta["queued"])
        self.pages = PagePool(self.n_pages)
        taken = set(used)
        assert len(taken) == len(used), "page double-booked in checkpoint"
        self.pages.free = [p for p in range(self.n_pages - 1, 0, -1)
                           if p not in taken]
