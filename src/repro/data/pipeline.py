"""Deterministic synthetic data pipeline.

Generates reproducible token/frame batches keyed on (seed, step) with no
host-side state, builds globally-sharded jax Arrays for a mesh, and exposes
``input_specs`` — the ShapeDtypeStruct stand-ins for every model input used
by the multi-pod dry-run (no allocation).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.models.config import ModelConfig, ShapeConfig

# Pixtral stub geometry (see configs/pixtral_12b.py)
N_PATCHES = 256


def batch_shapes(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """(shape, dtype) of every input for a train-kind cell."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.frontend == "frame":
        return {"frames": ((B, S, cfg.frontend_dim), jnp.bfloat16),
                "labels": ((B, S), jnp.int32),
                "mask": ((B, S), jnp.bool_)}
    if cfg.frontend == "patch":
        return {"tokens": ((B, S - N_PATCHES), jnp.int32),
                "patches": ((B, N_PATCHES, cfg.frontend_dim), jnp.bfloat16),
                "labels": ((B, S - N_PATCHES), jnp.int32)}
    return {"tokens": ((B, S), jnp.int32),
            "labels": ((B, S), jnp.int32)}


def prefill_shapes(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    if cfg.frontend == "frame":
        return {"frames": ((B, S, cfg.frontend_dim), jnp.bfloat16)}
    if cfg.frontend == "patch":
        return {"tokens": ((B, S - N_PATCHES), jnp.int32),
                "patches": ((B, N_PATCHES, cfg.frontend_dim), jnp.bfloat16)}
    return {"tokens": ((B, S), jnp.int32)}


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins (dry-run; no device allocation)."""
    shapes = (batch_shapes(cfg, shape) if shape.kind == "train"
              else prefill_shapes(cfg, shape))
    return {k: jax.ShapeDtypeStruct(s, d) for k, (s, d) in shapes.items()}


def _lcg_sequences(rng, B: int, S: int, V: int) -> np.ndarray:
    """Learnable token streams: x_{t+1} = (x_t + b) mod V with the stride b
    drawn per sequence from a small set — a deterministic next-token function
    inferable from any adjacent pair, so LM loss drops well below ln V."""
    strides = np.asarray([1, 2, 3, 5, 7, 11])
    b = strides[rng.integers(0, len(strides), (B,))]
    x0 = rng.integers(0, V, (B,))
    t = np.arange(S + 1)[None, :]
    x = (x0[:, None] + b[:, None] * t) % V
    return x.astype(np.int32)


def synthetic_batch(cfg: ModelConfig, shape: ShapeConfig, *, step: int,
                    seed: int = 0, batch_override: Optional[int] = None,
                    seq_override: Optional[int] = None) -> Dict[str, np.ndarray]:
    """Reproducible numpy batch (host-side, no jax)."""
    B = batch_override or shape.global_batch
    S = seq_override or shape.seq_len
    rng = np.random.default_rng(np.uint64(seed * 1_000_003 + step))
    out: Dict[str, np.ndarray] = {}
    if cfg.frontend == "frame":
        # frames carry the (scaled) label signal in the first channels plus
        # noise: the masked-prediction task is learnable from context
        labels = _lcg_sequences(rng, B, S - 1, cfg.vocab_size)[:, :S]
        frames = rng.standard_normal((B, S, cfg.frontend_dim),
                                     dtype=np.float32) * 0.1
        frames[:, :, 0] = labels / cfg.vocab_size
        out["frames"] = frames
        out["labels"] = labels
        out["mask"] = rng.random((B, S)) < 0.3
    elif cfg.frontend == "patch":
        n_p = min(N_PATCHES, max(1, S // 8))
        toks = _lcg_sequences(rng, B, S - n_p, cfg.vocab_size)
        out["tokens"] = toks[:, :-1]
        out["patches"] = rng.standard_normal((B, n_p, cfg.frontend_dim),
                                             dtype=np.float32)
        out["labels"] = toks[:, 1:]
    else:
        toks = _lcg_sequences(rng, B, S, cfg.vocab_size)
        out["tokens"] = toks[:, :-1]
        out["labels"] = toks[:, 1:]
    return out


def make_global_batch(np_batch: Dict[str, np.ndarray], shardings) -> Dict[str, jax.Array]:
    """Place a host batch onto the mesh with the plan's shardings."""
    return {k: jax.device_put(v, shardings[k]) for k, v in np_batch.items()}


class DataIterator:
    """Stateless-by-construction iterator: batch(step) is a pure function."""

    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, *, seed: int = 0,
                 shardings=None, batch_override: Optional[int] = None,
                 seq_override: Optional[int] = None):
        self.cfg, self.shape, self.seed = cfg, shape, seed
        self.shardings = shardings
        self.batch_override = batch_override
        self.seq_override = seq_override

    def batch(self, step: int) -> Dict[str, Any]:
        np_batch = synthetic_batch(self.cfg, self.shape, step=step,
                                   seed=self.seed,
                                   batch_override=self.batch_override,
                                   seq_override=self.seq_override)
        np_batch = {k: (v.astype(np.float32) if v.dtype == np.float64 else v)
                    for k, v in np_batch.items()}
        if self.shardings is not None:
            return make_global_batch(np_batch, self.shardings)
        return {k: jnp.asarray(v) for k, v in np_batch.items()}
