"""Lifecycle-transition checker.

Resolves every ``Block.state`` assignment, ``mark_*``, ``transition`` and
``set_state`` call site against the ``TRANSITIONS`` table in
``core/block.py`` (imported — the table itself stays the single source of
truth) and flags:

* ``state-assign-bypass`` — a direct ``x.state = BlockState.X`` store
  anywhere but ``Block.transition`` (bypasses the runtime validator *and*
  the history log);
* ``illegal-transition-target`` — a literal target state that is not a
  target of *any* legal transition (e.g. ``REQUESTED``: nothing ever
  transitions back to it);
* ``illegal-transition-edge`` — a call site whose source state is pinned
  by a dominating membership guard (``assert x.state == S`` /
  ``if x.state not in (...): raise``) where some pinned source has no
  legal edge to the literal target.

The per-function fact tracking is linear and optimistic: facts survive
calls that are not themselves state changes (the codebase convention is
guard-then-transition inside one function), reset at loop entry (so the
``if blk.state is not RUNNING: continue`` pattern re-pins per iteration),
and merge by union across branches.  Unknown sources produce no finding —
this pass only flags what a guard *proves* wrong.
"""
from __future__ import annotations

import ast
from typing import Dict, FrozenSet, List, Optional, Set, Tuple

from repro.analysis._astutil import attr_chain, call_name
from repro.analysis.report import Report

Facts = Dict[str, FrozenSet[str]]       # owner chain ("blk") -> possible states


def _table():
    from repro.core.block import TRANSITIONS, BlockState
    legal = {(s.name, t.name) for s, ts in TRANSITIONS.items() for t in ts}
    states = {s.name for s in BlockState}
    targets = {t for _, t in legal}
    terminal = {s for s in states
                if not any(src == s for src, _ in legal)}
    return legal, states, targets, terminal


def _module_state_consts(tree: ast.Module) -> Dict[str, FrozenSet[str]]:
    """Module-level ``_TERMINAL = (BlockState.DONE, ...)`` style constants."""
    out: Dict[str, FrozenSet[str]] = {}
    for node in tree.body:
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            states = _state_ref(node.value, {})
            if states:
                out[node.targets[0].id] = states
    return out


def _state_ref(node: ast.AST,
               consts: Dict[str, FrozenSet[str]]) -> Optional[FrozenSet[str]]:
    """``BlockState.X`` / a module const / a literal tuple of either."""
    if isinstance(node, ast.Attribute):
        base = attr_chain(node)
        if base and len(base) >= 2 and base[-2] == "BlockState":
            return frozenset({node.attr})
        return None
    if isinstance(node, ast.Name):
        return consts.get(node.id)
    if isinstance(node, (ast.Tuple, ast.Set, ast.List)):
        acc: Set[str] = set()
        for elt in node.elts:
            got = _state_ref(elt, consts)
            if got is None:
                return None
            acc |= got
        return frozenset(acc)
    return None


def _state_owner(node: ast.AST) -> Optional[str]:
    """``blk.state`` -> "blk"; ``self.apps[x].state`` -> None (not a pure
    chain — facts only track pure chains)."""
    chain = attr_chain(node)
    if chain and len(chain) >= 2 and chain[-1] == "state":
        return ".".join(chain[:-1])
    return None


def _parse_guard(test: ast.AST, consts: Dict[str, FrozenSet[str]]
                 ) -> Optional[Tuple[str, bool, FrozenSet[str]]]:
    """(owner, positive?, states) for a state-membership test, else None.

    positive=True: the test holds when owner.state IS in states.
    """
    if isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not):
        got = _parse_guard(test.operand, consts)
        if got:
            return (got[0], not got[1], got[2])
        return None
    if not isinstance(test, ast.Compare) or len(test.ops) != 1:
        return None
    owner = _state_owner(test.left)
    if owner is None:
        return None
    states = _state_ref(test.comparators[0], consts)
    if states is None:
        return None
    op = test.ops[0]
    if isinstance(op, (ast.Eq, ast.Is, ast.In)):
        return (owner, True, states)
    if isinstance(op, (ast.NotEq, ast.IsNot, ast.NotIn)):
        return (owner, False, states)
    return None


def _terminates(stmts: List[ast.stmt]) -> bool:
    if not stmts:
        return False
    last = stmts[-1]
    if isinstance(last, (ast.Raise, ast.Return, ast.Continue, ast.Break)):
        return True
    if isinstance(last, ast.If):
        return (_terminates(last.body)
                and bool(last.orelse) and _terminates(last.orelse))
    return False


class _FunctionChecker:
    def __init__(self, path: str, qual: str, consts: Dict[str, FrozenSet[str]],
                 legal: Set[Tuple[str, str]], targets: Set[str],
                 report: Report, allow_state_assign: bool):
        self.path = path
        self.qual = qual
        self.consts = consts
        self.legal = legal
        self.targets = targets
        self.report = report
        self.allow_state_assign = allow_state_assign

    # ------------------------------------------------------------- statements
    def walk_body(self, stmts: List[ast.stmt], facts: Facts) -> Facts:
        for stmt in stmts:
            facts = self.walk_stmt(stmt, facts)
        return facts

    def walk_stmt(self, stmt: ast.stmt, facts: Facts) -> Facts:
        if isinstance(stmt, ast.Assert):
            guard = _parse_guard(stmt.test, self.consts)
            if guard and guard[1]:
                facts = dict(facts)
                facts[guard[0]] = guard[2]
            return facts
        if isinstance(stmt, ast.If):
            return self._walk_if(stmt, facts)
        if isinstance(stmt, (ast.For, ast.While, ast.AsyncFor)):
            # fresh fact scope per iteration: guards inside the loop re-pin
            # each pass; nothing survives the loop
            self.walk_body(stmt.body, {})
            self.walk_body(stmt.orelse, {})
            return {}
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            return self.walk_body(stmt.body, facts)
        if isinstance(stmt, ast.Try):
            self.walk_body(stmt.body, dict(facts))
            for h in stmt.handlers:
                self.walk_body(h.body, {})
            self.walk_body(stmt.orelse, {})
            self.walk_body(stmt.finalbody, {})
            return {}
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return facts            # nested defs are checked separately
        # leaf statement: process calls/stores in evaluation order
        for node in ast.walk(stmt):
            if isinstance(node, ast.Call):
                facts = self._handle_call(node, facts)
        if isinstance(stmt, ast.Assign):
            for target in stmt.targets:
                facts = self._handle_store(target, stmt.value, facts,
                                           stmt.lineno)
        return facts

    def _walk_if(self, stmt: ast.If, facts: Facts) -> Facts:
        guard = _parse_guard(stmt.test, self.consts)
        body_facts = dict(facts)
        else_facts = dict(facts)
        if guard:
            owner, positive, states = guard
            if positive:
                body_facts[owner] = states
            else:
                else_facts[owner] = states
        out_body = self.walk_body(stmt.body, body_facts)
        out_else = self.walk_body(stmt.orelse, else_facts) \
            if stmt.orelse else else_facts
        outs = []
        if not _terminates(stmt.body):
            outs.append(out_body)
        if not (stmt.orelse and _terminates(stmt.orelse)):
            outs.append(out_else)
        if not outs:
            return {}
        merged: Facts = {}
        for owner in outs[0]:
            if all(owner in o for o in outs):
                acc: Set[str] = set()
                for o in outs:
                    acc |= o[owner]
                merged[owner] = frozenset(acc)
        return merged

    # ------------------------------------------------------------------ sites
    def _handle_store(self, target: ast.AST, value: ast.AST, facts: Facts,
                      lineno: int) -> Facts:
        owner = _state_owner(target)
        if owner is None:
            return facts
        states = _state_ref(value, self.consts)
        if states is None and not isinstance(value, ast.Name):
            return facts            # not a state store we understand
        if not self.allow_state_assign:
            self.report.add(
                "state-assign-bypass", self.path, lineno,
                f"{self.qual}:{owner}.state",
                f"{self.qual} assigns {owner}.state directly — bypasses "
                f"Block.transition (no TRANSITIONS validation, no history "
                f"entry); call transition()/set_state() instead")
        facts = dict(facts)
        if states is not None:
            facts[owner] = states
        else:
            facts.pop(owner, None)
        return facts

    def _handle_call(self, call: ast.Call, facts: Facts) -> Facts:
        name = call_name(call)
        owner: Optional[str] = None
        target_node: Optional[ast.AST] = None
        if name == "transition":
            if isinstance(call.func, ast.Attribute):
                chain = attr_chain(call.func.value)
                owner = ".".join(chain) if chain else None
            target_node = call.args[0] if call.args else None
        elif name == "set_state":
            target_node = call.args[1] if len(call.args) > 1 else None
            if target_node is None:
                for kw in call.keywords:
                    if kw.arg == "state":
                        target_node = kw.value
        elif name == "mark_preempted":
            targets = frozenset({"PREEMPTED"})
            return self._check(call, owner, targets, facts)
        else:
            return facts
        if target_node is None:
            return facts
        targets = _state_ref(target_node, self.consts)
        if targets is None:
            # non-literal target (e.g. Registry.set_state forwarding its
            # parameter): state becomes unknown
            facts = dict(facts)
            if owner is not None:
                facts.pop(owner, None)
            else:
                facts = {}
            return facts
        return self._check(call, owner, targets, facts)

    def _check(self, call: ast.Call, owner: Optional[str],
               targets: FrozenSet[str], facts: Facts) -> Facts:
        for t in sorted(targets):
            if t not in self.targets:
                self.report.add(
                    "illegal-transition-target", self.path, call.lineno,
                    f"{self.qual}:{t}",
                    f"{self.qual} transitions to {t}, which is not a "
                    f"target of any legal transition in TRANSITIONS")
        src: Optional[FrozenSet[str]] = None
        src_owner = owner
        if owner is not None:
            src = facts.get(owner)
        elif len(facts) == 1:
            # set_state(app_id, ...) names the app, not the block object;
            # with exactly one pinned object in scope, attribute the call
            # to it (the repo's guard-then-transition convention)
            src_owner, src = next(iter(facts.items()))
        if src:
            for s in sorted(src):
                if not any((s, t) in self.legal for t in targets):
                    tnames = "/".join(sorted(targets))
                    self.report.add(
                        "illegal-transition-edge", self.path, call.lineno,
                        f"{self.qual}:{s}->{tnames}",
                        f"{self.qual}: a dominating guard pins the state "
                        f"to {s}, but {s} -> {tnames} is not in "
                        f"TRANSITIONS — this call can only raise")
        facts = dict(facts)
        if src_owner is not None:
            facts[src_owner] = targets
        else:
            facts = {}
        return facts


def run(modules: Dict[str, ast.Module], report: Report) -> Dict[str, object]:
    legal, states, targets, terminal = _table()
    for path, tree in modules.items():
        consts = _module_state_consts(tree)
        for cls, func in _iter_functions(tree):
            qual = f"{cls}.{func.name}" if cls else func.name
            allow = (cls == "Block" and func.name == "transition")
            checker = _FunctionChecker(path, qual, consts, legal, targets,
                                       report, allow)
            checker.walk_body(func.body, {})
    return {
        "states": sorted(states),
        "terminal": sorted(terminal),
        "transitions": sorted(f"{s} -> {t}" for s, t in legal),
    }


def _iter_functions(tree: ast.Module):
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield None, node
        elif isinstance(node, ast.ClassDef):
            for item in node.body:
                if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    yield node.name, item
