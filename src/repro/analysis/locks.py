"""Lock-discipline linter + cross-module lock-order graph.

Discipline (per class):
  * every attribute that is ever mutated inside ``with self._lock:`` is
    *learned* as guarded by that lock (``threading.Condition(self._lock)``
    aliases back to the lock it wraps);
  * any mutation of a learned attribute outside the lock — except in
    ``__init__``/``__new__``, and except in helpers annotated with a
    ``# lock: caller`` marker whose callers hold the lock — is a finding;
  * an attribute mutated under two *disjoint* lock sets is "inconsistently
    guarded" (no single lock protects it);
  * a store through a helper-call result (``self._get(x).attr = ...``) in a
    lock-owning class, outside any lock, is a finding: the helper's lock was
    already released when the store lands.

Order (global):
  * a lock is identified as ``Class.attr``; acquiring B (directly or via
    any resolvable call chain) while holding A adds edge A->B;
  * receiver types resolve through ``self.x = ClassName(...)`` assignments,
    local aliases, and a global attr-name fallback (an attr name constructed
    as exactly one class anywhere, e.g. ``bus`` -> EventBus, covers
    dependency-injected ``self.bus = bus``);
  * any cycle in the edge set is a deadlock-by-convention finding; nested
    acquisition of a *non-reentrant* ``threading.Lock`` with itself is a
    self-deadlock finding.
"""
from __future__ import annotations

import ast
from typing import Dict, FrozenSet, List, Optional, Set, Tuple

from repro.analysis._astutil import (attr_chain, call_name, ctor_class,
                                     has_caller_lock_marker, store_root)
from repro.analysis.report import Report

_LOCK_CTORS = {"Lock", "RLock"}
_EXEMPT_METHODS = {"__init__", "__new__"}
# method names that mutate their receiver (list/dict/set/deque mutators)
_MUTATORS = {"append", "appendleft", "extend", "insert", "remove", "pop",
             "popleft", "popitem", "clear", "update", "setdefault", "add",
             "discard", "sort", "reverse", "put", "put_nowait"}

LockId = Tuple[str, str]            # (ClassName, lock attr)


class MutationSite:
    __slots__ = ("attr", "held", "func", "lineno", "through_call")

    def __init__(self, attr: str, held: FrozenSet[str], func: str,
                 lineno: int, through_call: bool = False):
        self.attr = attr
        self.held = held
        self.func = func
        self.lineno = lineno
        self.through_call = through_call


class ClassModel:
    def __init__(self, name: str, path: str, node: ast.ClassDef):
        self.name = name
        self.path = path
        self.node = node
        self.methods: Dict[str, ast.FunctionDef] = {}
        self.lock_attrs: Dict[str, str] = {}      # attr -> "Lock"|"RLock"
        self.aliases: Dict[str, str] = {}         # Condition attr -> lock attr
        self.attr_types: Dict[str, str] = {}      # attr -> constructed class
        self.mutations: List[MutationSite] = []
        self.marked_caller_locked: Set[str] = set()

    def canon(self, attr: str) -> str:
        return self.aliases.get(attr, attr)


def _collect_methods(cls: ast.ClassDef) -> Dict[str, ast.FunctionDef]:
    out: Dict[str, ast.FunctionDef] = {}
    for item in cls.body:
        if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
            out[item.name] = item
    return out


def _scan_class_decls(model: ClassModel) -> None:
    """Find lock attributes, Condition aliases and constructed attr types."""
    for meth in model.methods.values():
        for node in ast.walk(meth):
            if not isinstance(node, ast.Assign) or len(node.targets) != 1:
                continue
            chain = attr_chain(node.targets[0])
            if not chain or len(chain) != 2 or chain[0] != "self":
                continue
            attr = chain[1]
            if isinstance(node.value, ast.Call):
                fname = call_name(node.value)
                if fname in _LOCK_CTORS:
                    model.lock_attrs[attr] = fname
                    continue
                if fname == "Condition":
                    if node.value.args:
                        inner = attr_chain(node.value.args[0])
                        if inner and len(inner) == 2 and inner[0] == "self":
                            model.aliases[attr] = inner[1]
                            continue
                    # a Condition owning its private lock is itself a lock
                    model.lock_attrs[attr] = "RLock"
                    continue
            ctor = ctor_class(node.value)
            if ctor:
                model.attr_types[attr] = ctor


class _MutationScanner(ast.NodeVisitor):
    """Walks one method body tracking the lexical ``with self.<lock>`` stack
    and recording every mutation rooted at a ``self`` attribute."""

    def __init__(self, model: ClassModel, func_name: str):
        self.model = model
        self.func = func_name
        self.held: List[str] = []

    # ---- held-lock tracking
    def visit_With(self, node: ast.With) -> None:
        acquired = []
        for item in node.items:
            chain = attr_chain(item.context_expr)
            if chain and len(chain) == 2 and chain[0] == "self":
                attr = self.model.canon(chain[1])
                if attr in self.model.lock_attrs:
                    acquired.append(attr)
        self.held.extend(acquired)
        for stmt in node.body:
            self.visit(stmt)
        if acquired:
            del self.held[len(self.held) - len(acquired):]

    # ---- mutation forms
    def _record_target(self, target: ast.AST, lineno: int) -> None:
        if isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                self._record_target(elt, lineno)
            return
        chain, through_call = store_root(target)
        if not chain or chain[0] != "self" or len(chain) < 2:
            return
        attr = chain[1]
        if not through_call and attr in self.model.lock_attrs:
            return                   # assigning the lock object itself
        if not through_call and attr in self.model.aliases:
            return
        self.model.mutations.append(MutationSite(
            attr, frozenset(self.held), self.func, lineno, through_call))

    def visit_Assign(self, node: ast.Assign) -> None:
        for t in node.targets:
            self._record_target(t, node.lineno)
        self.visit(node.value)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self._record_target(node.target, node.lineno)
        self.visit(node.value)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        if node.value is not None:
            self._record_target(node.target, node.lineno)
            self.visit(node.value)

    def visit_Delete(self, node: ast.Delete) -> None:
        for t in node.targets:
            self._record_target(t, node.lineno)

    def visit_Call(self, node: ast.Call) -> None:
        name = call_name(node)
        if name in _MUTATORS and isinstance(node.func, ast.Attribute):
            chain, through_call = store_root(node.func.value)
            if (chain and chain[0] == "self" and len(chain) >= 2
                    and not through_call):
                attr = chain[1]
                if (attr not in self.model.lock_attrs
                        and attr not in self.model.aliases):
                    self.model.mutations.append(MutationSite(
                        attr, frozenset(self.held), self.func, node.lineno))
        self.generic_visit(node)

    # nested defs inherit the lexical held stack (closures run where called,
    # but in this codebase nested defs are jit'd step fns, not lock users)
    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        for stmt in node.body:
            self.visit(stmt)

    visit_AsyncFunctionDef = visit_FunctionDef


def build_class_models(modules: Dict[str, ast.Module],
                       sources: Dict[str, List[str]]
                       ) -> Dict[str, ClassModel]:
    models: Dict[str, ClassModel] = {}
    for path, tree in modules.items():
        for node in ast.walk(tree):
            if not isinstance(node, ast.ClassDef):
                continue
            model = ClassModel(node.name, path, node)
            model.methods = _collect_methods(node)
            _scan_class_decls(model)
            lines = sources.get(path, [])
            for mname, meth in model.methods.items():
                if has_caller_lock_marker(lines, meth):
                    model.marked_caller_locked.add(mname)
                sc = _MutationScanner(model, mname)
                for stmt in meth.body:
                    sc.visit(stmt)
            models[node.name] = model
    return models


def check_discipline(models: Dict[str, ClassModel], report: Report) -> Dict:
    """Learn guarded attrs, flag unguarded mutations.  Returns the learned
    model (class -> attr -> guard set) for --describe / docs."""
    learned_all: Dict[str, Dict[str, List[str]]] = {}
    for model in models.values():
        if not model.lock_attrs:
            continue
        guards: Dict[str, Optional[FrozenSet[str]]] = {}
        for m in model.mutations:
            if m.through_call or not m.held:
                continue
            prev = guards.get(m.attr)
            guards[m.attr] = m.held if prev is None else (prev & m.held)
        learned_all[model.name] = {
            a: sorted(g) for a, g in sorted(guards.items()) if g}
        for attr, guard in sorted(guards.items()):
            if guard is not None and not guard:
                sites = sorted({(m.func, m.lineno) for m in model.mutations
                                if m.attr == attr and m.held})
                report.add(
                    "lock-inconsistent-guard", model.path, sites[0][1],
                    f"{model.name}.{attr}",
                    f"{model.name}.{attr} is mutated under disjoint lock "
                    f"sets ({', '.join(f'{f}:{l}' for f, l in sites)}) — "
                    f"no single lock protects it")
        for m in model.mutations:
            if m.func in _EXEMPT_METHODS:
                continue
            if m.func in model.marked_caller_locked:
                continue
            if m.through_call:
                if not m.held:
                    report.add(
                        "lock-discipline", model.path, m.lineno,
                        f"{model.name}.{m.func}:{m.attr}()",
                        f"{model.name}.{m.func} stores through "
                        f"self.{m.attr}(...) outside any lock — the "
                        f"helper's lock is already released when the "
                        f"store lands")
                continue
            guard = guards.get(m.attr)
            if not guard:
                continue
            if not (m.held & guard):
                locks = "/".join(sorted(f"self.{g}" for g in guard))
                report.add(
                    "lock-discipline", model.path, m.lineno,
                    f"{model.name}.{m.func}:{m.attr}",
                    f"{model.name}.{m.func} mutates self.{m.attr} without "
                    f"holding {locks} (guarded at every other mutation "
                    f"site)")
    return learned_all


# --------------------------------------------------------------- lock order
class _TypeEnv:
    """Best-effort receiver-type resolution for lock/call chains."""

    def __init__(self, models: Dict[str, ClassModel]):
        self.models = models
        # attr-name fallback: attr constructed as exactly one class anywhere
        counts: Dict[str, Set[str]] = {}
        for m in models.values():
            for attr, cls in m.attr_types.items():
                if cls in models:
                    counts.setdefault(attr, set()).add(cls)
        self.fallback = {a: next(iter(cs)) for a, cs in counts.items()
                         if len(cs) == 1}

    def resolve_chain(self, chain: Tuple[str, ...], cls: Optional[str],
                      local_types: Dict[str, str]) -> Optional[str]:
        """Type of the object the chain denotes, or None."""
        if not chain:
            return None
        head, rest = chain[0], chain[1:]
        if head == "self" and cls:
            cur: Optional[str] = cls
        else:
            cur = local_types.get(head) or self.fallback.get(head)
        for attr in rest:
            if cur is None:
                return None
            model = self.models.get(cur)
            nxt = model.attr_types.get(attr) if model else None
            cur = nxt or self.fallback.get(attr)
        return cur

    def lock_at(self, chain: Tuple[str, ...], cls: Optional[str],
                local_types: Dict[str, str]) -> Optional[LockId]:
        """If the chain denotes a lock attribute, its global id."""
        if len(chain) < 2:
            return None
        owner = self.resolve_chain(chain[:-1], cls, local_types)
        model = self.models.get(owner) if owner else None
        if model is None:
            return None
        attr = model.canon(chain[-1])
        if attr in model.lock_attrs:
            return (owner, attr)
        return None


def _local_types(func: ast.FunctionDef, cls: Optional[str],
                 env: _TypeEnv) -> Dict[str, str]:
    out: Dict[str, str] = {}
    for node in ast.walk(func):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            name = node.targets[0].id
            ctor = ctor_class(node.value)
            if ctor and ctor in env.models:
                out[name] = ctor
                continue
            chain = attr_chain(node.value)
            if chain:
                t = env.resolve_chain(chain, cls, out)
                if t:
                    out[name] = t
    # parameters fall back by name (e.g. ``ctl`` -> ClusterController)
    for arg in func.args.args + func.args.kwonlyargs:
        if arg.arg != "self" and arg.arg not in out:
            t = env.fallback.get(arg.arg)
            if t:
                out[arg.arg] = t
    return out


class _FuncInfo:
    __slots__ = ("node", "cls", "path", "local_types")

    def __init__(self, node, cls, path, local_types):
        self.node = node
        self.cls = cls
        self.path = path
        self.local_types = local_types


def _collect_functions(modules: Dict[str, ast.Module],
                       models: Dict[str, ClassModel], env: _TypeEnv
                       ) -> Dict[Tuple[Optional[str], str], _FuncInfo]:
    funcs: Dict[Tuple[Optional[str], str], _FuncInfo] = {}
    for path, tree in modules.items():
        for node in tree.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                funcs[(None, node.name)] = _FuncInfo(
                    node, None, path, _local_types(node, None, env))
    for model in models.values():
        for mname, meth in model.methods.items():
            funcs[(model.name, mname)] = _FuncInfo(
                meth, model.name, model.path,
                _local_types(meth, model.name, env))
    return funcs


def _callees(info: _FuncInfo, env: _TypeEnv,
             funcs: Dict[Tuple[Optional[str], str], _FuncInfo]
             ) -> List[Tuple[Optional[str], str]]:
    out = []
    for node in ast.walk(info.node):
        if not isinstance(node, ast.Call):
            continue
        if isinstance(node.func, ast.Attribute):
            recv = attr_chain(node.func.value)
            if recv is None:
                continue
            owner = env.resolve_chain(recv, info.cls, info.local_types)
            if owner and (owner, node.func.attr) in funcs:
                out.append((owner, node.func.attr))
        elif isinstance(node.func, ast.Name):
            if (None, node.func.id) in funcs:
                out.append((None, node.func.id))
    return out


def build_lock_order(modules: Dict[str, ast.Module],
                     models: Dict[str, ClassModel], report: Report
                     ) -> Dict[str, object]:
    env = _TypeEnv(models)
    funcs = _collect_functions(modules, models, env)
    call_graph = {k: _callees(info, env, funcs)
                  for k, info in funcs.items()}

    # fixpoint: locks each function may acquire (directly or transitively)
    summary: Dict[Tuple[Optional[str], str], Set[LockId]] = {
        k: set() for k in funcs}
    for k, info in funcs.items():
        for node in ast.walk(info.node):
            if isinstance(node, ast.With):
                for item in node.items:
                    chain = attr_chain(item.context_expr)
                    if chain:
                        lk = env.lock_at(chain, info.cls, info.local_types)
                        if lk:
                            summary[k].add(lk)
    changed = True
    while changed:
        changed = False
        for k in funcs:
            for callee in call_graph[k]:
                before = len(summary[k])
                summary[k] |= summary[callee]
                if len(summary[k]) != before:
                    changed = True

    # edge pass: while holding H, every direct with + resolvable call
    edges: Dict[Tuple[LockId, LockId], Tuple[str, int]] = {}

    def walk(node: ast.AST, held: List[LockId], info: _FuncInfo) -> None:
        if isinstance(node, ast.With):
            acquired = []
            for item in node.items:
                chain = attr_chain(item.context_expr)
                lk = (env.lock_at(chain, info.cls, info.local_types)
                      if chain else None)
                if lk:
                    for h in held:
                        if h != lk:
                            edges.setdefault((h, lk),
                                             (info.path, node.lineno))
                        elif _kind(models, lk) == "Lock":
                            report.add(
                                "lock-self-deadlock", info.path, node.lineno,
                                f"{lk[0]}.{lk[1]}",
                                f"nested acquisition of non-reentrant "
                                f"{lk[0]}.{lk[1]} deadlocks")
                    acquired.append(lk)
            held = held + acquired
            for stmt in node.body:
                walk(stmt, held, info)
            return
        if isinstance(node, ast.Call) and held:
            target = None
            if isinstance(node.func, ast.Attribute):
                recv = attr_chain(node.func.value)
                if recv is not None:
                    owner = env.resolve_chain(recv, info.cls,
                                              info.local_types)
                    if owner and (owner, node.func.attr) in funcs:
                        target = (owner, node.func.attr)
            elif isinstance(node.func, ast.Name):
                if (None, node.func.id) in funcs:
                    target = (None, node.func.id)
            if target:
                for lk in summary[target]:
                    for h in held:
                        if h != lk:
                            edges.setdefault((h, lk),
                                             (info.path, node.lineno))
                        elif _kind(models, lk) == "Lock":
                            report.add(
                                "lock-self-deadlock", info.path,
                                node.lineno, f"{lk[0]}.{lk[1]}",
                                f"{target[0]}.{target[1]} re-acquires "
                                f"non-reentrant {lk[0]}.{lk[1]} already "
                                f"held here — deadlocks")
        for child in ast.iter_child_nodes(node):
            walk(child, held, info)

    for info in funcs.values():
        for stmt in info.node.body:
            walk(stmt, [], info)

    _report_cycles(edges, report)
    return {
        "locks": sorted(f"{c}.{a}" for c, m in models.items()
                        for a in m.lock_attrs),
        "edges": sorted(f"{a[0]}.{a[1]} -> {b[0]}.{b[1]}"
                        for (a, b) in edges),
    }


def _kind(models: Dict[str, ClassModel], lk: LockId) -> str:
    m = models.get(lk[0])
    return m.lock_attrs.get(lk[1], "RLock") if m else "RLock"


def _report_cycles(edges: Dict[Tuple[LockId, LockId], Tuple[str, int]],
                   report: Report) -> None:
    adj: Dict[LockId, List[LockId]] = {}
    for (a, b) in edges:
        adj.setdefault(a, []).append(b)
        adj.setdefault(b, [])
    seen_cycles: Set[Tuple[LockId, ...]] = set()
    WHITE, GREY, BLACK = 0, 1, 2
    color = {n: WHITE for n in adj}
    stack: List[LockId] = []

    def dfs(n: LockId) -> None:
        color[n] = GREY
        stack.append(n)
        for m in sorted(adj[n]):
            if color[m] == GREY:
                cyc = tuple(stack[stack.index(m):])
                i = cyc.index(min(cyc))
                canon = cyc[i:] + cyc[:i]
                if canon not in seen_cycles:
                    seen_cycles.add(canon)
                    path, line = edges[(n, m)]
                    names = " -> ".join(f"{c}.{a}" for c, a in canon)
                    report.add(
                        "lock-order-cycle", path, line,
                        "->".join(f"{c}.{a}" for c, a in canon),
                        f"lock-order cycle: {names} -> {canon[0][0]}."
                        f"{canon[0][1]} — threads taking these locks in "
                        f"different orders can deadlock")
            elif color[m] == WHITE:
                dfs(m)
        stack.pop()
        color[n] = BLACK

    for n in sorted(adj):
        if color[n] == WHITE:
            dfs(n)


def run(modules: Dict[str, ast.Module], sources: Dict[str, List[str]],
        report: Report) -> Dict[str, object]:
    models = build_class_models(modules, sources)
    learned = check_discipline(models, report)
    order = build_lock_order(modules, models, report)
    return {"guarded": learned, **order}
