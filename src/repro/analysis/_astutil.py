"""Small shared AST helpers for the analysis passes (stdlib only)."""
from __future__ import annotations

import ast
import os
import re
from typing import Iterator, List, Optional, Tuple

# marker comment on (or one line above) a ``def`` whose body mutates
# lock-guarded state on behalf of callers that already hold the lock
CALLER_LOCK_MARKER = re.compile(r"#\s*lock:\s*caller")


def iter_py_files(paths: List[str]) -> Iterator[str]:
    for p in paths:
        if os.path.isfile(p):
            if p.endswith(".py"):
                yield p
        else:
            for root, dirs, files in os.walk(p):
                dirs[:] = sorted(d for d in dirs
                                 if d not in ("__pycache__", ".git"))
                for f in sorted(files):
                    if f.endswith(".py"):
                        yield os.path.join(root, f)


def parse_module(path: str) -> Tuple[Optional[ast.Module], List[str]]:
    with open(path, encoding="utf-8") as f:
        src = f.read()
    try:
        return ast.parse(src, filename=path), src.splitlines()
    except SyntaxError:
        return None, src.splitlines()


def attr_chain(node: ast.AST) -> Optional[Tuple[str, ...]]:
    """``self.ctl.registry._lock`` -> ("self","ctl","registry","_lock").

    Returns None for anything that is not a pure Name/Attribute chain
    (calls, subscripts, literals...).
    """
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return tuple(reversed(parts))
    return None


def store_root(node: ast.AST) -> Tuple[Optional[Tuple[str, ...]], bool]:
    """Resolve an assignment *target* down to its rooted chain.

    Peels Subscript/Attribute layers: ``self.chips[c].owner`` roots at
    ``("self", "chips")``.  Second element is True when the chain passes
    through a Call (``self._get(x).attr = ...`` — a store through a helper
    call's result), in which case the returned chain is the *callee* chain
    (``("self", "_get")``).
    """
    while True:
        if isinstance(node, ast.Subscript):
            node = node.value
        elif isinstance(node, ast.Attribute):
            if isinstance(node.value, ast.Call):
                return attr_chain(node.value.func), True
            chain = attr_chain(node)
            if chain is not None:
                return chain, False      # pure chain from here down
            node = node.value            # impure (subscript below): peel
        else:
            break
    return attr_chain(node), False


def call_name(node: ast.Call) -> Optional[str]:
    """Last path component of the called function, if statically nameable."""
    if isinstance(node.func, ast.Attribute):
        return node.func.attr
    if isinstance(node.func, ast.Name):
        return node.func.id
    return None


def ctor_class(node: ast.AST) -> Optional[str]:
    """``ClassName(...)`` (possibly inside ``x or ClassName(...)``) -> name."""
    if isinstance(node, ast.BoolOp):
        for v in node.values:
            got = ctor_class(v)
            if got:
                return got
        return None
    if isinstance(node, ast.Call):
        chain = attr_chain(node.func)
        if chain:
            return chain[-1]
    return None


def has_caller_lock_marker(lines: List[str], node: ast.AST) -> bool:
    """True if the def line or the line above carries ``# lock: caller``."""
    lineno = getattr(node, "lineno", 0)
    for i in (lineno - 1, lineno - 2):          # 0-indexed def line, line above
        if 0 <= i < len(lines) and CALLER_LOCK_MARKER.search(lines[i]):
            return True
    return False


def const_str(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None
