"""CLI: ``python -m repro.analysis [paths] [options]``.

Exit status 0 when every error-severity finding is covered by the baseline
(the checked-in baseline is empty — the repo is clean), 1 otherwise.  This
is the CI gate.

    python -m repro.analysis src/repro             # gate (default paths)
    python -m repro.analysis --describe            # learned concurrency model
    python -m repro.analysis --json findings.json  # machine-readable output
    python -m repro.analysis --update-baseline     # re-grandfather findings
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from repro.analysis.report import dump_baseline, load_baseline
from repro.analysis.run import analyze_paths

_DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__), "baseline.json")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="concurrency & lifecycle verifier for the control plane")
    ap.add_argument("paths", nargs="*", default=None,
                    help="files/directories to analyze (default: src/repro)")
    ap.add_argument("--baseline", default=_DEFAULT_BASELINE,
                    help="baseline JSON of grandfathered findings")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the baseline: any error finding fails")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline with the current findings")
    ap.add_argument("--json", dest="json_out", default=None,
                    help="write all findings (and the model) to this file")
    ap.add_argument("--describe", action="store_true",
                    help="print the learned concurrency model and exit 0")
    args = ap.parse_args(argv)

    paths = args.paths or None
    if not paths:
        for cand in ("src/repro", os.path.join(
                os.path.dirname(__file__), "..")):
            if os.path.isdir(cand):
                paths = [os.path.normpath(cand)]
                break
    report, model = analyze_paths(paths)

    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump({"findings": [x.to_dict() for x in report.findings],
                       "model": model}, f, indent=2, sort_keys=True)
            f.write("\n")

    if args.describe:
        print(json.dumps(model, indent=2, sort_keys=True))
        return 0

    if args.update_baseline:
        dump_baseline(args.baseline, report.errors())
        print(f"baseline updated: {len(report.errors())} findings "
              f"-> {args.baseline}")
        return 0

    baseline = [] if args.no_baseline else load_baseline(args.baseline)
    new = report.new_findings(baseline)
    for f in report.findings:
        marker = "" if f in new or f.severity != "error" else " (baseline)"
        print(f.render() + marker)
    n_warn = len(report.findings) - len(report.errors())
    print(f"{len(report.errors())} error(s) "
          f"({len(new)} new, {len(report.errors()) - len(new)} baselined), "
          f"{n_warn} warning(s) over {len(paths or [])} path(s)")
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
