"""Findings, fingerprints and the baseline diff.

A :class:`Finding` is one analyzer hit.  Its *fingerprint* deliberately
excludes the line number — baselines must survive unrelated edits above a
grandfathered site — and keys on ``(rule, path, symbol)`` plus the detail
discriminator, so two distinct violations inside one function still get
distinct fingerprints only when the analyzer gives them distinct symbols.

The baseline file is a JSON list of fingerprint objects.  The repo's
checked-in baseline is empty: core/gateway findings were *fixed*, not
grandfathered, and the CI gate fails on any new finding.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass
class Finding:
    rule: str          # e.g. "lock-discipline", "lock-order-cycle"
    path: str          # file the finding anchors to (repo-relative if possible)
    line: int          # 1-indexed; 0 when the finding is whole-file/global
    symbol: str        # qualified symbol, e.g. "Monitor.heartbeat:last_heartbeat"
    message: str
    severity: str = "error"      # "error" gates CI; "warning" is advisory

    def fingerprint(self) -> Tuple[str, str, str]:
        return (self.rule, _norm(self.path), self.symbol)

    def to_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)

    def render(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        return f"{loc}: [{self.rule}] {self.severity}: {self.message}"


def _norm(path: str) -> str:
    """Normalize to a stable repo-relative form so fingerprints match no
    matter what directory the CLI was invoked from."""
    p = path.replace(os.sep, "/")
    for marker in ("src/repro/", "tests/"):
        i = p.find(marker)
        if i >= 0:
            return p[i:]
    return p.lstrip("./")


class Report:
    """Accumulates findings across passes; diffs against a baseline."""

    def __init__(self) -> None:
        self.findings: List[Finding] = []

    def add(self, rule: str, path: str, line: int, symbol: str,
            message: str, severity: str = "error") -> None:
        self.findings.append(Finding(rule, path, line, symbol, message,
                                     severity))

    def extend(self, other: "Report") -> None:
        self.findings.extend(other.findings)

    def errors(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == "error"]

    def new_findings(self, baseline: List[Tuple[str, str, str]]
                     ) -> List[Finding]:
        """Errors not covered by the baseline (warnings never gate)."""
        pool = list(baseline)
        out = []
        for f in self.errors():
            fp = f.fingerprint()
            if fp in pool:
                pool.remove(fp)      # multiset semantics: one entry, one hit
            else:
                out.append(f)
        return out

    def to_json(self) -> str:
        return json.dumps([f.to_dict() for f in self.findings], indent=2,
                          sort_keys=True) + "\n"


def load_baseline(path: Optional[str]) -> List[Tuple[str, str, str]]:
    if not path or not os.path.exists(path):
        return []
    with open(path) as f:
        raw = json.load(f)
    return [(e["rule"], _norm(e["path"]), e["symbol"]) for e in raw]


def dump_baseline(path: str, findings: List[Finding]) -> None:
    entries = [{"rule": f.rule, "path": _norm(f.path), "symbol": f.symbol}
               for f in findings]
    with open(path, "w") as f:
        json.dump(entries, f, indent=2, sort_keys=True)
        f.write("\n")
