"""Repo-specific lint rule pack.

Rule ``falsy-zero-param``: the model-time convention passes ``now=None``
everywhere and substitutes the wall clock with ``now if now is not None
else time.time()``.  The recurring bug (fixed at least three times across
PRs 1-5 in ``pump``/``dead_blocks``/``expired``/``run_round``) is the
truthiness shortcut — ``if now:`` / ``now or time.time()`` — which
silently swaps wall clock in at model time 0.0 and corrupts every duration
derived from it.  The same falsy-zero trap applies to the other
``None``-defaulted numeric knobs where 0 is a meaningful value
(``max_rate_hz=0.0`` is "paused", ``max_inflight=0`` is "dispatch
nothing").  This rule flags any truthiness test of those parameters;
``is (not) None`` comparisons are the sanctioned form and never flagged.
"""
from __future__ import annotations

import ast
from typing import Dict, List

from repro.analysis.report import Report

# parameter names where 0/0.0 is a legal value distinct from None
_SUSPECT_PARAMS = {"now", "until_t", "deadline_at", "queued_at",
                   "enqueued_at", "max_rate_hz", "max_inflight"}


def _suspect_args(func: ast.FunctionDef) -> List[str]:
    args = func.args
    names = [a.arg for a in args.args + args.kwonlyargs + args.posonlyargs]
    return [n for n in names if n in _SUSPECT_PARAMS]


class _TruthinessScanner(ast.NodeVisitor):
    def __init__(self, suspects: List[str]):
        self.suspects = set(suspects)
        self.hits: List[ast.Name] = []   # bare-name truthiness uses
        # a reassignment like ``now = now if now is not None else ...``
        # retires the suspect: after it, ``now`` is a plain float
        self.retired: set = set()

    def _flag(self, node: ast.AST) -> None:
        if isinstance(node, ast.Name) and node.id in self.suspects \
                and node.id not in self.retired:
            self.hits.append(node)

    def visit_If(self, node: ast.If) -> None:
        self._flag(node.test)
        self.generic_visit(node)

    def visit_While(self, node: ast.While) -> None:
        self._flag(node.test)
        self.generic_visit(node)

    def visit_IfExp(self, node: ast.IfExp) -> None:
        self._flag(node.test)
        self.generic_visit(node)

    def visit_Assert(self, node: ast.Assert) -> None:
        self._flag(node.test)
        self.generic_visit(node)

    def visit_UnaryOp(self, node: ast.UnaryOp) -> None:
        if isinstance(node.op, ast.Not):
            self._flag(node.operand)
        self.generic_visit(node)

    def visit_BoolOp(self, node: ast.BoolOp) -> None:
        # ``now or time.time()`` — any bare suspect in an and/or chain is
        # a truthiness use, whether as condition or value-select
        for v in node.values:
            self._flag(v)
        self.generic_visit(node)

    def visit_Assign(self, node: ast.Assign) -> None:
        self.generic_visit(node)
        for t in node.targets:
            if isinstance(t, ast.Name) and t.id in self.suspects:
                self.retired.add(t.id)

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        return          # nested defs get their own scan

    visit_AsyncFunctionDef = visit_FunctionDef


def run(modules: Dict[str, ast.Module], report: Report) -> None:
    for path, tree in modules.items():
        for node in ast.walk(tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            suspects = _suspect_args(node)
            if not suspects:
                continue
            scanner = _TruthinessScanner(suspects)
            for stmt in node.body:
                scanner.visit(stmt)
            for hit in scanner.hits:
                report.add(
                    "falsy-zero-param", path, hit.lineno,
                    f"{node.name}:{hit.id}",
                    f"{node.name} tests parameter {hit.id!r} for "
                    f"truthiness — 0/0.0 is a legal value here (model "
                    f"time zero / paused / no dispatch) and falls through "
                    f"to the default; use '{hit.id} is not None'")
