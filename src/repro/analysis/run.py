"""Pass orchestration: parse once, run every analyzer, return one report."""
from __future__ import annotations

import os
from typing import Dict, List, Optional, Tuple

from repro.analysis import events_check, lifecycle, locks, rules
from repro.analysis._astutil import iter_py_files, parse_module
from repro.analysis.report import Report


def _find_js(paths: List[str]) -> List[Tuple[str, str]]:
    out = []
    for p in paths:
        if os.path.isfile(p) and p.endswith(".js"):
            with open(p, encoding="utf-8") as f:
                out.append((p, f.read()))
            continue
        if not os.path.isdir(p):
            continue
        for root, dirs, files in os.walk(p):
            dirs[:] = sorted(d for d in dirs
                             if d not in ("__pycache__", ".git"))
            for f in sorted(files):
                if f.endswith(".js"):
                    full = os.path.join(root, f)
                    with open(full, encoding="utf-8") as fh:
                        out.append((full, fh.read()))
    return out


def analyze_paths(paths: List[str],
                  js_files: Optional[List[Tuple[str, str]]] = None
                  ) -> Tuple[Report, Dict[str, object]]:
    """Run all four passes over ``paths``.  ``js_files`` overrides the
    default scan for ``*.js`` under the given paths (tests)."""
    report = Report()
    modules: Dict[str, object] = {}
    sources: Dict[str, List[str]] = {}
    for path in iter_py_files(paths):
        tree, lines = parse_module(path)
        if tree is None:
            report.add("syntax-error", path, 0, "module",
                       "file does not parse; all passes skipped for it")
            continue
        modules[path] = tree
        sources[path] = lines
    model: Dict[str, object] = {}
    model["locks"] = locks.run(modules, sources, report)
    model["lifecycle"] = lifecycle.run(modules, report)
    if js_files is None:
        js_files = _find_js(paths)
    model["events"] = events_check.run(modules, js_files, report)
    rules.run(modules, report)
    return report, model
