"""Runtime race detection: lock-order recording + serialized-section
ownership assertions.

Activated by ``REPRO_RACE_CHECK=1`` (tests/conftest.py installs it for the
whole pytest session), so every existing daemon/engine/gateway test doubles
as a race-detection corpus:

* :func:`install` replaces ``threading.Lock``/``threading.RLock`` with
  instrumented factories.  Each lock is named by its *creation site*
  (module:function:line), so every ``Registry.__init__`` lock aggregates to
  one node no matter how many registries a test builds.  Per-thread
  acquisition stacks record an order edge A->B whenever B is acquired while
  A is held; an edge that closes a cycle in the global order graph is a
  violation (two threads taking those locks in opposite orders can
  deadlock), as is re-acquiring a held *non-reentrant* lock (self-deadlock).
* :func:`serialized` marks the daemon-serialized sections (scheduler pump,
  engine round, daemon command execution).  The daemon architecture
  guarantees at most one thread inside any of them at a time; two distinct
  threads concurrently inside the same named section means some mutation
  path bypassed the command queue — a violation.

Everything records and keeps going (the suite should finish and report all
violations, not die at the first), and the fixture in conftest asserts the
session ended clean.  Unit tests exercise a private :class:`Recorder`, so
deliberately-seeded violations never pollute the session gate.

Condition-variable note: the instrumented lock forwards ``_is_owned`` /
``_release_save`` / ``_acquire_restore`` straight to the real lock, so
``threading.Condition(instrumented)`` works; during a ``wait()`` the
bookkeeping still shows the waiter holding the lock, which is harmless — a
blocked thread records no new edges.
"""
from __future__ import annotations

import functools
import os
import sys
import threading
from typing import Dict, List, Optional, Set, Tuple

_real_Lock = threading.Lock
_real_RLock = threading.RLock

ENV_FLAG = "REPRO_RACE_CHECK"


def enabled() -> bool:
    return os.environ.get(ENV_FLAG) == "1"


class Recorder:
    """Order graph + violation log.  One global instance backs install();
    tests build private ones via :func:`make_lock` / :func:`serialized`."""

    def __init__(self) -> None:
        self._meta = _real_Lock()           # guards everything below
        self.edges: Dict[str, Set[str]] = {}
        self.edge_sites: Dict[Tuple[str, str], str] = {}
        self.violations: List[str] = []
        self._tls = threading.local()
        self._sections: Dict[str, Tuple[int, int]] = {}  # name->(owner,depth)

    # ------------------------------------------------------------- held stack
    def _stack(self) -> List["InstrumentedLock"]:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    # ------------------------------------------------------------ acquisition
    def before_acquire(self, lock: "InstrumentedLock") -> None:
        stack = self._stack()
        if any(h is lock for h in stack):
            if not lock.reentrant:
                self.record(
                    f"self-deadlock: thread "
                    f"{threading.current_thread().name!r} re-acquired "
                    f"non-reentrant lock {lock.name} it already holds")
            return
        if not stack:
            return
        with self._meta:
            for held in stack:
                a, b = held.name, lock.name
                if a == b:
                    continue
                if b not in self.edges.setdefault(a, set()):
                    # adding a->b: a path b ~> a would close a cycle
                    path = self._path(b, a)
                    self.edges[a].add(b)
                    self.edge_sites[(a, b)] = threading.current_thread().name
                    if path is not None:
                        chain = " -> ".join(path + [b])
                        self.record(
                            f"lock-order inversion: acquiring {b} while "
                            f"holding {a}, but the reverse order "
                            f"{chain} was also observed — threads taking "
                            f"these locks in opposite orders can deadlock",
                            locked=True)

    def after_acquire(self, lock: "InstrumentedLock") -> None:
        self._stack().append(lock)

    def after_release(self, lock: "InstrumentedLock") -> None:
        stack = self._stack()
        for i in range(len(stack) - 1, -1, -1):
            if stack[i] is lock:
                del stack[i]
                break

    def _path(self, src: str, dst: str) -> Optional[List[str]]:
        """DFS path src ~> dst in the current edge graph (caller holds
        _meta).  Returns the node list or None."""
        seen = {src}
        todo: List[Tuple[str, List[str]]] = [(src, [src])]
        while todo:
            node, path = todo.pop()
            if node == dst:
                return path
            for nxt in self.edges.get(node, ()):
                if nxt not in seen:
                    seen.add(nxt)
                    todo.append((nxt, path + [nxt]))
        return None

    # -------------------------------------------------------------- sections
    def enter_section(self, name: str) -> bool:
        me = threading.get_ident()
        with self._meta:
            owner, depth = self._sections.get(name, (0, 0))
            if depth == 0 or owner == me:
                self._sections[name] = (me, depth + 1)
                return True
            self.record(
                f"serialized-section violation: thread "
                f"{threading.current_thread().name!r} entered "
                f"{name!r} while another thread holds it — a mutation "
                f"path bypassed the daemon command queue", locked=True)
            return False

    def exit_section(self, name: str) -> None:
        with self._meta:
            owner, depth = self._sections.get(name, (0, 0))
            if depth > 0:
                self._sections[name] = (owner, depth - 1)

    # ------------------------------------------------------------- reporting
    def record(self, msg: str, locked: bool = False) -> None:
        if locked:                       # caller already holds _meta
            self.violations.append(msg)
            return
        with self._meta:
            self.violations.append(msg)

    def snapshot(self) -> List[str]:
        with self._meta:
            return list(self.violations)

    def order_edges(self) -> List[str]:
        with self._meta:
            return sorted(f"{a} -> {b}" for a, bs in self.edges.items()
                          for b in bs)


class InstrumentedLock:
    """Wraps a real Lock/RLock; Condition-compatible (see module doc)."""

    def __init__(self, inner, name: str, reentrant: bool,
                 recorder: Recorder):
        self._inner = inner
        self.name = name
        self.reentrant = reentrant
        self._recorder = recorder

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        self._recorder.before_acquire(self)
        got = self._inner.acquire(blocking, timeout)
        if got:
            self._recorder.after_acquire(self)
        return got

    def release(self) -> None:
        self._inner.release()
        self._recorder.after_release(self)

    def locked(self) -> bool:
        inner_locked = getattr(self._inner, "locked", None)
        return inner_locked() if inner_locked is not None else False

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    # --- threading.Condition compatibility: delegate to the real lock so
    # wait() can release/restore without tripping the bookkeeping
    def _is_owned(self):
        inner = getattr(self._inner, "_is_owned", None)
        if inner is not None:
            return inner()
        if self._inner.acquire(False):
            self._inner.release()
            return False
        return True

    def _release_save(self):
        inner = getattr(self._inner, "_release_save", None)
        if inner is not None:
            return inner()
        self._inner.release()

    def _acquire_restore(self, state):
        inner = getattr(self._inner, "_acquire_restore", None)
        if inner is not None:
            return inner(state)
        self._inner.acquire()

    def __getattr__(self, attr):
        # CPython internals poke extra methods on lock objects
        # (e.g. concurrent.futures registers _at_fork_reinit at-fork
        # handlers); forward anything we don't wrap to the real lock.
        try:
            inner = object.__getattribute__(self, "_inner")
        except AttributeError:
            raise AttributeError(attr)
        return getattr(inner, attr)

    def __repr__(self) -> str:
        return f"<InstrumentedLock {self.name} {self._inner!r}>"


_recorder = Recorder()            # the session-global recorder
_installed = False


def _creation_site(depth: int = 2) -> str:
    try:
        frame = sys._getframe(depth)
    except ValueError:
        return "unknown:0"
    fn = os.path.basename(frame.f_code.co_filename)
    if fn.endswith(".py"):
        fn = fn[:-3]
    return f"{fn}:{frame.f_code.co_name}:{frame.f_lineno}"


def make_lock(name: Optional[str] = None, reentrant: bool = False,
              recorder: Optional[Recorder] = None) -> InstrumentedLock:
    """Explicitly-wrapped lock (unit tests / ad-hoc instrumentation)."""
    inner = _real_RLock() if reentrant else _real_Lock()
    return InstrumentedLock(inner, name or _creation_site(),
                            reentrant, recorder or _recorder)


def _lock_factory():
    return InstrumentedLock(_real_Lock(), _creation_site(), False, _recorder)


def _rlock_factory():
    return InstrumentedLock(_real_RLock(), _creation_site(), True, _recorder)


def install() -> None:
    """Monkeypatch ``threading.Lock``/``RLock``.  Locks created *before*
    install (module import time, interpreter internals) stay untracked."""
    global _installed
    if _installed:
        return
    threading.Lock = _lock_factory
    threading.RLock = _rlock_factory
    _installed = True


def uninstall() -> None:
    global _installed
    threading.Lock = _real_Lock
    threading.RLock = _real_RLock
    _installed = False


def installed() -> bool:
    return _installed


def violations() -> List[str]:
    return _recorder.snapshot()


def order_edges() -> List[str]:
    return _recorder.order_edges()


# ----------------------------------------------------------- serialized guard
class _NullCtx:
    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL = _NullCtx()


class _SectionCtx:
    __slots__ = ("name", "recorder", "_entered")

    def __init__(self, name: str, recorder: Recorder):
        self.name = name
        self.recorder = recorder
        self._entered = False

    def __enter__(self):
        self._entered = self.recorder.enter_section(self.name)
        return self

    def __exit__(self, *exc):
        if self._entered:
            self.recorder.exit_section(self.name)
        return False


def serialized(name: str, recorder: Optional[Recorder] = None):
    """Single-entrancy assertion for daemon-serialized state.  Free when
    the checker is not installed (returns a shared no-op context)."""
    if recorder is None:
        if not _installed:
            return _NULL
        recorder = _recorder
    return _SectionCtx(name, recorder)


def guard_serialized(name: str):
    """Decorator form of :func:`serialized` for the control-plane mutators
    (scheduler pump, engine round, controller tick).  Near-free when the
    checker is not installed."""
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            if not _installed:
                return fn(*args, **kwargs)
            with _SectionCtx(name, _recorder):
                return fn(*args, **kwargs)
        return wrapper
    return deco
