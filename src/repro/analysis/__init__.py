"""Static-analysis + runtime-verification subsystem for the control plane.

The multi-block control plane (registry, partitioner, monitor, event bus,
daemon pump, gateway threads) is correct only under three conventions that
nothing used to check mechanically:

* **lock discipline** — every attribute a class mutates under ``with
  self._lock:`` must *only* be mutated under that lock (``locks``), and
  cross-object lock acquisition must stay acyclic (``locks``, lock-order
  graph);
* **lifecycle discipline** — every block-state change goes through
  ``Block.transition`` and respects the ``TRANSITIONS`` table
  (``lifecycle``);
* **event taxonomy** — every ``bus.publish(kind, ...)`` literal, every
  consumer match and the dashboard's SSE subscription list agree with the
  declared ``EVENT_KINDS`` schema (``events_check``).

``rules`` adds a repo-specific lint pack (falsy-zero model-time bug class).
``runtime_check`` is the dynamic companion: under ``REPRO_RACE_CHECK=1`` it
wraps ``threading.Lock``/``RLock`` with an acquisition-order recorder plus
deadlock-cycle detector, and asserts single-entrancy of daemon-serialized
sections, so the whole test suite doubles as a race-detection corpus.

Zero external dependencies — stdlib ``ast`` only.  Entry point::

    python -m repro.analysis [paths] [--json out.json] [--describe]

Findings diff against ``analysis/baseline.json`` (kept empty: the repo is
clean); any non-baseline finding exits non-zero, which is the CI gate.
"""
from repro.analysis.report import Finding, Report, load_baseline  # noqa: F401
from repro.analysis.run import analyze_paths  # noqa: F401
