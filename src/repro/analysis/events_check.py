"""Event-taxonomy checker.

``core/events.py`` declares the schema (``EVENT_KINDS``).  This pass
collects every *producer* literal (``bus.publish("kind", ...)``) and every
*consumer* reference:

* ``ev.kind == "x"`` / ``ev.kind in {...}`` comparisons (Monitor.on_event),
* literal ``kinds={...}`` sets passed to ``subscribe``/``events_since``/
  ``wait`` (SSE handlers, Monitor.subscribe_to),
* the dashboard's SSE subscription array in ``gateway/static/app.js``
  (regex scan — JS has no AST here).

Unknown kinds on either side are errors: a renamed kind can never again
silently orphan the dashboard or the Monitor's accounting.  A declared
kind that is never published, or that the dashboard does not subscribe
to, is a warning (advisory, does not gate CI).
"""
from __future__ import annotations

import ast
import re
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis._astutil import call_name, const_str
from repro.analysis.report import Report

# kinds= consumers whose literal sets reference the taxonomy
_KIND_SINKS = {"subscribe", "events_since", "wait", "wait_events"}

# the dashboard subscribes in one loop: for (const kind of ["a", "b", ...])
_JS_KIND_ARRAY = re.compile(
    r"const\s+kind\s+of\s*\[([^\]]*)\]", re.MULTILINE)
_JS_STR = re.compile(r"[\"']([a-z_]+)[\"']")


def _declared_kinds() -> Set[str]:
    from repro.core.events import EVENT_KINDS
    return set(EVENT_KINDS)


def _literal_strs(node: ast.AST) -> Optional[List[str]]:
    if isinstance(node, (ast.Set, ast.Tuple, ast.List)):
        out = []
        for elt in node.elts:
            s = const_str(elt)
            if s is None:
                return None
            out.append(s)
        return out
    if isinstance(node, ast.Call) and call_name(node) in ("set", "frozenset") \
            and len(node.args) == 1:
        return _literal_strs(node.args[0])
    return None


def _qual_of(tree: ast.Module) -> Dict[int, str]:
    """lineno -> enclosing function qualname (best effort, for symbols)."""
    out: Dict[int, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for sub in ast.walk(node):
                ln = getattr(sub, "lineno", None)
                if ln is not None and ln not in out:
                    out[ln] = node.name
    return out


def run(modules: Dict[str, ast.Module], js_files: List[Tuple[str, str]],
        report: Report) -> Dict[str, object]:
    declared = _declared_kinds()
    published: Dict[str, List[Tuple[str, int]]] = {}
    consumed: Dict[str, List[Tuple[str, int]]] = {}

    for path, tree in modules.items():
        quals = _qual_of(tree)
        for node in ast.walk(tree):
            if isinstance(node, ast.Call):
                name = call_name(node)
                if name == "publish":
                    kind = const_str(node.args[0]) if node.args else None
                    if kind is None:
                        for kw in node.keywords:
                            if kw.arg == "kind":
                                kind = const_str(kw.value)
                    if kind is not None:
                        published.setdefault(kind, []).append(
                            (path, node.lineno))
                        if kind not in declared:
                            report.add(
                                "unknown-event-kind", path, node.lineno,
                                f"publish:{kind}",
                                f"publish({kind!r}) is not in EVENT_KINDS "
                                f"(core/events.py) — no consumer will ever "
                                f"see it; declare it or fix the name")
                if name in _KIND_SINKS:
                    for kw in node.keywords:
                        if kw.arg != "kinds":
                            continue
                        kinds = _literal_strs(kw.value)
                        for k in kinds or []:
                            consumed.setdefault(k, []).append(
                                (path, node.lineno))
                            if k not in declared:
                                report.add(
                                    "unknown-event-kind", path, node.lineno,
                                    f"{name}:kinds:{k}",
                                    f"{name}(kinds=...) filters on "
                                    f"{k!r}, which is not in EVENT_KINDS "
                                    f"— the filter can never match")
            elif isinstance(node, ast.Compare) and len(node.ops) == 1:
                # ev.kind == "x"  /  ev.kind in {"a", "b"} — only on
                # event-named receivers: ``job.kind`` (train|serve) and
                # other .kind fields are a different namespace
                left = node.left
                if not (isinstance(left, ast.Attribute)
                        and left.attr == "kind"
                        and isinstance(left.value, ast.Name)
                        and left.value.id in ("ev", "event", "evt")):
                    continue
                cmp_strs = ([const_str(node.comparators[0])]
                            if const_str(node.comparators[0]) is not None
                            else _literal_strs(node.comparators[0]))
                for k in cmp_strs or []:
                    if k is None:
                        continue
                    consumed.setdefault(k, []).append((path, node.lineno))
                    if k not in declared:
                        fn = quals.get(node.lineno, "?")
                        report.add(
                            "unknown-event-kind", path, node.lineno,
                            f"{fn}:kind=={k}",
                            f"{fn} matches ev.kind == {k!r}, which is not "
                            f"in EVENT_KINDS — dead consumer branch "
                            f"(renamed kind?)")

    dashboard: Set[str] = set()
    for js_path, js_src in js_files:
        arrays = _JS_KIND_ARRAY.findall(js_src)
        for arr in arrays:
            for m in _JS_STR.finditer(arr):
                k = m.group(1)
                dashboard.add(k)
                consumed.setdefault(k, []).append((js_path, 0))
                if k not in declared:
                    report.add(
                        "unknown-event-kind", js_path, 0,
                        f"dashboard:{k}",
                        f"the dashboard subscribes to SSE kind {k!r}, "
                        f"which is not in EVENT_KINDS — the stream will "
                        f"never deliver it (renamed kind orphaned the "
                        f"dashboard)")

    for k in sorted(declared - set(published)):
        report.add("unpublished-event-kind", "src/repro/core/events.py", 0,
                   f"declared:{k}",
                   f"EVENT_KINDS declares {k!r} but no publish() literal "
                   f"emits it", severity="warning")
    if dashboard:
        for k in sorted(declared - dashboard):
            report.add("dashboard-kind-gap", js_files[0][0], 0,
                       f"dashboard-missing:{k}",
                       f"EVENT_KINDS declares {k!r} but the dashboard's "
                       f"SSE subscription loop does not include it",
                       severity="warning")

    return {
        "kinds": sorted(declared),
        "published": {k: len(v) for k, v in sorted(published.items())},
        "consumed": sorted(consumed),
        "dashboard": sorted(dashboard),
    }
