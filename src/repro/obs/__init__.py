"""Observability layer: distributed tracing, time-series metrics, and
flight-recorder postmortems for the whole control plane.

Three stdlib-only, inert-by-default components:

* ``trace.TRACER`` — a process-global tracer that opens spans with
  trace/span/parent ids and propagates a trace context along each
  request's whole path (gateway handler → daemon command queue →
  scheduler decision → engine round → runtime dispatch/harvest →
  decode round).  Disabled by default: a disabled tracer's ``span()``
  returns a shared no-op and records nothing, so inline deterministic
  mode and ``benchmarks/policy_admission.py`` stay bit-identical.
  Export is Chrome-trace/Perfetto JSON (``GET /v1/trace``).

* ``metrics.REGISTRY`` — a lock-cheap metrics registry (counters,
  gauges, log-bucket histograms with p50/p90/p99) rendered as
  Prometheus text at ``GET /metrics`` and as ring-buffered series
  backing the dashboard sparkline tiles.  Fed from the EventBus by
  ``bridge.wire_bus`` plus direct self-instrumentation of the daemon
  pump loop, engine rounds, SSE fan-out and the HTTP server.

* ``flight.RECORDER`` — a bounded ring of recent events + spans that
  dumps a postmortem JSON artifact automatically on block FAILED, pod
  death, or a daemon pump crash, downloadable via the gateway
  (``GET /v1/postmortems``).

The ``Monitor`` remains the semantic accountant (EWMAs, SLO outcomes,
federation totals); it is now one consumer of the event stream among
several rather than the only sink.
"""
from repro.obs.bridge import wire_bus
from repro.obs.flight import RECORDER, FlightRecorder
from repro.obs.metrics import REGISTRY, MetricsRegistry
from repro.obs.trace import TRACER, Span, Tracer

__all__ = ["TRACER", "Tracer", "Span", "REGISTRY", "MetricsRegistry",
           "RECORDER", "FlightRecorder", "wire_bus"]
