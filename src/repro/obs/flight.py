"""Flight recorder — a bounded ring of recent events + spans that dumps
a postmortem JSON artifact when something dies.

The recorder is a passive EventBus subscriber (``install(bus)``): it
mirrors the last N events globally and per-block, costs one deque append
per event, and mutates nothing in the control plane — deterministic
inline mode is unaffected by its presence.

A dump fires automatically on

* a block entering FAILED (``state`` event with ``state == "failed"``),
* pod death (``ClusterController.fail_pod`` calls ``dump()`` after
  computing the victim set, so the victims' final preempted/state events
  and spans are already in the ring), and
* a daemon pump-loop crash (the daemon's tick exception handler).

Artifacts are written crash-safely (mkstemp in the target directory,
fsync, ``os.replace``) because the typical dump happens exactly when the
process is least healthy.  Each dump also publishes a ``postmortem``
event so dashboards and SSE watchers learn an artifact exists.
"""
from __future__ import annotations

import collections
import json
import os
import tempfile
import threading
import time
from typing import Deque, Dict, List, Optional

from repro.obs.trace import TRACER

#: retained artifact files (oldest pruned beyond this)
MAX_ARTIFACTS = 16


class FlightRecorder:
    """Bounded event/span ring with crash-safe postmortem dumps."""

    def __init__(self, max_events: int = 2048, max_per_app: int = 256):
        self._lock = threading.Lock()
        self._ring: Deque[Dict] = collections.deque(maxlen=max_events)
        self._per_app: Dict[str, Deque[Dict]] = {}
        self._max_per_app = max_per_app
        self._dumps: List[Dict] = []        # newest last, bounded
        self._bus = None
        self.dir: Optional[str] = None
        self._seq = 0

    # ------------------------------------------------------------- wiring
    def configure(self, dir: Optional[str] = None) -> "FlightRecorder":
        """Point artifact output at a directory (daemon passes
        ``<ckpt_root>/postmortems``).  Without one, dumps stay in-memory
        only — still visible to tests and ``GET /v1/postmortems``."""
        if dir is not None:
            self.dir = dir
        return self

    def install(self, bus) -> "FlightRecorder":
        """Mirror every event on ``bus`` and auto-dump on block FAILED."""
        self._bus = bus
        bus.subscribe(self._on_event)
        return self

    def _on_event(self, ev) -> None:
        d = ev.to_dict()
        app_id = d.get("app_id")
        with self._lock:
            self._ring.append(d)
            if app_id:
                ring = self._per_app.get(app_id)
                if ring is None:
                    if len(self._per_app) >= 4096:
                        self._per_app.pop(next(iter(self._per_app)))
                    ring = self._per_app[app_id] = collections.deque(
                        maxlen=self._max_per_app)
                ring.append(d)
        if ev.kind == "state" and d.get("state") == "failed":
            self.dump("block_failed", apps=[app_id] if app_id else None,
                      now=d.get("t"))

    # --------------------------------------------------------------- dump
    def dump(self, reason: str, apps: Optional[List[str]] = None,
             now: Optional[float] = None, detail: Optional[Dict] = None,
             ) -> Dict:
        """Snapshot recent events + the victims' spans into a postmortem
        artifact.  ``apps`` names the victims (None = whole-plane dump,
        e.g. a pump crash)."""
        t = now if now is not None else time.time()
        with self._lock:
            self._seq += 1
            name = f"postmortem-{self._seq:04d}-{reason}"
            events = list(self._ring)
            per_app = {a: list(self._per_app.get(a, ()))
                       for a in (apps or []) if a}
        spans = []
        for a in (apps or []):
            if a:
                spans.extend(s.to_dict() for s in TRACER.spans(app_id=a))
        if not apps:
            spans = [s.to_dict() for s in TRACER.spans()]
        artifact = {"name": name, "reason": reason, "t": t,
                    "apps": [a for a in (apps or []) if a],
                    "detail": detail or {},
                    "n_events": len(events), "n_spans": len(spans),
                    "events": events, "per_app_events": per_app,
                    "spans": spans}
        path = self._write(name, artifact)
        meta = {"name": name, "reason": reason, "t": t,
                "apps": artifact["apps"], "n_events": len(events),
                "n_spans": len(spans), "path": path}
        with self._lock:
            self._dumps.append({"meta": meta, "artifact": artifact})
            while len(self._dumps) > MAX_ARTIFACTS:
                self._dumps.pop(0)
        if self._bus is not None:
            try:
                self._bus.publish("postmortem", block_id=artifact["apps"][0]
                                  if artifact["apps"] else None, now=t,
                                  reason=reason, name=name,
                                  n_events=len(events), n_spans=len(spans))
            except Exception:
                pass            # a dying plane must still get its artifact
        return meta

    def _write(self, name: str, artifact: Dict) -> Optional[str]:
        if self.dir is None:
            return None
        try:
            os.makedirs(self.dir, exist_ok=True)
            path = os.path.join(self.dir, f"{name}.json")
            fd, tmp = tempfile.mkstemp(dir=self.dir, suffix=".tmp")
            try:
                with os.fdopen(fd, "w") as f:
                    json.dump(artifact, f, indent=1, default=str)
                    f.flush()
                    os.fsync(f.fileno())
                os.replace(tmp, path)
            finally:
                if os.path.exists(tmp):
                    os.unlink(tmp)
            self._prune()
            return path
        except OSError:
            return None         # dump must never take the plane down

    def _prune(self) -> None:
        try:
            files = sorted(f for f in os.listdir(self.dir)
                           if f.startswith("postmortem-")
                           and f.endswith(".json"))
            for stale in files[:-MAX_ARTIFACTS]:
                os.unlink(os.path.join(self.dir, stale))
        except OSError:
            pass

    # --------------------------------------------------------------- reads
    def dumps(self) -> List[Dict]:
        """Newest-first artifact metadata (gateway listing)."""
        with self._lock:
            return [d["meta"] for d in reversed(self._dumps)]

    def read(self, name: str) -> Optional[Dict]:
        with self._lock:
            for d in self._dumps:
                if d["meta"]["name"] == name:
                    return d["artifact"]
        return None

    @property
    def last(self) -> Optional[Dict]:
        with self._lock:
            return self._dumps[-1]["artifact"] if self._dumps else None

    def reset(self) -> None:
        with self._lock:
            self._ring.clear()
            self._per_app.clear()
            self._dumps.clear()
            self._seq = 0


#: the process-global recorder the daemon installs on its bus
RECORDER = FlightRecorder()
