"""Distributed tracing — spans with trace/span/parent ids over one
process-global ``TRACER``.

Design constraints (why this is not a straight OpenTelemetry clone):

* **Inert by default.**  The control plane's deterministic inline mode
  and the ``policy_admission`` benchmark must be bit-identical with the
  tracer present.  A disabled tracer never reads a clock, never
  allocates a span and never takes a lock: ``span()`` returns one shared
  no-op object.  Hot loops additionally guard on ``TRACER.enabled`` so
  the disabled cost is a single attribute read.

* **Two propagation channels.**  Within a thread, spans nest through a
  thread-local stack (the gateway request span parents the daemon call
  span parents the scheduler decision span, all on the worker thread).
  Across threads — the daemon's command queue hands work from a gateway
  worker to the pump thread — the enqueuer captures ``context()`` into
  the ``Command`` and the pump re-attaches it, so the queue-wait and
  execution spans parent back to the originating request.

* **Block binding.**  A request is transient but a block lives on: the
  first span labeled with an ``app_id`` binds that block to its trace
  (``bind()``), and later spans for the block with no thread-local
  parent (engine rounds on the pump/pod-worker threads, decode rounds,
  post-resume activity) join the *bound* trace.  That is what makes a
  single ``generate`` request one connected trace across gateway →
  daemon queue → scheduler → engine → decode round, and what makes the
  trace context survive preempt/resume — the binding is keyed by
  ``app_id`` and outlives the runtime object.

Spans are kept in a bounded ring and exported as Chrome-trace JSON
(``{"traceEvents": [...]}``, ``ph: "X"`` complete events) which loads in
``chrome://tracing`` and Perfetto.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
import threading
import time
from typing import Deque, Dict, List, Optional, Tuple

#: (trace_id, parent_span_id) — what crosses a thread boundary
Context = Tuple[str, str]


@dataclasses.dataclass
class Span:
    trace_id: str
    span_id: str
    parent_id: Optional[str]
    name: str
    cat: str
    t0: float                      # perf_counter at open
    t1: float = 0.0                # perf_counter at close
    tid: int = 0                   # opening thread id
    app_id: Optional[str] = None
    user: Optional[str] = None
    args: Dict = dataclasses.field(default_factory=dict)

    @property
    def dur_s(self) -> float:
        return max(0.0, self.t1 - self.t0)

    def to_dict(self) -> Dict:
        d = {"trace_id": self.trace_id, "span_id": self.span_id,
             "parent_id": self.parent_id, "name": self.name,
             "cat": self.cat, "t0": self.t0, "t1": self.t1,
             "dur_s": self.dur_s, "tid": self.tid}
        if self.app_id is not None:
            d["app_id"] = self.app_id
        if self.user is not None:
            d["user"] = self.user
        if self.args:
            d["args"] = dict(self.args)
        return d


class _NoopSpan:
    """Shared do-nothing span: what a disabled tracer hands out.  Falsy,
    context-manager compatible, accepts the live span's surface."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def __bool__(self):
        return False

    def set(self, **args):
        return self


_NOOP = _NoopSpan()


class _LiveSpan:
    """An open span: closes (and lands in the tracer ring) on __exit__."""

    __slots__ = ("tracer", "span")

    def __init__(self, tracer: "Tracer", span: Span):
        self.tracer = tracer
        self.span = span

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        if exc_type is not None:
            self.span.args.setdefault("error", repr(exc))
        self.tracer._close(self.span)
        return False

    def __bool__(self):
        return True

    def set(self, **args):
        self.span.args.update(args)
        return self


class Tracer:
    """Process-global span collector (see module docstring).  All public
    methods are safe to call with the tracer disabled — they no-op."""

    def __init__(self, max_spans: int = 16384):
        self.enabled = False
        self._lock = threading.Lock()
        self._spans: Deque[Span] = collections.deque(maxlen=max_spans)
        self._tls = threading.local()
        # itertools.count.__next__ is atomic under the GIL: id allocation
        # costs no lock on the span hot path
        self._ids = itertools.count(1)
        self._traces = itertools.count(1)
        #: app_id -> (trace_id, anchor span_id): the block's bound trace
        self._blocks: Dict[str, Context] = {}

    # ----------------------------------------------------------- lifecycle
    def enable(self) -> "Tracer":
        self.enabled = True
        return self

    def disable(self) -> None:
        self.enabled = False

    def reset(self) -> None:
        """Drop every recorded span, block binding and id counter (tests;
        the enabled flag is left as-is)."""
        with self._lock:
            self._spans.clear()
            self._blocks.clear()
            self._ids = itertools.count(1)
            self._traces = itertools.count(1)

    # ------------------------------------------------------------- plumbing
    def _stack(self) -> List[Span]:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def _new_trace_id(self) -> str:
        return f"t{next(self._traces):012x}"

    def _new_span_id(self) -> str:
        return f"s{next(self._ids):012x}"

    def _close(self, span: Span) -> None:
        span.t1 = time.perf_counter()
        st = self._stack()
        if st and st[-1] is span:
            st.pop()
        else:                       # defensive: unbalanced exit
            try:
                st.remove(span)
            except ValueError:
                pass
        with self._lock:
            self._spans.append(span)

    # --------------------------------------------------------------- spans
    def span(self, name: str, cat: str = "span",
             app_id: Optional[str] = None, user: Optional[str] = None,
             ctx: Optional[Context] = None, t0: Optional[float] = None,
             parent: str = "auto", **args):
        """Open a span.  Parent resolution order: explicit ``ctx`` (a
        cross-thread handoff), the thread-local stack top, the block
        binding for ``app_id``, else a fresh trace root.
        ``parent="binding"`` flips the stack/binding priority: a span for
        a *bound* block joins the block's trace even when the opening
        thread already has a span stack (the engine's per-app dispatch
        runs under a round loop but must join the request trace that
        bound the block).  ``t0`` backdates the open (the pump starts the
        exec span at the exact instant the queue-wait span ends, so the
        two tile the enclosing call).  Returns a context manager (the
        shared no-op when disabled)."""
        if not self.enabled:
            return _NOOP
        st = self._stack()
        bound = self._blocks.get(app_id) if app_id is not None else None
        if ctx is not None:
            trace_id, parent_id = ctx
        elif parent == "binding" and bound is not None:
            trace_id, parent_id = bound
        elif st:
            trace_id, parent_id = st[-1].trace_id, st[-1].span_id
        elif bound is not None:
            trace_id, parent_id = bound
        else:
            trace_id, parent_id = self._new_trace_id(), None
        span = Span(trace_id=trace_id, span_id=self._new_span_id(),
                    parent_id=parent_id, name=name, cat=cat,
                    t0=t0 if t0 is not None else time.perf_counter(),
                    tid=threading.get_ident() % 100000,
                    app_id=app_id, user=user, args=dict(args) if args else {})
        if app_id is not None and app_id not in self._blocks:
            # first span for this block: bind the block to this trace so
            # later engine/decode activity (and post-resume spans) join it
            with self._lock:
                self._blocks.setdefault(app_id, (trace_id, span.span_id))
        st.append(span)
        return _LiveSpan(self, span)

    def record(self, name: str, t0: float, t1: float, cat: str = "span",
               ctx: Optional[Context] = None, app_id: Optional[str] = None,
               user: Optional[str] = None, **args) -> None:
        """Record an already-elapsed span from explicit ``perf_counter``
        endpoints (e.g. the daemon queue-wait measured between enqueue
        and pump claim — no thread ever 'holds' that span open)."""
        if not self.enabled:
            return
        trace_id, parent_id = ctx if ctx is not None else \
            (self._new_trace_id(), None)
        span = Span(trace_id=trace_id, span_id=self._new_span_id(),
                    parent_id=parent_id, name=name, cat=cat, t0=t0, t1=t1,
                    tid=threading.get_ident() % 100000,
                    app_id=app_id, user=user, args=dict(args) if args else {})
        with self._lock:
            self._spans.append(span)

    # ------------------------------------------------------------- context
    def context(self) -> Optional[Context]:
        """The current thread's trace context — what an enqueuer captures
        into a ``Command`` for the pump to ``attach``."""
        if not self.enabled:
            return None
        st = getattr(self._tls, "stack", None)
        if not st:
            return None
        return (st[-1].trace_id, st[-1].span_id)

    def current_request_id(self) -> Optional[str]:
        """The ``X-Request-ID`` carried by the innermost span that has one
        (the gateway stamps it on the request root span) — what the
        EventBus folds into event payloads as correlation metadata."""
        if not self.enabled:
            return None
        st = getattr(self._tls, "stack", None)
        if not st:
            return None
        for span in reversed(st):
            rid = span.args.get("request_id")
            if rid is not None:
                return rid
        return None

    def bind(self, app_id: str) -> None:
        """Bind ``app_id`` to the current thread's trace context (e.g. the
        generate command binds the serve block to the request's trace so
        its decode rounds join it)."""
        if not self.enabled:
            return
        ctx = self.context()
        if ctx is not None:
            with self._lock:
                self._blocks.setdefault(app_id, ctx)

    def block_trace(self, app_id: str) -> Optional[str]:
        """The trace id a block is bound to (stable across
        preempt/resume), or None."""
        bound = self._blocks.get(app_id)
        return bound[0] if bound else None

    # -------------------------------------------------------------- export
    def spans(self, app_id: Optional[str] = None,
              trace_id: Optional[str] = None) -> List[Span]:
        with self._lock:
            out = list(self._spans)
        if app_id is not None:
            bound = self.block_trace(app_id)
            out = [s for s in out
                   if s.app_id == app_id
                   or (bound is not None and s.trace_id == bound)]
        if trace_id is not None:
            out = [s for s in out if s.trace_id == trace_id]
        return out

    def chrome_trace(self, app_id: Optional[str] = None,
                     trace_id: Optional[str] = None) -> Dict:
        """Chrome-trace/Perfetto JSON: one ``ph: "X"`` complete event per
        finished span, timestamps in microseconds on the tracer's own
        monotonic axis."""
        events = []
        for s in self.spans(app_id=app_id, trace_id=trace_id):
            args = {"trace_id": s.trace_id, "span_id": s.span_id}
            if s.parent_id:
                args["parent_id"] = s.parent_id
            if s.app_id:
                args["app_id"] = s.app_id
            if s.user:
                args["user"] = s.user
            args.update(s.args)
            events.append({"name": s.name, "cat": s.cat, "ph": "X",
                           "ts": round(s.t0 * 1e6, 3),
                           "dur": round(s.dur_s * 1e6, 3),
                           "pid": 1, "tid": s.tid, "args": args})
        return {"traceEvents": events, "displayTimeUnit": "ms"}


#: the process-global tracer every subsystem instruments against
TRACER = Tracer()
