"""Time-series metrics — counters, gauges, and log-bucket histograms in
one process-global ``REGISTRY``.

The hot-path cost model: every instrument update is one dict lookup plus
one arithmetic op under a single registry lock (uncontended in CPython:
acquire/release is ~100ns).  Histograms bucket by log2 of the value so a
record is an ``int.bit_length`` call, not a sort; quantiles (p50/p90/p99)
are reconstructed from bucket counts at render time, which is the cold
path (`GET /metrics` scrape or a dashboard poll).

Naming follows Prometheus conventions: ``repro_<subsystem>_<what>_<unit>``
with ``_total`` for counters, labels in ``{k="v"}`` form sorted by key.
The registry also keeps a bounded ring per series (``sample()``) so the
dashboard can draw sparklines without an external TSDB.
"""
from __future__ import annotations

import collections
import math
import threading
import time
from typing import Deque, Dict, List, Optional, Tuple

#: canonical label ordering inside a series key
Labels = Tuple[Tuple[str, str], ...]


def _labels(labels: Optional[Dict[str, str]]) -> Labels:
    if not labels:
        return ()
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _fmt_labels(labels: Labels) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in labels)
    return "{" + inner + "}"


class _Hist:
    """Log2-bucketed histogram over positive floats.

    Values are scaled to microseconds-resolution integers before
    bucketing so sub-millisecond latencies spread across buckets instead
    of collapsing into one.  Bucket ``i`` holds values in
    ``[2^(i-1), 2^i) µs``; quantiles interpolate within a bucket.
    """

    __slots__ = ("counts", "n", "total", "vmin", "vmax")

    SCALE = 1e6          # seconds -> µs
    NBUCKETS = 64

    def __init__(self):
        self.counts = [0] * self.NBUCKETS
        self.n = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = 0.0

    def record(self, v: float) -> None:
        if v < 0.0:
            v = 0.0
        i = int(v * self.SCALE).bit_length()
        if i >= self.NBUCKETS:
            i = self.NBUCKETS - 1
        self.counts[i] += 1
        self.n += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v

    def quantile(self, q: float) -> float:
        """Approximate quantile in seconds (midpoint of the target
        log-bucket, clamped to the observed min/max)."""
        if self.n == 0:
            return 0.0
        target = q * self.n
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= target:
                lo = (2 ** (i - 1)) / self.SCALE if i > 0 else 0.0
                hi = (2 ** i) / self.SCALE
                mid = (lo + hi) / 2.0
                return min(max(mid, self.vmin), self.vmax)
        return self.vmax

    def summary(self) -> Dict[str, float]:
        mean = self.total / self.n if self.n else 0.0
        return {"count": self.n, "sum": self.total, "mean": mean,
                "min": self.vmin if self.n else 0.0, "max": self.vmax,
                "p50": self.quantile(0.50), "p90": self.quantile(0.90),
                "p99": self.quantile(0.99)}


class MetricsRegistry:
    """Lock-cheap named counters/gauges/histograms plus per-series sample
    rings for dashboard sparklines.  Safe to use from any thread; never
    raises on the update path."""

    RING = 120           # sparkline samples kept per sampled series

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[Tuple[str, Labels], float] = {}
        self._gauges: Dict[Tuple[str, Labels], float] = {}
        self._hists: Dict[Tuple[str, Labels], _Hist] = {}
        self._help: Dict[str, str] = {}
        self._series: Dict[str, Deque[Tuple[float, float]]] = {}

    # ------------------------------------------------------------ describe
    def describe(self, name: str, help_text: str) -> None:
        with self._lock:
            self._help.setdefault(name, help_text)

    # ------------------------------------------------------------- updates
    def inc(self, name: str, value: float = 1.0,
            labels: Optional[Dict[str, str]] = None) -> None:
        key = (name, _labels(labels))
        with self._lock:
            self._counters[key] = self._counters.get(key, 0.0) + value

    def set_gauge(self, name: str, value: float,
                  labels: Optional[Dict[str, str]] = None) -> None:
        with self._lock:
            self._gauges[(name, _labels(labels))] = float(value)

    def add_gauge(self, name: str, delta: float,
                  labels: Optional[Dict[str, str]] = None) -> float:
        """Atomic gauge increment/decrement (concurrent SSE streams both
        adjusting the stream count must not lose updates).  Clamps at
        zero and returns the new value."""
        key = (name, _labels(labels))
        with self._lock:
            v = max(0.0, self._gauges.get(key, 0.0) + delta)
            self._gauges[key] = v
            return v

    def observe(self, name: str, value: float,
                labels: Optional[Dict[str, str]] = None) -> None:
        key = (name, _labels(labels))
        with self._lock:
            h = self._hists.get(key)
            if h is None:
                h = self._hists[key] = _Hist()
            h.record(value)

    def sample(self, series: str, value: float,
               now: Optional[float] = None) -> None:
        """Append a (t, value) point to a bounded dashboard series."""
        t = now if now is not None else time.time()
        with self._lock:
            ring = self._series.get(series)
            if ring is None:
                ring = self._series[series] = collections.deque(
                    maxlen=self.RING)
            ring.append((t, float(value)))

    # --------------------------------------------------------------- reads
    def counter_value(self, name: str,
                      labels: Optional[Dict[str, str]] = None) -> float:
        with self._lock:
            return self._counters.get((name, _labels(labels)), 0.0)

    def counter_total(self, name: str) -> float:
        """Sum of a counter family across all label sets."""
        with self._lock:
            return sum(v for (n, _), v in self._counters.items()
                       if n == name)

    def gauge_value(self, name: str,
                    labels: Optional[Dict[str, str]] = None) -> float:
        with self._lock:
            return self._gauges.get((name, _labels(labels)), 0.0)

    def hist_summary(self, name: str,
                     labels: Optional[Dict[str, str]] = None) -> Dict:
        with self._lock:
            h = self._hists.get((name, _labels(labels)))
            return h.summary() if h is not None else _Hist().summary()

    def series(self, name: Optional[str] = None) -> Dict[str, List]:
        """Sparkline series for the dashboard: name -> [[t, v], ...]."""
        with self._lock:
            if name is not None:
                ring = self._series.get(name, ())
                return {name: [list(p) for p in ring]}
            return {k: [list(p) for p in ring]
                    for k, ring in self._series.items()}

    def snapshot(self) -> Dict:
        """JSON-friendly dump (dashboard ``obs`` tile + tests)."""
        with self._lock:
            counters = {f"{n}{_fmt_labels(lb)}": v
                        for (n, lb), v in sorted(self._counters.items())}
            gauges = {f"{n}{_fmt_labels(lb)}": v
                      for (n, lb), v in sorted(self._gauges.items())}
            hists = {f"{n}{_fmt_labels(lb)}": h.summary()
                     for (n, lb), h in sorted(self._hists.items())}
        return {"counters": counters, "gauges": gauges, "histograms": hists}

    # -------------------------------------------------------------- render
    def render(self) -> str:
        """Prometheus text exposition format (version 0.0.4).

        Histograms render as a ``_summary``-style family: ``_count``,
        ``_sum``, and ``{quantile="..."}`` gauge lines — scrapeable by
        any Prometheus-compatible agent without bucket-boundary
        negotiation.
        """
        with self._lock:
            counters = sorted(self._counters.items())
            gauges = sorted(self._gauges.items())
            hists = [(k, h.summary()) for k, h in sorted(self._hists.items())]
            helps = dict(self._help)
        out: List[str] = []
        seen_header = set()

        def header(name: str, mtype: str) -> None:
            if name in seen_header:
                return
            seen_header.add(name)
            htext = helps.get(name)
            if htext:
                out.append(f"# HELP {name} {htext}")
            out.append(f"# TYPE {name} {mtype}")

        for (name, lb), v in counters:
            header(name, "counter")
            out.append(f"{name}{_fmt_labels(lb)} {_num(v)}")
        for (name, lb), v in gauges:
            header(name, "gauge")
            out.append(f"{name}{_fmt_labels(lb)} {_num(v)}")
        for (name, lb), s in hists:
            header(name, "summary")
            base = _fmt_labels(lb)
            for q in ("0.5", "0.9", "0.99"):
                qkey = {"0.5": "p50", "0.9": "p90", "0.99": "p99"}[q]
                qlb = _fmt_labels(lb + (("quantile", q),))
                out.append(f"{name}{qlb} {_num(s[qkey])}")
            out.append(f"{name}_sum{base} {_num(s['sum'])}")
            out.append(f"{name}_count{base} {_num(s['count'])}")
        return "\n".join(out) + "\n"

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._hists.clear()
            self._series.clear()


def _num(v: float) -> str:
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


#: the process-global registry every subsystem reports into
REGISTRY = MetricsRegistry()
