"""EventBus → MetricsRegistry translator.

``wire_bus(bus)`` subscribes one callback that turns the control plane's
semantic event stream into time-series metrics: every published event
increments ``repro_events_total{kind}``, and the kinds that carry
latencies or capacities additionally feed histograms/gauges.  The
translator never mutates control-plane state and never raises into the
publishing thread, so wiring it changes no scheduling decision —
deterministic inline mode stays bit-identical.

Label conventions (documented in docs/architecture.md): ``user`` for
per-tenant counters, ``pod`` for pod lifecycle, ``label`` for the
compile-cache block-family, ``action``/``state``/``reason`` for
enumerated outcomes.  High-cardinality ids (app_id, session) are never
labels — they live in traces and the flight recorder instead.
"""
from __future__ import annotations

from repro.obs.metrics import REGISTRY

_DESCRIPTIONS = [
    ("repro_events_total", "Events published on the cluster bus by kind"),
    ("repro_steps_total", "Block steps recorded, by user"),
    ("repro_step_duration_seconds", "Per-step wall time reported by blocks"),
    ("repro_admission_wait_seconds",
     "Queue wait between enqueue and admission"),
    ("repro_admissions_total", "Admissions by path (immediate/queued/resume)"),
    ("repro_queue_depth", "Blocks currently waiting for admission"),
    ("repro_preemptions_total", "Blocks preempted, by user"),
    ("repro_block_state_total", "Block lifecycle transitions by state"),
    ("repro_block_failures_total", "Blocks that entered FAILED"),
    ("repro_chips_used", "Chips currently granted to running blocks"),
    ("repro_chips_total", "Chips known to the partitioner"),
    ("repro_compile_total", "Compile-cache lookups by action and family"),
    ("repro_pod_events_total", "Pod lifecycle events by action"),
    ("repro_sessions_total", "Serve session events by action"),
    ("repro_generate_tokens_total", "Tokens emitted by generate streams"),
    ("repro_migrations_total", "Cross-pod block migrations"),
    ("repro_postmortems_total", "Flight-recorder artifacts written"),
]


def wire_bus(bus, registry=None) -> None:
    """Attach the translator to ``bus``.  Idempotent per (bus, registry):
    double-wiring would double-count."""
    reg = registry if registry is not None else REGISTRY
    wired = getattr(bus, "_obs_bridge_wired", None)
    if wired is None:
        wired = bus._obs_bridge_wired = set()
    if id(reg) in wired:
        return
    wired.add(id(reg))
    for name, help_text in _DESCRIPTIONS:
        reg.describe(name, help_text)

    def on_event(ev) -> None:
        try:
            _translate(ev, reg)
        except Exception:
            pass        # metrics must never break the publishing thread

    bus.subscribe(on_event)


def _translate(ev, reg) -> None:
    p = ev.payload
    user = ev.user if ev.user is not None else "-"
    reg.inc("repro_events_total", labels={"kind": ev.kind})
    if ev.kind == "step":
        reg.inc("repro_steps_total", labels={"user": user})
        step_s = p.get("step_s")
        if step_s is not None:
            reg.observe("repro_step_duration_seconds", step_s,
                        labels={"user": user})
    elif ev.kind == "admitted":
        wait_s = p.get("wait_s")
        if wait_s is not None:
            reg.observe("repro_admission_wait_seconds", wait_s)
        path = ("immediate" if p.get("immediate")
                else "resume" if p.get("resumed") else "queued")
        reg.inc("repro_admissions_total", labels={"path": path})
    elif ev.kind == "enqueued":
        reg.add_gauge("repro_queue_depth", 1)
    elif ev.kind == "dequeued":
        reg.add_gauge("repro_queue_depth", -1)       # clamps at zero
    elif ev.kind == "preempted":
        reg.inc("repro_preemptions_total", labels={"user": user})
    elif ev.kind == "state":
        state = p.get("state")
        if state is not None:
            reg.inc("repro_block_state_total", labels={"state": state})
            if state == "failed":
                reg.inc("repro_block_failures_total")
    elif ev.kind == "utilization":
        used = p.get("used_chips")
        total = p.get("total_chips")
        if used is not None:
            reg.set_gauge("repro_chips_used", used)
            reg.sample("chips_used", used)
        if total is not None:
            reg.set_gauge("repro_chips_total", total)
    elif ev.kind == "compile":
        reg.inc("repro_compile_total",
                labels={"action": p.get("action") or "-",
                        "label": p.get("label") or "-"})
    elif ev.kind == "pod":
        reg.inc("repro_pod_events_total",
                labels={"action": p.get("action") or "-"})
    elif ev.kind == "session":
        reg.inc("repro_sessions_total",
                labels={"action": p.get("action") or "-"})
    elif ev.kind == "generate":
        # one generate event per emitted token (see engine._harvest_generate)
        reg.inc("repro_generate_tokens_total", labels={"user": user})
    elif ev.kind == "migrated":
        reg.inc("repro_migrations_total")
    elif ev.kind == "postmortem":
        reg.inc("repro_postmortems_total",
                labels={"reason": p.get("reason") or "-"})
