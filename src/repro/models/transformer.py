"""Stack assembly for every architecture family.

Every family is expressed as a repeated *group* of sublayers so the whole
stack lowers to one ``jax.lax.scan`` over stacked group params (small HLO,
remat-friendly):

  dense / encoder : group = [attn + mlp]
  moe (every=1)   : group = [attn + moe]                     (deepseek-v2, MLA)
  moe (every=2)   : group = [attn + mlp, attn + moe]         (llama4-maverick)
  xlstm           : group = [mLSTM x (k-1), sLSTM x 1]
  hybrid          : group = [mamba2 x m, shared-attn + mlp]  (zamba2; attn
                    params are weight-shared across groups -> passed as
                    non-scanned closure constants)

``group_fwd`` handles train (no cache), prefill (cache written) and decode
(S==1, cache read+written) uniformly.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models import ssm
from repro.models.config import ModelConfig
from repro.models.layers import (_he, apply_norm, attention_fwd,
                                 attention_init, mla_fwd, mla_init, mlp_fwd,
                                 mlp_init, norm_init, paged_attention_fwd)
from repro.models.moe import moe_fwd, moe_init
from repro.sharding import ctx as shard_ctx


# ---------------------------------------------------------------------------
# group structure
# ---------------------------------------------------------------------------

def group_size(cfg: ModelConfig) -> int:
    if cfg.family == "xlstm":
        return cfg.xlstm.slstm_every
    if cfg.family == "hybrid":
        return cfg.hybrid.mamba_per_group + 1
    if cfg.family == "moe" and cfg.d_ff > 0:
        return 2  # alternating dense / moe
    return 1


def n_groups(cfg: ModelConfig) -> int:
    g = group_size(cfg)
    assert cfg.n_layers % g == 0, (cfg.name, cfg.n_layers, g)
    return cfg.n_layers // g


# ---------------------------------------------------------------------------
# per-group init
# ---------------------------------------------------------------------------

def _attn_init(key, cfg: ModelConfig, dtype):
    if cfg.attention.is_mla:
        return mla_init(key, cfg.d_model, cfg.attention, dtype)
    return attention_init(key, cfg.d_model, cfg.attention, dtype)


def _dense_sublayer_init(key, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": norm_init(cfg.d_model, cfg.norm, dtype),
        "attn": _attn_init(k1, cfg, dtype),
        "ln2": norm_init(cfg.d_model, cfg.norm, dtype),
        "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.mlp_gated, dtype),
    }


def _moe_sublayer_init(key, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": norm_init(cfg.d_model, cfg.norm, dtype),
        "attn": _attn_init(k1, cfg, dtype),
        "ln2": norm_init(cfg.d_model, cfg.norm, dtype),
        "moe": moe_init(k2, cfg.d_model, cfg.moe, dtype),
    }


def group_init(key, cfg: ModelConfig, dtype):
    fam = cfg.family
    if fam in ("dense", "encoder", "vlm"):
        return _dense_sublayer_init(key, cfg, dtype)
    if fam == "moe":
        if cfg.d_ff > 0:
            k1, k2 = jax.random.split(key)
            return {"dense": _dense_sublayer_init(k1, cfg, dtype),
                    "moe": _moe_sublayer_init(k2, cfg, dtype)}
        return _moe_sublayer_init(key, cfg, dtype)
    if fam == "xlstm":
        n_m = cfg.xlstm.slstm_every - 1
        keys = jax.random.split(key, n_m + 1)
        m_params = jax.vmap(
            lambda k: {"ln": norm_init(cfg.d_model, cfg.norm, dtype),
                       "blk": ssm.mlstm_init(k, cfg.d_model, cfg.xlstm, dtype)}
        )(keys[:n_m])
        s_params = {"ln": norm_init(cfg.d_model, cfg.norm, dtype),
                    "blk": ssm.slstm_init(keys[-1], cfg.d_model, cfg.xlstm, dtype)}
        return {"mlstm": m_params, "slstm": s_params}
    if fam == "hybrid":
        n_m = cfg.hybrid.mamba_per_group
        keys = jax.random.split(key, n_m)
        m_params = jax.vmap(
            lambda k: {"ln": norm_init(cfg.d_model, cfg.norm, dtype),
                       "blk": ssm.mamba2_init(k, cfg.d_model, cfg.ssm, dtype)}
        )(keys)
        return {"mamba": m_params}
    raise ValueError(fam)


def shared_extra_init(key, cfg: ModelConfig, dtype):
    """Weight-shared sublayers applied once per group (zamba2 attention)."""
    if cfg.family == "hybrid":
        return _dense_sublayer_init(key, cfg, dtype)
    return None


# ---------------------------------------------------------------------------
# per-group forward
# ---------------------------------------------------------------------------

def _dense_sublayer_fwd(p, x, cfg, *, positions, cache, cache_len, causal=None,
                        page_table=None, seq_lens=None):
    h = apply_norm(p["ln1"], x, cfg.norm)
    # `is not None`: an all-zeros page table is a valid (trash-only) table
    if page_table is not None:
        a, new_cache = paged_attention_fwd(p["attn"], h, cfg.attention,
                                           pages=cache,
                                           page_table=page_table,
                                           seq_lens=seq_lens)
    elif cfg.attention.is_mla:
        a, new_cache = mla_fwd(p["attn"], h, cfg.attention,
                               positions=positions, cache=cache,
                               cache_len=cache_len)
    else:
        a, new_cache = attention_fwd(p["attn"], h, cfg.attention,
                                     positions=positions, cache=cache,
                                     cache_len=cache_len, causal=causal)
    x = x + a
    h = apply_norm(p["ln2"], x, cfg.norm)
    x = x + mlp_fwd(p["mlp"], h, cfg.act, cfg.mlp_gated)
    return x, new_cache


def _moe_sublayer_fwd(p, x, cfg, *, positions, cache, cache_len,
                      page_table=None, seq_lens=None):
    h = apply_norm(p["ln1"], x, cfg.norm)
    if page_table is not None:
        a, new_cache = paged_attention_fwd(p["attn"], h, cfg.attention,
                                           pages=cache,
                                           page_table=page_table,
                                           seq_lens=seq_lens)
    elif cfg.attention.is_mla:
        a, new_cache = mla_fwd(p["attn"], h, cfg.attention,
                               positions=positions, cache=cache,
                               cache_len=cache_len)
    else:
        a, new_cache = attention_fwd(p["attn"], h, cfg.attention,
                                     positions=positions, cache=cache,
                                     cache_len=cache_len)
    x = x + a
    h = apply_norm(p["ln2"], x, cfg.norm)
    m, aux = moe_fwd(p["moe"], h, cfg.moe, cfg.act)
    return x + m, aux, new_cache


def group_fwd(gp, x, cfg: ModelConfig, *, positions, cache, cache_len, extra,
              page_table=None, seq_lens=None):
    """Returns (x, aux, new_cache).  ``cache`` is this group's cache (or None)."""
    fam = cfg.family
    aux = jnp.zeros((), jnp.float32)
    if fam in ("dense", "vlm"):
        x, nc = _dense_sublayer_fwd(gp, x, cfg, positions=positions,
                                    cache=cache, cache_len=cache_len,
                                    page_table=page_table, seq_lens=seq_lens)
        return x, aux, nc
    if fam == "encoder":
        x, nc = _dense_sublayer_fwd(gp, x, cfg, positions=positions,
                                    cache=None, cache_len=None, causal=False)
        return x, aux, None
    if fam == "moe":
        if cfg.d_ff > 0:
            c_d = None if cache is None else cache["dense"]
            c_m = None if cache is None else cache["moe"]
            x, nc_d = _dense_sublayer_fwd(gp["dense"], x, cfg,
                                          positions=positions, cache=c_d,
                                          cache_len=cache_len,
                                          page_table=page_table,
                                          seq_lens=seq_lens)
            x, aux, nc_m = _moe_sublayer_fwd(gp["moe"], x, cfg,
                                             positions=positions, cache=c_m,
                                             cache_len=cache_len,
                                             page_table=page_table,
                                             seq_lens=seq_lens)
            nc = None if cache is None else {"dense": nc_d, "moe": nc_m}
            return x, aux, nc
        x, aux, nc = _moe_sublayer_fwd(gp, x, cfg, positions=positions,
                                       cache=cache, cache_len=cache_len,
                                       page_table=page_table,
                                       seq_lens=seq_lens)
        return x, aux, nc
    if fam == "xlstm":
        def m_step(x, inp):
            lp, st = inp
            h = apply_norm(lp["ln"], x, cfg.norm)
            y, new_st = ssm.mlstm_fwd(lp["blk"], h, cfg.xlstm, cfg.d_model,
                                      state=st)
            return x + y, new_st
        m_states = None if cache is None else cache["mlstm"]
        x, new_m = _scan_sublayers(m_step, x, gp["mlstm"], m_states,
                                   cfg.xlstm.slstm_every - 1)
        h = apply_norm(gp["slstm"]["ln"], x, cfg.norm)
        s_state = None if cache is None else cache["slstm"]
        y, new_s = ssm.slstm_fwd(gp["slstm"]["blk"], h, cfg.xlstm,
                                 cfg.d_model, state=s_state)
        x = x + y
        nc = None if cache is None else {"mlstm": new_m, "slstm": new_s}
        return x, aux, nc
    if fam == "hybrid":
        def m_step(x, inp):
            lp, st = inp
            h = apply_norm(lp["ln"], x, cfg.norm)
            y, new_st = ssm.mamba2_fwd(lp["blk"], h, cfg.ssm, cfg.d_model,
                                       state=st)
            return x + y, new_st
        m_states = None if cache is None else cache["mamba"]
        x, new_m = _scan_sublayers(m_step, x, gp["mamba"], m_states,
                                   cfg.hybrid.mamba_per_group)
        # weight-shared attention block (params from `extra`, cache per group)
        a_cache = None if cache is None else cache["attn"]
        x, new_a = _dense_sublayer_fwd(extra, x, cfg, positions=positions,
                                       cache=a_cache, cache_len=cache_len)
        nc = None if cache is None else {"mamba": new_m, "attn": new_a}
        return x, aux, nc
    raise ValueError(fam)


def _scan_sublayers(step, x, stacked_params, stacked_states, n: int):
    """Scan ``step`` over n stacked sublayers (params + optional states)."""
    if stacked_states is None:
        def body(c, lp):
            y, st = step(c, (lp, None))
            return y, st
        return jax.lax.scan(body, x, stacked_params)
    def body(c, inp):
        lp, st = inp
        y, new_st = step(c, (lp, st))
        return y, new_st
    return jax.lax.scan(body, x, (stacked_params, stacked_states))


# ---------------------------------------------------------------------------
# cache init (actual arrays; decode/prefill state)
# ---------------------------------------------------------------------------

def _attn_cache_init(cfg: ModelConfig, batch: int, smax: int):
    a = cfg.attention
    dt = jnp.dtype(cfg.param_dtype)
    if a.is_mla:
        return {"c_kv": jnp.zeros((batch, smax, a.kv_lora_rank), dt),
                "k_rope": jnp.zeros((batch, smax, a.qk_rope_head_dim), dt)}
    return {"k": jnp.zeros((batch, smax, a.n_kv_heads, a.head_dim), dt),
            "v": jnp.zeros((batch, smax, a.n_kv_heads, a.v_dim), dt)}


def _zeros_from_spec(spec):
    return jax.tree.map(lambda s: jnp.zeros(s[0], s[1]), spec,
                        is_leaf=lambda s: isinstance(s, tuple)
                        and len(s) == 2 and isinstance(s[0], tuple))


def group_cache_init(cfg: ModelConfig, batch: int, smax: int):
    fam = cfg.family
    if fam in ("dense", "vlm"):
        return _attn_cache_init(cfg, batch, smax)
    if fam == "encoder":
        return None
    if fam == "moe":
        c = _attn_cache_init(cfg, batch, smax)
        if cfg.d_ff > 0:
            return {"dense": _attn_cache_init(cfg, batch, smax), "moe": c}
        return c
    if fam == "xlstm":
        n_m = cfg.xlstm.slstm_every - 1
        one_m = {
            "conv": jnp.zeros((batch, 3, int(cfg.xlstm.proj_factor * cfg.d_model)),
                              jnp.bfloat16),
            "mlstm": _mlstm_zero_carry(cfg, batch),
        }
        m = jax.tree.map(lambda t: jnp.broadcast_to(t, (n_m,) + t.shape), one_m)
        H = cfg.xlstm.n_heads
        Dh = cfg.d_model // H
        s = {"slstm": (jnp.zeros((batch, H, Dh), jnp.float32),
                       jnp.zeros((batch, H, Dh), jnp.float32),
                       jnp.ones((batch, H, Dh), jnp.float32),
                       jnp.zeros((batch, H, Dh), jnp.float32))}
        return {"mlstm": m, "slstm": s}
    if fam == "hybrid":
        n_m = cfg.hybrid.mamba_per_group
        spec = ssm.mamba2_state_spec(cfg.ssm, cfg.d_model, batch)
        one = _zeros_from_spec(spec)
        m = jax.tree.map(lambda t: jnp.broadcast_to(t, (n_m,) + t.shape), one)
        return {"mamba": m, "attn": _attn_cache_init(cfg, batch, smax)}
    raise ValueError(fam)


def _mlstm_zero_carry(cfg: ModelConfig, batch: int):
    inner, Dk, Dv, H = ssm._mlstm_dims(cfg.d_model, cfg.xlstm)
    return (jnp.zeros((batch, H, Dk, Dv), jnp.float32),
            jnp.zeros((batch, H, Dk), jnp.float32),
            jnp.full((batch, H), -jnp.inf, jnp.float32))


def init_cache(cfg: ModelConfig, batch: int, smax: int):
    """Stacked (n_groups, ...) cache pytree."""
    one = group_cache_init(cfg, batch, smax)
    if one is None:
        return None
    ng = n_groups(cfg)
    return jax.tree.map(lambda t: jnp.broadcast_to(t[None], (ng,) + t.shape)
                        .astype(t.dtype), one)


# ---------------------------------------------------------------------------
# paged cache (continuous-batching serve)
# ---------------------------------------------------------------------------

def check_paged_support(cfg: ModelConfig) -> None:
    """Paged decode covers the plain-GQA attention families; recurrent
    states (xlstm/hybrid) and MLA's compressed cache page differently and
    stay on the dense path."""
    if cfg.family not in ("dense", "vlm", "moe") or cfg.attention is None:
        raise ValueError(
            f"paged decode unsupported for family {cfg.family!r}")
    if cfg.attention.is_mla:
        raise ValueError("paged decode does not support MLA caches")
    if cfg.attention.sliding_window > 0:
        raise ValueError("paged decode does not support sliding windows")


def _paged_group_cache_init(cfg: ModelConfig, n_pages: int, page_size: int):
    a = cfg.attention
    dt = jnp.dtype(cfg.param_dtype)

    def one():
        return {"k": jnp.zeros((n_pages, page_size, a.n_kv_heads,
                                a.head_dim), dt),
                "v": jnp.zeros((n_pages, page_size, a.n_kv_heads,
                                a.v_dim), dt)}

    if cfg.family == "moe" and cfg.d_ff > 0:
        return {"dense": one(), "moe": one()}
    return one()


def init_paged_cache(cfg: ModelConfig, n_pages: int, page_size: int):
    """Stacked (n_groups, ...) page-pool pytree shared by all live slots.
    Page 0 is reserved as the trash page (never allocated to a session):
    inactive slots' table rows point at it so their scatter writes and
    gathered garbage stay masked out."""
    check_paged_support(cfg)
    one = _paged_group_cache_init(cfg, n_pages, page_size)
    ng = n_groups(cfg)
    return jax.tree.map(lambda t: jnp.broadcast_to(t[None], (ng,) + t.shape)
                        .astype(t.dtype), one)


# ---------------------------------------------------------------------------
# full-stack params + forward
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    dtype = jnp.dtype(cfg.param_dtype)
    k_emb, k_layers, k_extra, k_head, k_mask = jax.random.split(key, 5)
    ng = n_groups(cfg)
    layer_keys = jax.random.split(k_layers, ng)
    layers = jax.vmap(lambda k: group_init(k, cfg, dtype))(layer_keys)
    params: Dict[str, Any] = {"layers": layers}
    if cfg.frontend == "frame":
        params["frame_proj"] = _he(k_emb, (cfg.frontend_dim, cfg.d_model), dtype)
        params["mask_embed"] = (jax.random.normal(k_mask, (cfg.d_model,),
                                                  jnp.float32) * 0.02).astype(dtype)
    else:
        params["embed"] = (jax.random.normal(
            k_emb, (cfg.vocab_size, cfg.d_model), jnp.float32) * 0.02
        ).astype(dtype)
    if cfg.frontend == "patch":
        params["patch_proj"] = _he(k_extra, (cfg.frontend_dim, cfg.d_model), dtype)
    extra = shared_extra_init(k_extra, cfg, dtype)
    if extra is not None:
        params["extra"] = extra
    params["final_norm"] = norm_init(cfg.d_model, cfg.norm, dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = _he(k_head, (cfg.d_model, cfg.vocab_size), dtype)
    return params


def embed_inputs(params, cfg: ModelConfig, batch: Dict[str, Any]):
    """Build the (B, S, d) input activations from the batch dict."""
    if cfg.frontend == "frame":
        x = batch["frames"].astype(jnp.bfloat16) @ params["frame_proj"]
        if "mask" in batch:
            x = jnp.where(batch["mask"][..., None],
                          params["mask_embed"][None, None], x)
        return x
    tok = params["embed"][batch["tokens"]]
    if cfg.frontend == "patch" and "patches" in batch:
        patches = batch["patches"].astype(jnp.bfloat16) @ params["patch_proj"]
        tok = jnp.concatenate([patches, tok], axis=1)
    return shard_ctx.constrain_tokens_3d(tok)


def forward(params, cfg: ModelConfig, x, *, positions, cache=None,
            cache_len=None, page_table=None, seq_lens=None):
    """Run the stack on embedded inputs x: (B, S, d).

    With ``page_table``/``seq_lens`` set, ``cache`` is the stacked paged
    pool from ``init_paged_cache`` and decode runs the paged-attention path
    (the table and lengths are shared across groups; each group scans its
    own pool slice).  Returns (logits (B, S, V), aux_loss, new_cache).
    """
    extra = params.get("extra")

    def body(carry, inp):
        x, aux = carry
        if cache is None:
            gp, gc = inp, None
        else:
            gp, gc = inp
        x, a, nc = group_fwd(gp, x, cfg, positions=positions, cache=gc,
                             cache_len=cache_len, extra=extra,
                             page_table=page_table, seq_lens=seq_lens)
        return (x, aux + a), nc

    if cfg.remat != "none":
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if cfg.remat == "dots" else None)
        body = jax.checkpoint(body, policy=policy, prevent_cse=False)

    xs = params["layers"] if cache is None else (params["layers"], cache)
    (x, aux), new_cache = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs)
    x = apply_norm(params["final_norm"], x, cfg.norm)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = x @ head
    if cfg.logits_softcap > 0:
        logits = jnp.tanh(logits / cfg.logits_softcap) * cfg.logits_softcap
    logits = shard_ctx.constrain_logits(logits)
    return logits, aux, (None if cache is None else new_cache)
