"""Shared neural-net layers: norms, RoPE, GQA / MLA attention, MLPs.

Parameters are plain nested dicts.  ``*_init(key, cfg, ...)`` builds one
layer's params; stacks vmap these over layer keys to produce scanned (L, ...)
pytrees.  All matmuls run in the param dtype with fp32 softmax/norm accum.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.models.config import AttentionConfig, ModelConfig


def _he(key, shape, dtype, fan_in=None):
    fan_in = fan_in if fan_in is not None else shape[0]
    return (jax.random.normal(key, shape, jnp.float32)
            * (1.0 / np.sqrt(fan_in))).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def norm_init(d: int, kind: str, dtype):
    if kind == "layer":
        return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype)}


def apply_norm(p, x, kind: str, eps: float = 1e-6):
    if kind == "layer":
        xf = x.astype(jnp.float32)
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        return (y * p["scale"].astype(jnp.float32)
                + p["bias"].astype(jnp.float32)).astype(x.dtype)
    return ops.rmsnorm(x, p["scale"], eps=eps)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x, positions, theta: float):
    """x: (..., S, D) with D even; positions: (S,) or (B, S)."""
    D = x.shape[-1]
    inv = rope_freqs(D, theta)                       # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    # broadcast (S, D/2) or (B, S, D/2) against (..., S, D/2)
    while cos.ndim < x.ndim:
        cos, sin = cos[..., None, :, :], sin[..., None, :, :]
    x1, x2 = x[..., ::2].astype(jnp.float32), x[..., 1::2].astype(jnp.float32)
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    return jnp.stack([r1, r2], axis=-1).reshape(x.shape).astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------

def attention_init(key, d_model: int, a: AttentionConfig, dtype):
    ks = jax.random.split(key, 4)
    vd = a.v_dim
    return {
        "wq": _he(ks[0], (d_model, a.n_heads * a.head_dim), dtype),
        "wk": _he(ks[1], (d_model, a.n_kv_heads * a.head_dim), dtype),
        "wv": _he(ks[2], (d_model, a.n_kv_heads * vd), dtype),
        "wo": _he(ks[3], (a.n_heads * vd, d_model), dtype,
                  fan_in=a.n_heads * vd),
    }


def attention_fwd(p, x, a: AttentionConfig, *, positions, cache=None,
                  cache_len=None, causal=None):
    """x: (B, S, d).  cache: dict(k,v: (B, Smax, Hkv, D)) updated in decode.

    Returns (out, new_cache).  In prefill mode (cache given, S>1) the K/V are
    written at positions [0, S); in decode (S==1) at position cache_len.
    """
    B, S, _ = x.shape
    H, Hkv, D, vd = a.n_heads, a.n_kv_heads, a.head_dim, a.v_dim
    causal = a.causal if causal is None else causal
    q = (x @ p["wq"]).reshape(B, S, H, D)
    k = (x @ p["wk"]).reshape(B, S, Hkv, D)
    v = (x @ p["wv"]).reshape(B, S, Hkv, vd)
    q = apply_rope(q.swapaxes(1, 2), positions, a.rope_theta)   # (B,H,S,D)
    k = apply_rope(k.swapaxes(1, 2), positions, a.rope_theta)   # (B,Hkv,S,D)
    v = v.swapaxes(1, 2)

    if cache is None:
        o = ops.flash_attention(q, k, v, causal=causal,
                                sliding_window=a.sliding_window)
        new_cache = None
    elif S == 1:  # decode
        idx = cache_len  # scalar int32
        k_cache = jax.lax.dynamic_update_slice(
            cache["k"], k.swapaxes(1, 2).astype(cache["k"].dtype),
            (0, idx, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            cache["v"], v.swapaxes(1, 2).astype(cache["v"].dtype),
            (0, idx, 0, 0))
        o = ops.decode_attention(
            q, k_cache.swapaxes(1, 2), v_cache.swapaxes(1, 2), cache_len + 1,
            sliding_window=a.sliding_window)
        new_cache = {"k": k_cache, "v": v_cache}
    else:  # prefill into cache
        o = ops.flash_attention(q, k, v, causal=causal,
                                sliding_window=a.sliding_window)
        Smax = cache["k"].shape[1]
        kp = jnp.pad(k.swapaxes(1, 2), ((0, 0), (0, Smax - S), (0, 0), (0, 0)))
        vp = jnp.pad(v.swapaxes(1, 2), ((0, 0), (0, Smax - S), (0, 0), (0, 0)))
        new_cache = {"k": kp.astype(cache["k"].dtype),
                     "v": vp.astype(cache["v"].dtype)}
    o = o.swapaxes(1, 2).reshape(B, S, H * vd)
    return o @ p["wo"], new_cache


def paged_attention_fwd(p, x, a: AttentionConfig, *, pages, page_table,
                        seq_lens):
    """Decode one token per slot against a paged KV pool (continuous
    batching).  x: (B, 1, d); pages: dict(k/v: (n_pages, page, Hkv, D|Dv));
    page_table: (B, maxp) int32; seq_lens: (B,) int32 — tokens already
    cached per slot.  The new token's K/V is written at position
    ``seq_lens[b]`` (its page must already be allocated in the table), then
    the slot attends over ``seq_lens + 1`` entries — the exact analogue of
    the dense decode branch in ``attention_fwd``, with per-slot positions
    instead of one scalar ``cache_len``.  Returns (out, new_pages)."""
    B, S, _ = x.shape
    H, Hkv, D, vd = a.n_heads, a.n_kv_heads, a.head_dim, a.v_dim
    q = (x @ p["wq"]).reshape(B, S, H, D)
    k = (x @ p["wk"]).reshape(B, S, Hkv, D)
    v = (x @ p["wv"]).reshape(B, S, Hkv, vd)
    positions = seq_lens[:, None]                            # (B, 1) absolute
    q = apply_rope(q.swapaxes(1, 2), positions, a.rope_theta)  # (B,H,1,D)
    k = apply_rope(k.swapaxes(1, 2), positions, a.rope_theta)  # (B,Hkv,1,D)
    v = v.swapaxes(1, 2)

    page = pages["k"].shape[1]
    # flat pool row of each slot's write position; inactive slots (their
    # table rows all point at the reserved trash page 0) scatter harmlessly
    row = page_table[jnp.arange(B), seq_lens // page] * page + seq_lens % page
    k_pool = pages["k"].reshape(-1, Hkv, D).at[row].set(
        k[:, :, 0].astype(pages["k"].dtype)).reshape(pages["k"].shape)
    v_pool = pages["v"].reshape(-1, Hkv, vd).at[row].set(
        v[:, :, 0].astype(pages["v"].dtype)).reshape(pages["v"].shape)
    o = ops.paged_attention(q, k_pool, v_pool, page_table, seq_lens + 1)
    o = o.swapaxes(1, 2).reshape(B, S, H * vd)
    return o @ p["wo"], {"k": k_pool, "v": v_pool}


def attention_cache_spec(a: AttentionConfig, batch: int, smax: int, dtype):
    return {"k": (batch, smax, a.n_kv_heads, a.head_dim),
            "v": (batch, smax, a.n_kv_heads, a.v_dim)}


# ---------------------------------------------------------------------------
# Multi-head Latent Attention (DeepSeek-V2)
# ---------------------------------------------------------------------------

def mla_init(key, d_model: int, a: AttentionConfig, dtype):
    ks = jax.random.split(key, 6)
    H, Dn, Dr, Dv = a.n_heads, a.head_dim, a.qk_rope_head_dim, a.v_dim
    return {
        "wq_a": _he(ks[0], (d_model, a.q_lora_rank), dtype),
        "q_norm": jnp.ones((a.q_lora_rank,), dtype),
        "wq_b": _he(ks[1], (a.q_lora_rank, H * (Dn + Dr)), dtype),
        "wkv_a": _he(ks[2], (d_model, a.kv_lora_rank + Dr), dtype),
        "kv_norm": jnp.ones((a.kv_lora_rank,), dtype),
        "wk_b": _he(ks[3], (a.kv_lora_rank, H * Dn), dtype),
        "wv_b": _he(ks[4], (a.kv_lora_rank, H * Dv), dtype),
        "wo": _he(ks[5], (H * Dv, d_model), dtype, fan_in=H * Dv),
    }


def mla_fwd(p, x, a: AttentionConfig, *, positions, cache=None, cache_len=None):
    """MLA forward.  cache: dict(c_kv: (B,Smax,R), k_rope: (B,Smax,Dr))."""
    B, S, _ = x.shape
    H, Dn, Dr, Dv, R = (a.n_heads, a.head_dim, a.qk_rope_head_dim,
                        a.v_dim, a.kv_lora_rank)
    scale = 1.0 / np.sqrt(Dn + Dr)
    cq = ops.rmsnorm(x @ p["wq_a"], p["q_norm"])
    q = (cq @ p["wq_b"]).reshape(B, S, H, Dn + Dr)
    q_nope, q_rope = q[..., :Dn], q[..., Dn:]
    q_rope = apply_rope(q_rope.swapaxes(1, 2), positions, a.rope_theta)  # (B,H,S,Dr)

    kv_a = x @ p["wkv_a"]
    c_kv = ops.rmsnorm(kv_a[..., :R], p["kv_norm"])          # (B,S,R)
    k_rope = apply_rope(kv_a[..., None, R:].swapaxes(1, 2),
                        positions, a.rope_theta)              # (B,1,S,Dr)

    if cache is not None and S == 1:
        # ---- absorbed decode: score against the compressed cache ----
        idx = cache_len
        c_cache = jax.lax.dynamic_update_slice(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, idx, 0))
        r_cache = jax.lax.dynamic_update_slice(
            cache["k_rope"], k_rope[:, 0].astype(cache["k_rope"].dtype),
            (0, idx, 0))
        wk_b = p["wk_b"].reshape(R, H, Dn)
        q_abs = jnp.einsum("bshd,rhd->bhsr", q_nope, wk_b)   # (B,H,1,R)
        s = (jnp.einsum("bhsr,btr->bhst", q_abs.astype(jnp.float32),
                        c_cache.astype(jnp.float32))
             + jnp.einsum("bhsd,btd->bhst", q_rope.astype(jnp.float32),
                          r_cache.astype(jnp.float32))) * scale
        pos = jnp.arange(c_cache.shape[1])
        s = jnp.where((pos < cache_len + 1)[None, None, None], s, ops.NEG_INF)
        w = jax.nn.softmax(s, axis=-1)
        o_c = jnp.einsum("bhst,btr->bhsr", w, c_cache.astype(jnp.float32))
        wv_b = p["wv_b"].reshape(R, H, Dv)
        o = jnp.einsum("bhsr,rhd->bshd", o_c.astype(x.dtype), wv_b)
        new_cache = {"c_kv": c_cache, "k_rope": r_cache}
    else:
        # ---- train / prefill: materialize per-head K, V ----
        k_nope = (c_kv @ p["wk_b"]).reshape(B, S, H, Dn).swapaxes(1, 2)
        v = (c_kv @ p["wv_b"]).reshape(B, S, H, Dv).swapaxes(1, 2)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, (B, H, S, Dr))], axis=-1)
        qq = jnp.concatenate([q_nope.swapaxes(1, 2), q_rope], axis=-1)
        o = ops.flash_attention(qq, k, v, causal=True, scale=scale)
        o = o.swapaxes(1, 2)
        if cache is not None:
            Smax = cache["c_kv"].shape[1]
            new_cache = {
                "c_kv": jnp.pad(c_kv, ((0, 0), (0, Smax - S), (0, 0))
                                ).astype(cache["c_kv"].dtype),
                "k_rope": jnp.pad(k_rope[:, 0], ((0, 0), (0, Smax - S), (0, 0))
                                  ).astype(cache["k_rope"].dtype),
            }
        else:
            new_cache = None
    out = o.reshape(B, S, H * Dv) @ p["wo"]
    return out, new_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, gated: bool, dtype):
    ks = jax.random.split(key, 3)
    p = {"w_up": _he(ks[0], (d_model, d_ff), dtype),
         "w_down": _he(ks[1], (d_ff, d_model), dtype, fan_in=d_ff)}
    if gated:
        p["w_gate"] = _he(ks[2], (d_model, d_ff), dtype)
    return p


def mlp_fwd(p, x, act: str, gated: bool):
    h = x @ p["w_up"]
    if gated:
        g = x @ p["w_gate"]
        g = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)
        h = g * h
    else:
        h = jax.nn.gelu(h) if act == "gelu" else jax.nn.silu(h)
    return h @ p["w_down"]
