"""Model configuration dataclasses for every assigned architecture family.

A ``ModelConfig`` fully determines parameter shapes, the forward pass, and the
cache layout.  Configs are plain frozen dataclasses so they hash/compare and can
be embedded in jit static args.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class AttentionConfig:
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_theta: float = 10_000.0
    causal: bool = True
    # Sliding-window attention (0 = full).  Used to bound hybrid long-context.
    sliding_window: int = 0
    # --- Multi-head Latent Attention (DeepSeek-V2) ---
    q_lora_rank: int = 0          # 0 => dense q projection
    kv_lora_rank: int = 0         # 0 => standard GQA KV
    qk_rope_head_dim: int = 0     # decoupled RoPE dims (MLA only)
    v_head_dim: int = 0           # defaults to head_dim when 0

    @property
    def is_mla(self) -> bool:
        return self.kv_lora_rank > 0

    @property
    def v_dim(self) -> int:
        return self.v_head_dim or self.head_dim


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    d_ff_shared: int = 0          # defaults to d_ff_expert when 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.001

    @property
    def shared_ff(self) -> int:
        return self.d_ff_shared or self.d_ff_expert


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (state-space dual) block config."""
    state_dim: int = 64
    head_dim: int = 64
    expand: int = 2
    chunk: int = 256
    conv_width: int = 4


@dataclass(frozen=True)
class XLSTMConfig:
    n_heads: int = 4
    proj_factor: float = 2.0      # mLSTM inner = proj_factor * d_model
    qk_factor: float = 0.5        # qk dim = qk_factor * inner
    slstm_every: int = 8          # 1 sLSTM per this many layers (rest mLSTM)
    chunk: int = 256


@dataclass(frozen=True)
class HybridConfig:
    """Zamba2-style: runs of Mamba2 blocks + one weight-SHARED attention block."""
    mamba_per_group: int = 5      # 5 mamba + 1 shared-attn application per group
    # shared attention block params are applied (n_layers // (mamba_per_group+1)) times


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | xlstm | hybrid | encoder | vlm
    n_layers: int
    d_model: int
    vocab_size: int
    d_ff: int                     # dense-family MLP width (0 => no MLP, e.g. xlstm)
    attention: Optional[AttentionConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    hybrid: Optional[HybridConfig] = None
    norm: str = "rms"             # rms | layer
    act: str = "silu"             # silu (gated) | gelu (plain)
    mlp_gated: bool = True
    tie_embeddings: bool = False
    # Modality frontend stub: "none" | "patch" (vlm) | "frame" (audio)
    frontend: str = "none"
    frontend_dim: int = 0         # embedding dim delivered by the stub (== d_model)
    # encoder-only models have no causal mask / no decode
    is_encoder: bool = False
    # remat policy for the layer scan: "full" | "dots" | "none"
    remat: str = "full"
    logits_softcap: float = 0.0
    param_dtype: str = "bfloat16"    # bfloat16 | float32

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Total parameter count (exact, mirrors init shapes)."""
        from repro.models import model as _m
        return _m.count_params(_m.abstract_params(self))

    def active_param_count(self) -> int:
        """Params touched per token (MoE: shared + top_k routed)."""
        from repro.models import model as _m
        return _m.count_active_params(self)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str                     # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                     # train | prefill | decode
    seq_len: int
    global_batch: int
    # decode shapes: cache holds `seq_len` tokens, one new token generated
    microbatch: int = 1           # grad-accumulation steps (train only)

    def replace(self, **kw) -> "ShapeConfig":
        return dataclasses.replace(self, **kw)


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", "train", seq_len=4_096, global_batch=256, microbatch=8),
    ShapeConfig("prefill_32k", "prefill", seq_len=32_768, global_batch=32),
    ShapeConfig("decode_32k", "decode", seq_len=32_768, global_batch=128),
    ShapeConfig("long_500k", "decode", seq_len=524_288, global_batch=1),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}
