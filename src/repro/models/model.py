"""Model facade: the public API the runtime layers (train/serve) consume.

  init_params(cfg, key)                  -> params pytree
  abstract_params(cfg)                   -> ShapeDtypeStruct pytree (no alloc)
  loss_fn(params, cfg, batch)            -> (loss, metrics)
  prefill(params, cfg, batch, cache)     -> (logits_last, filled_cache)
  decode_step(params, cfg, token, cache, cache_len) -> (logits, new_cache)
  init_cache(cfg, batch, smax)           -> cache pytree
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer
from repro.models.config import ModelConfig
from repro.models.transformer import (embed_inputs, forward,  # re-export
                                      init_cache, init_paged_cache)

init_params = transformer.init_params
check_paged_support = transformer.check_paged_support


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(
        lambda k: transformer.init_params(cfg, k),
        jax.ShapeDtypeStruct((2,), jnp.uint32))


def count_params(tree) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(tree))


def count_active_params(cfg: ModelConfig) -> int:
    """Params touched per token: full count minus inactive routed experts."""
    total = count_params(abstract_params(cfg))
    if cfg.moe is None:
        return total
    m = cfg.moe
    per_expert = 3 * cfg.d_model * m.d_ff_expert
    n_moe_layers = transformer.n_groups(cfg)   # one moe sublayer per group
    inactive = n_moe_layers * (m.n_experts - m.top_k) * per_expert
    return total - inactive


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def _xent(logits, labels, mask):
    """Cross-entropy in fp32 with a validity mask.  logits: (B,S,V)."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = (lse - gold) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    return nll.sum() / denom


def loss_fn(params, cfg: ModelConfig, batch: Dict[str, Any]
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    x = embed_inputs(params, cfg, batch)
    B, S = x.shape[0], x.shape[1]
    positions = jnp.arange(S)
    logits, aux, _ = forward(params, cfg, x, positions=positions)

    if cfg.frontend == "frame":
        # masked-prediction (HuBERT-style): loss only on masked frames
        labels = batch["labels"]
        mask = batch["mask"].astype(jnp.float32)
        loss = _xent(logits, labels, mask)
    elif cfg.frontend == "patch":
        # next-token on the text segment only (patches occupy the prefix)
        n_p = batch["patches"].shape[1]
        labels = batch["labels"]                       # (B, S_text)
        text_logits = logits[:, n_p:]
        loss = _next_token_loss(text_logits, labels)
    else:
        loss = _next_token_loss(logits, batch["labels"])
    total = loss + aux
    return total, {"loss": loss, "aux_loss": aux}


def _next_token_loss(logits, labels):
    """Standard causal LM loss: logits[t] predicts labels[t]."""
    mask = jnp.ones(labels.shape, jnp.float32)
    return _xent(logits, labels, mask)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def prefill(params, cfg: ModelConfig, batch: Dict[str, Any], cache):
    """Run the prompt through the stack, filling ``cache``.

    Returns (logits_last (B, V), cache)."""
    x = embed_inputs(params, cfg, batch)
    S = x.shape[1]
    positions = jnp.arange(S)
    logits, _, new_cache = forward(params, cfg, x, positions=positions,
                                   cache=cache, cache_len=jnp.int32(0))
    return logits[:, -1], new_cache


def decode_step(params, cfg: ModelConfig, token, cache, cache_len):
    """One autoregressive step.  token: (B, 1) int32; cache_len: scalar int32.

    Returns (logits (B, V), new_cache)."""
    x = embed_inputs(params, cfg, {"tokens": token})
    positions = cache_len + jnp.arange(1)
    logits, _, new_cache = forward(params, cfg, x, positions=positions,
                                   cache=cache, cache_len=cache_len)
    return logits[:, -1], new_cache


# ---------------------------------------------------------------------------
# paged serving (continuous batching)
# ---------------------------------------------------------------------------

def decode_step_paged(params, cfg: ModelConfig, token, cache, page_table,
                      seq_lens):
    """One decode step for every slot of a continuous batch.

    token: (B, 1) int32 — each slot's last token (garbage for idle slots);
    cache: stacked paged pool from ``init_paged_cache``;
    page_table: (B, maxp) int32; seq_lens: (B,) int32 per-slot cache fill
    (idle slots: 0 with a trash-page table row).
    Returns (logits (B, V), new_cache)."""
    x = embed_inputs(params, cfg, {"tokens": token})
    positions = seq_lens[:, None]
    logits, _, new_cache = forward(params, cfg, x, positions=positions,
                                   cache=cache, cache_len=None,
                                   page_table=page_table, seq_lens=seq_lens)
    return logits[:, -1], new_cache


def write_prefill_to_pages(pool, dense_cache, page_ids, page_size: int):
    """Scatter a freshly prefilled dense cache (batch=1, smax a multiple of
    ``page_size``) into the paged pool at the allocated ``page_ids``.

    Leaf shapes: dense (ng, 1, smax, Hkv, D) -> pool (ng, n_pages, page,
    Hkv, D).  The dense prefill wrote positions [0, plen); trailing rows of
    the last page carry the dense cache's zero padding, overwritten in
    place on later decode steps.  Bit-preserving: page row ``p`` receives
    exactly dense row ``p``."""
    ids = jnp.asarray(page_ids, jnp.int32)
    npg = ids.shape[0]

    def put(p, d):
        ng, _, smax = d.shape[:3]
        assert smax == npg * page_size, (smax, npg, page_size)
        src = d[:, 0].reshape((ng, npg, page_size) + d.shape[3:])
        return p.at[:, ids].set(src.astype(p.dtype))

    return jax.tree.map(put, pool, dense_cache)
