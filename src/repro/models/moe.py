"""Mixture-of-Experts layer: top-k token-choice routing with per-data-shard
scatter/gather dispatch (production EP layout).

Routing and capacity are computed *per data shard* (leading DP dim), so
position-within-expert is a local cumsum — no cross-device sequential
dependency, unlike a global-T dispatch.  Tokens are scattered into
(DP, E, C, d) expert buffers (rows, no one-hot einsums: dispatch costs ~zero
flops); the reshard of those buffers from dp-sharded to expert(model)-sharded
is exactly the EP all-to-all.  The N shared experts are fused into one wide
MLP (concatenated ffs sum after the down-projection).

Token-choice semantics match the papers (per-token top-k); capacity/overflow
is per-shard, as deployed systems do.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import MoEConfig
from repro.models.layers import _he, mlp_fwd, mlp_init
from repro.sharding import ctx as shard_ctx


def moe_init(key, d_model: int, cfg: MoEConfig, dtype):
    ks = jax.random.split(key, 5)
    E, F = cfg.n_experts, cfg.d_ff_expert
    p = {
        "router": _he(ks[0], (d_model, E), jnp.float32),
        "w_gate": _he(ks[1], (E, d_model, F), dtype, fan_in=d_model),
        "w_up": _he(ks[2], (E, d_model, F), dtype, fan_in=d_model),
        "w_down": _he(ks[3], (E, F, d_model), dtype, fan_in=F),
    }
    if cfg.n_shared > 0:
        p["shared"] = mlp_init(ks[4], d_model, cfg.n_shared * cfg.shared_ff,
                               gated=True, dtype=dtype)
    return p


def moe_fwd(p, x, cfg: MoEConfig, act: str = "silu"):
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar)."""
    B, S, d = x.shape
    T = B * S
    E, K = cfg.n_experts, cfg.top_k
    DP = shard_ctx.dp_size()
    if T % DP != 0:
        DP = 1
    Tl = T // DP

    xs = x.reshape(DP, Tl, d)
    logits = (xs.astype(jnp.float32) @ p["router"])          # (DP, Tl, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, K)                 # (DP, Tl, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # per-shard capacity (static)
    C = max(1, int(np.ceil(Tl * K / E * cfg.capacity_factor)))

    # position of each (token, k) within its expert — local cumsum per shard,
    # k-major priority (k=0 choices claim slots first)
    pos_k = []
    counts = jnp.zeros((DP, 1, E), jnp.int32)
    for j in range(K):
        oh = jax.nn.one_hot(idx[:, :, j], E, dtype=jnp.int32)   # (DP, Tl, E)
        pos_all = jnp.cumsum(oh, axis=1) - 1 + counts           # (DP, Tl, E)
        pos_k.append(jnp.take_along_axis(
            pos_all, idx[:, :, j:j + 1], axis=-1)[..., 0])      # (DP, Tl)
        counts = counts + oh.sum(axis=1, keepdims=True)

    # stack (token,k) choices: slot ids within the per-shard expert buffer
    OVERFLOW = E * C
    slot_k, weight_k = [], []
    for j in range(K):
        pos = pos_k[j]
        valid = pos < C
        slot_k.append(jnp.where(valid, idx[:, :, j] * C + pos, OVERFLOW))
        weight_k.append((gate_vals[:, :, j] * valid).astype(x.dtype))
    slots = jnp.stack(slot_k)                                # (K, DP, Tl)
    weights = jnp.stack(weight_k)                            # (K, DP, Tl)

    # scatter tokens into per-shard expert buffers (DP, E*C, d).  The SPMD
    # scatter partitioner cannot prove the batch-dim locality of this
    # scatter and falls back to replicate+all-reduce of the full buffer
    # (measured 3 TB/device/step fwd + 8.5 TB in bwd on deepseek-v2), so the
    # dispatch/combine run under shard_map: manual over dp, auto elsewhere.
    xs = shard_ctx.constrain_moe_shards(xs)
    buf = _shardmapped(_scatter_local, (xs, slots), E=E, C=C)
    ebuf = buf.reshape(DP, E, C, d)
    ebuf = shard_ctx.constrain_expert_buffers(ebuf)             # EP all-to-all
    g = jnp.einsum("secd,edf->secf", ebuf, p["w_gate"])
    u = jnp.einsum("secd,edf->secf", ebuf, p["w_up"])
    g = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)
    h = jnp.einsum("secf,efd->secd", g * u, p["w_down"])
    h = shard_ctx.constrain_expert_buffers(h)
    hflat = shard_ctx.constrain_moe_shards(h.reshape(DP, E * C, d))  # to dp
    out = _shardmapped(_combine_local, (hflat, slots, weights), E=E, C=C)

    if cfg.n_shared > 0:
        out = out + mlp_fwd(p["shared"], xs, act, gated=True)

    # load-balancing auxiliary loss (Switch-style)
    frac_tokens = jnp.mean(
        jax.nn.one_hot(idx[..., 0], E, dtype=jnp.float32), axis=(0, 1))
    frac_probs = probs.mean((0, 1))
    aux = cfg.router_aux_coef * E * jnp.sum(frac_tokens * frac_probs)
    return out.reshape(B, S, d), aux


def _scatter_local(xs, slots, *, E, C):
    """Per-shard dispatch.  xs: (DPl, Tl, d); slots: (K, DPl, Tl) with
    overflow id E*C (out of bounds -> mode='drop' discards it without the
    concat+slice round-trip of an explicit overflow row)."""
    DPl, Tl, d = xs.shape
    K = slots.shape[0]
    buf = jnp.zeros((DPl, E * C, d), xs.dtype)
    for j in range(K):
        buf = buf.at[jnp.arange(DPl)[:, None], slots[j]].add(xs, mode="drop")
    return buf


def _combine_local(hflat, slots, weights, *, E, C):
    """Per-shard combine.  hflat: (DPl, E*C, d).  Returns (DPl, Tl, d)."""
    K = slots.shape[0]
    out = None
    for j in range(K):
        safe = jnp.minimum(slots[j], E * C - 1)[..., None]
        rows = jnp.take_along_axis(hflat, safe, axis=1, mode="clip")
        contrib = rows * weights[j][..., None]
        out = contrib if out is None else out + contrib
    return out


def _shardmapped(fn, args, **kw):
    """Run ``fn`` with the leading dp dim manual (shard_map) when a sharding
    context is installed; direct call otherwise (single-device tests)."""
    import functools
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map
    ctx = shard_ctx.current()
    if ctx is None:
        return fn(*args, **kw)
    dp = ctx.dp if len(ctx.dp) > 1 else ctx.dp[0]
    # arg 0 carries the dp dim leading (DP, ...); the rest are (K, DP, ...)
    in_specs = tuple(
        P(dp, *([None] * (a.ndim - 1))) if i == 0
        else P(None, dp, *([None] * (a.ndim - 2)))
        for i, a in enumerate(args))
    f = shard_map(functools.partial(fn, **kw), mesh=ctx.mesh,
                  in_specs=in_specs, out_specs=P(dp, None, None),
                  axis_names=set(ctx.dp))
    return f(*args)
