"""Recurrent sequence-mixing blocks: Mamba2 (SSD), mLSTM and sLSTM (xLSTM).

Each block exposes:
  *_init(key, d_model, cfg, dtype)          -> params
  *_fwd(p, x, cfg, *, state=None)           -> (y, new_state)
  *_state_spec(cfg, d_model, batch)         -> pytree of (shape, dtype)

``state=None`` means full-sequence (train/prefill) mode starting from zeros;
passing a state runs from it and returns the updated one (decode passes S=1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.models.config import SSMConfig, XLSTMConfig
from repro.models.layers import _he


# ---------------------------------------------------------------------------
# causal depthwise conv (width W) with cached tail for decode
# ---------------------------------------------------------------------------

def causal_conv(x, w, tail=None):
    """x: (B, S, C); w: (W, C); tail: (B, W-1, C) previous inputs or None.

    Returns (y, new_tail).  y[t] = sum_i w[i] * x_ext[t + i] where x_ext is
    x left-padded with the tail (or zeros).
    """
    W = w.shape[0]
    B, S, C = x.shape
    if tail is None:
        tail = jnp.zeros((B, W - 1, C), x.dtype)
    ext = jnp.concatenate([tail.astype(x.dtype), x], axis=1)   # (B, S+W-1, C)
    y = sum(ext[:, i:i + S] * w[i].astype(x.dtype) for i in range(W))
    new_tail = ext[:, -(W - 1):] if W > 1 else tail
    return y, new_tail


# ===========================================================================
# Mamba2
# ===========================================================================

def mamba2_init(key, d_model: int, cfg: SSMConfig, dtype):
    di = cfg.expand * d_model
    H = di // cfg.head_dim
    N = cfg.state_dim
    conv_ch = di + 2 * N
    ks = jax.random.split(key, 4)
    return {
        # order: [z(di), x(di), B(N), C(N), dt(H)]
        "w_in": _he(ks[0], (d_model, 2 * di + 2 * N + H), dtype),
        "conv_w": _he(ks[1], (cfg.conv_width, conv_ch), dtype, fan_in=cfg.conv_width),
        "A_log": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": jnp.ones((di,), dtype),
        "w_out": _he(ks[2], (di, d_model), dtype, fan_in=di),
    }


def mamba2_fwd(p, x, cfg: SSMConfig, d_model: int, *, state=None):
    B, S, _ = x.shape
    di = cfg.expand * d_model
    H = di // cfg.head_dim
    N = cfg.state_dim
    zxbcdt = x @ p["w_in"]
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * N]
    dt = zxbcdt[..., -H:]

    conv_tail = None if state is None else state["conv"]
    xbc, new_tail = causal_conv(xbc, p["conv_w"], conv_tail)
    xbc = jax.nn.silu(xbc)
    xs = xbc[..., :di].reshape(B, S, H, cfg.head_dim)
    Bm = xbc[..., di:di + N]
    Cm = xbc[..., di + N:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    h0 = None if state is None else state["ssm"]
    if S == 1 and state is not None:
        y, h = ops.ssd_decode_step(xs[:, 0], dt[:, 0], A, Bm[:, 0], Cm[:, 0],
                                   p["D"], h0)
        y = y[:, None]
    else:
        y, h = ops.ssd_scan(xs, dt, A, Bm, Cm, p["D"], chunk=cfg.chunk, h0=h0)
    y = y.reshape(B, S, di)
    y = ops.rmsnorm(y, p["norm"]) * jax.nn.silu(z)
    out = y @ p["w_out"]
    new_state = {"conv": new_tail, "ssm": h}
    return out, new_state


def mamba2_state_spec(cfg: SSMConfig, d_model: int, batch: int):
    di = cfg.expand * d_model
    H = di // cfg.head_dim
    return {"conv": ((batch, cfg.conv_width - 1, di + 2 * cfg.state_dim),
                     jnp.bfloat16),
            "ssm": ((batch, H, cfg.head_dim, cfg.state_dim), jnp.float32)}


# ===========================================================================
# mLSTM block (xLSTM)
# ===========================================================================

def _mlstm_dims(d_model: int, cfg: XLSTMConfig):
    inner = int(cfg.proj_factor * d_model)
    qk_total = int(cfg.qk_factor * inner)
    H = cfg.n_heads
    return inner, qk_total // H, inner // H, H   # inner, Dk, Dv, H


def mlstm_init(key, d_model: int, cfg: XLSTMConfig, dtype):
    inner, Dk, Dv, H = _mlstm_dims(d_model, cfg)
    ks = jax.random.split(key, 7)
    return {
        "w_up": _he(ks[0], (d_model, 2 * inner), dtype),
        "conv_w": _he(ks[1], (4, inner), dtype, fan_in=4),
        "wq": _he(ks[2], (inner, H * Dk), dtype, fan_in=inner),
        "wk": _he(ks[3], (inner, H * Dk), dtype, fan_in=inner),
        "wv": _he(ks[4], (inner, H * Dv), dtype, fan_in=inner),
        "w_if": _he(ks[5], (inner, 2 * H), dtype, fan_in=inner),
        "out_norm": jnp.ones((inner,), dtype),
        "w_down": _he(ks[6], (inner, d_model), dtype, fan_in=inner),
    }


def mlstm_fwd(p, x, cfg: XLSTMConfig, d_model: int, *, state=None):
    B, S, _ = x.shape
    inner, Dk, Dv, H = _mlstm_dims(d_model, cfg)
    up = x @ p["w_up"]
    xm, z = up[..., :inner], up[..., inner:]
    conv_tail = None if state is None else state["conv"]
    xc, new_tail = causal_conv(xm, p["conv_w"], conv_tail)
    xc = jax.nn.silu(xc)
    q = (xc @ p["wq"]).reshape(B, S, H, Dk).swapaxes(1, 2)
    k = (xc @ p["wk"]).reshape(B, S, H, Dk).swapaxes(1, 2)
    v = (xm @ p["wv"]).reshape(B, S, H, Dv).swapaxes(1, 2)
    gates = (xc @ p["w_if"]).reshape(B, S, 2, H)
    ig = gates[:, :, 0].swapaxes(1, 2)      # (B,H,S)
    fg = gates[:, :, 1].swapaxes(1, 2)

    carry = None if state is None else state["mlstm"]
    if S == 1 and state is not None:
        h, new_carry = ops.mlstm_decode_step(q[:, :, 0], k[:, :, 0], v[:, :, 0],
                                             ig[:, :, 0], fg[:, :, 0], carry)
        h = h[:, :, None]
    else:
        h, new_carry = ops.mlstm_scan(q, k, v, ig, fg, chunk=cfg.chunk,
                                      carry=carry)
    h = h.swapaxes(1, 2).reshape(B, S, inner)
    h = ops.rmsnorm(h, p["out_norm"]) * jax.nn.silu(z)
    out = h @ p["w_down"]
    return out, {"conv": new_tail, "mlstm": new_carry}


def mlstm_state_spec(cfg: XLSTMConfig, d_model: int, batch: int):
    inner, Dk, Dv, H = _mlstm_dims(d_model, cfg)
    return {"conv": ((batch, 3, inner), jnp.bfloat16),
            "mlstm": (((batch, H, Dk, Dv), jnp.float32),
                      ((batch, H, Dk), jnp.float32),
                      ((batch, H), jnp.float32))}


# ===========================================================================
# sLSTM block (xLSTM scalar memory, true recurrence)
# ===========================================================================

def slstm_init(key, d_model: int, cfg: XLSTMConfig, dtype):
    H = cfg.n_heads
    Dh = d_model // H
    ks = jax.random.split(key, 4)
    ff = int(d_model * 4 / 3)
    return {
        "w_gates": _he(ks[0], (d_model, 4 * d_model), dtype),      # z i f o
        "r_gates": _he(ks[1], (H, Dh, 4 * Dh), dtype, fan_in=Dh),  # block-diag
        "out_norm": jnp.ones((d_model,), dtype),
        "w_ff_gate": _he(ks[2], (d_model, ff), dtype),
        "w_ff_up": _he(ks[2], (d_model, ff), dtype),
        "w_ff_down": _he(ks[3], (ff, d_model), dtype, fan_in=ff),
    }


def slstm_fwd(p, x, cfg: XLSTMConfig, d_model: int, *, state=None):
    B, S, _ = x.shape
    H = cfg.n_heads
    Dh = d_model // H
    gates_x = (x @ p["w_gates"]).reshape(B, S, 4, H, Dh)

    if state is None:
        h0 = jnp.zeros((B, H, Dh), jnp.float32)
        c0 = jnp.zeros((B, H, Dh), jnp.float32)
        n0 = jnp.ones((B, H, Dh), jnp.float32)
        m0 = jnp.zeros((B, H, Dh), jnp.float32)
    else:
        h0, c0, n0, m0 = state["slstm"]

    r = p["r_gates"].astype(jnp.float32)

    def step(carry, gx):
        h, c, n, m = carry                          # (B,H,Dh) each
        rec = jnp.einsum("bhd,hdg->bhg", h, r).reshape(B, H, 4, Dh)
        g = gx.astype(jnp.float32) + jnp.moveaxis(rec, 2, 1)
        # g: (B, 4, H, Dh) -> z i f o
        z_t = jnp.tanh(g[:, 0])
        i_t = g[:, 1]
        f_t = g[:, 2]
        o_t = jax.nn.sigmoid(g[:, 3])
        logf = jax.nn.log_sigmoid(f_t)
        m_new = jnp.maximum(logf + m, i_t)
        i_p = jnp.exp(i_t - m_new)
        f_p = jnp.exp(logf + m - m_new)
        c = f_p * c + i_p * z_t
        n = f_p * n + i_p
        h = o_t * c / jnp.maximum(jnp.abs(n), 1.0)
        # ys stacked in bf16: keeps the scan-carry buffer dtype-stable (no
        # full-buffer converts per trip) and halves the stacked-output HBM
        return (h, c, n, m_new), h.astype(jnp.bfloat16)

    (hf, cf, nf, mf), hs = jax.lax.scan(step, (h0, c0, n0, m0),
                                        jnp.moveaxis(gates_x, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).reshape(B, S, d_model).astype(x.dtype)
    y = ops.rmsnorm(y, p["out_norm"])
    ff = jax.nn.silu(y @ p["w_ff_gate"]) * (y @ p["w_ff_up"])
    out = ff @ p["w_ff_down"]
    return out, {"slstm": (hf, cf, nf, mf)}


def slstm_state_spec(cfg: XLSTMConfig, d_model: int, batch: int):
    H = cfg.n_heads
    Dh = d_model // H
    s = ((batch, H, Dh), jnp.float32)
    return {"slstm": (s, s, s, s)}
