"""Activation-sharding context.

Model code is mesh-agnostic; the runtime installs a ``ShardCtx`` around
lowering/execution and the model calls the ``constrain_*`` helpers, which
no-op when no context is installed (single-device tests).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()


class ShardCtx:
    def __init__(self, mesh: Mesh, dp_axes: Tuple[str, ...], model_axis: str,
                 seq_axis: Optional[str] = None, tp: bool = True):
        self.mesh = mesh
        self.dp = dp_axes
        self.model = model_axis
        self.seq_axis = seq_axis  # axis used to shard sequence when batch==1
        self.tp = tp              # False: model axis folded into dp (no TP)

    def sharding(self, *spec) -> NamedSharding:
        return NamedSharding(self.mesh, P(*spec))


def current() -> Optional[ShardCtx]:
    return getattr(_STATE, "ctx", None)


@contextlib.contextmanager
def use(ctx: Optional[ShardCtx]):
    prev = getattr(_STATE, "ctx", None)
    _STATE.ctx = ctx
    try:
        yield ctx
    finally:
        _STATE.ctx = prev


def _constrain(x, *spec):
    ctx = current()
    if ctx is None:
        return x
    return jax.lax.with_sharding_constraint(x, ctx.sharding(*spec))


def constrain_tokens_3d(x):
    """(B, S, d) residual-stream activations: batch over dp."""
    ctx = current()
    if ctx is None:
        return x
    if x.shape[0] % _dp_size(ctx) == 0:
        return _constrain(x, ctx.dp, None, None)
    if ctx.seq_axis and x.shape[1] % ctx.mesh.shape[ctx.seq_axis] == 0:
        return _constrain(x, None, ctx.seq_axis, None)
    return x


def constrain_experts(x):
    """(E, C, d) expert buffers: experts over the model axis (EP)."""
    ctx = current()
    if ctx is None or not ctx.tp:
        return x
    if x.shape[0] % ctx.mesh.shape[ctx.model] == 0:
        return _constrain(x, ctx.model, None, None)
    return x


def constrain_logits(x):
    """(B, S, V) logits: batch over dp, vocab over model."""
    ctx = current()
    if ctx is None:
        return x
    v_ok = ctx.tp and x.shape[-1] % ctx.mesh.shape[ctx.model] == 0
    b_ok = x.shape[0] % _dp_size(ctx) == 0
    return _constrain(x, ctx.dp if b_ok else None, None,
                      ctx.model if v_ok else None)


def _dp_size(ctx: ShardCtx) -> int:
    n = 1
    for a in ctx.dp:
        n *= ctx.mesh.shape[a]
    return n


def dp_size() -> int:
    """Data-parallel world size (1 when no sharding context installed)."""
    ctx = current()
    return _dp_size(ctx) if ctx is not None else 1


def constrain_moe_shards(x):
    """(DP, Tl, ...) per-shard routing tensors: leading dim over dp."""
    ctx = current()
    if ctx is None or x.shape[0] % _dp_size(ctx) != 0:
        return x
    return _constrain(x, ctx.dp, *([None] * (x.ndim - 1)))


def constrain_expert_buffers(x):
    """(DP, E, C, d) expert buffers: shards over dp, experts over model —
    the reshard between these two is the EP all-to-all."""
    ctx = current()
    if ctx is None:
        return x
    dp_ok = x.shape[0] % _dp_size(ctx) == 0
    e_ok = ctx.tp and x.shape[1] % ctx.mesh.shape[ctx.model] == 0
    return _constrain(x, ctx.dp if dp_ok else None,
                      ctx.model if e_ok else None,
                      *([None] * (x.ndim - 2)))
