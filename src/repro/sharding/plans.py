"""Partition-spec plans: map param/batch/cache pytrees to PartitionSpecs.

Axis roles:
  dp axes   ("pod","data") or ("data",) — data parallel + FSDP (ZeRO-3)
  model     "model"                     — TP (heads/ff/vocab) + EP (experts)

Rules are keyed on leaf *names* (unique across the model substrate) with the
base (unstacked) spec; leading scan-stack dims get ``None``.  A dim is only
sharded if divisible by the axis size — otherwise it is replicated, which
avoids GSPMD padding waste on e.g. 40 heads / 16-way TP (the projections
shard on the fused ``H*hd`` dim instead, which is always divisible).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    dp: Tuple[str, ...]          # e.g. ("pod", "data") or ("data",)
    model: str                   # "model"

    @staticmethod
    def from_mesh(mesh: Mesh) -> "MeshAxes":
        names = tuple(mesh.axis_names)
        assert "model" in names, names
        dp = tuple(n for n in names if n != "model")
        return MeshAxes(dp=dp, model="model")


# base spec per leaf name: tuple of roles, one per base dim.
#   "fsdp"  -> sharded over dp axes (ZeRO-3 param shard)
#   "model" -> sharded over model axis (TP / EP / vocab)
#   None    -> replicated
# (name, ndim_base): spec
_RULES: Dict[str, Tuple[Optional[str], ...]] = {
    # embeddings / heads
    "embed": ("model", "fsdp"),
    "lm_head": ("fsdp", "model"),
    "frame_proj": (None, "fsdp"),
    "patch_proj": (None, "fsdp"),
    "mask_embed": (None,),
    # attention (dense / GQA)
    "wq": ("fsdp", "model"),
    "wk": ("fsdp", "model"),
    "wv": ("fsdp", "model"),
    "wo": ("model", "fsdp"),
    # MLA (lora ranks kept replicated; fused head dims column-parallel)
    "wq_a": ("fsdp", None),
    "wq_b": ("fsdp", "model"),
    "wkv_a": ("fsdp", None),
    "wk_b": ("fsdp", "model"),
    "wv_b": ("fsdp", "model"),
    "q_norm": (None,),
    "kv_norm": (None,),
    # MLP
    "w_up": ("fsdp", "model"),
    "w_gate": ("fsdp", "model"),
    "w_down": ("model", "fsdp"),
    # MoE (3D expert weights; detected by ndim)
    "router": ("fsdp", None),
    # mamba2
    "w_in": ("fsdp", "model"),
    "conv_w": (None, "model"),
    "A_log": (None,),
    "D": (None,),
    "dt_bias": (None,),
    "norm": ("model",),
    "w_out": ("model", "fsdp"),
    # xlstm
    "w_if": ("fsdp", None),
    "r_gates": (None, None, None),
    "w_gates": ("fsdp", "model"),
    "w_ff_gate": ("fsdp", "model"),
    "w_ff_up": ("fsdp", "model"),
    "w_ff_down": ("model", "fsdp"),
    "out_norm": ("model",),
    # norms
    "scale": (None,),
    "bias": (None,),
}

_MOE_EXPERT_RULES = {           # (E, d, ff) / (E, ff, d): EP over model
    "w_up": ("model", "fsdp", None),
    "w_gate": ("model", "fsdp", None),
    "w_down": ("model", None, "fsdp"),
}


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return entry.key
    return ""


def _roles_to_spec(roles, shape, axes: MeshAxes, mesh: Mesh,
                   no_tp: bool = False) -> P:
    """Resolve role names to mesh axes, honoring divisibility.  With
    ``no_tp`` the model axis is folded into dp (small models: pure ZeRO-3
    data parallelism, no tensor parallelism)."""
    dp_size = int(np.prod([mesh.shape[a] for a in axes.dp]))
    spec = []
    for role, dim in zip(roles, shape):
        if no_tp and role == "model":
            role = None
        if role == "fsdp" and dim % dp_size == 0:
            spec.append(axes.dp if len(axes.dp) > 1 else axes.dp[0])
        elif role == "model" and dim % mesh.shape[axes.model] == 0:
            spec.append(axes.model)
        else:
            spec.append(None)
    return P(*spec)


def param_specs(params_abstract, mesh: Mesh, axes: Optional[MeshAxes] = None,
                no_tp: bool = False):
    """PartitionSpec pytree mirroring ``params_abstract`` (shapes only)."""
    axes = axes or MeshAxes.from_mesh(mesh)

    def spec_for(path, leaf):
        name = _leaf_name(path)
        ndim = len(leaf.shape)
        rules = _RULES
        if name in _MOE_EXPERT_RULES:
            # distinguish MoE expert weights (base ndim 3) from MLP (base 2):
            # under the "moe"/"shared" context both exist; use trailing-dims fit
            base3 = _MOE_EXPERT_RULES[name]
            # expert weights always sit under a dict that also holds "router";
            # cheaper: try base-3 if the leaf has >=3 dims and the last three
            # dims include the expert count (first of the three > 1) — we
            # instead check the path for a "moe" ancestor without "shared".
            keys = [e.key for e in path if isinstance(e, jax.tree_util.DictKey)]
            if "moe" in keys and "shared" not in keys:
                roles = base3
                stack = ndim - 3
                return P(*((None,) * stack), *_roles_to_spec(
                    roles, leaf.shape[stack:], axes, mesh, no_tp))
        roles = rules.get(name)
        if roles is None:
            return P()
        stack = ndim - len(roles)
        if stack < 0:
            return P()
        return P(*((None,) * stack),
                 *_roles_to_spec(roles, leaf.shape[stack:], axes, mesh, no_tp))

    return jax.tree_util.tree_map_with_path(spec_for, params_abstract)


def batch_specs(batch_abstract, mesh: Mesh, axes: Optional[MeshAxes] = None):
    """Shard every batch leaf on its leading (global-batch) dim over dp."""
    axes = axes or MeshAxes.from_mesh(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in axes.dp]))
    dp = axes.dp if len(axes.dp) > 1 else axes.dp[0]

    def spec_for(leaf):
        if leaf.shape and leaf.shape[0] % dp_size == 0:
            return P(dp, *([None] * (len(leaf.shape) - 1)))
        return P(*([None] * len(leaf.shape)))

    return jax.tree.map(spec_for, batch_abstract)


def cache_specs(cache_abstract, cfg: ModelConfig, mesh: Mesh,
                axes: Optional[MeshAxes] = None, batch_size: int = 0):
    """KV/state caches: batch over dp when divisible, else sequence over dp
    (long-context B=1 decode); kv-heads/channels over model when divisible.

    Cache leaves all carry a leading (n_groups[, n_sub]) stack; the batch dim
    is located per leaf name.
    """
    axes = axes or MeshAxes.from_mesh(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in axes.dp]))
    model_size = mesh.shape[axes.model]
    dp = axes.dp if len(axes.dp) > 1 else axes.dp[0]

    # per leaf name: (batch_dim_from_end, seq_dim_from_end or None,
    #                 model_dim_from_end or None)
    layout = {
        "k": (4, 3, 2), "v": (4, 3, 2),            # (..., B, S, Hkv, D)
        "c_kv": (3, 2, None), "k_rope": (3, 2, None),   # (..., B, S, R)
        "conv": (3, None, 1),                      # (..., B, W-1, C)
        "ssm": (4, None, 3),                       # (..., B, H, P, N)
    }

    def spec_for(path, leaf):
        name = _leaf_name(path)
        nd = len(leaf.shape)
        spec = [None] * nd
        lay = layout.get(name)
        if lay is None:
            # xlstm/slstm tuple states: batch is dim -3 or -2... they are
            # small; shard batch dim if any dim == batch_size and divisible.
            for i, d in enumerate(leaf.shape):
                if batch_size and d == batch_size and d % dp_size == 0:
                    spec[i] = dp
                    break
            return P(*spec)
        b_i, s_i, m_i = lay
        if b_i is not None and nd - b_i >= 0 and leaf.shape[nd - b_i] % dp_size == 0:
            spec[nd - b_i] = dp
        elif s_i is not None and leaf.shape[nd - s_i] % dp_size == 0:
            spec[nd - s_i] = dp    # sequence-shard the cache (B==1 long ctx)
        if m_i is not None and leaf.shape[nd - m_i] % model_size == 0:
            spec[nd - m_i] = axes.model
        return P(*spec)

    return jax.tree_util.tree_map_with_path(spec_for, cache_abstract)


def opt_state_specs(opt_abstract, param_spec_tree):
    """Specs for an optimizer-state tree: m/v mirror their params; int8
    quantized states {"q","s"} give q the param spec and s the param spec
    with the (blocked) last dim replicated."""
    is_q = lambda x: isinstance(x, dict) and set(x.keys()) == {"q", "s"}

    def moment_spec(mleaf, pspec):
        if is_q(mleaf):
            nd = len(mleaf["q"].shape)
            entries = list(pspec) + [None] * (nd - len(list(pspec)))
            s_spec = P(*entries[:-1], None) if nd else P()
            return {"q": pspec, "s": s_spec}
        return pspec

    def tree_for(moments):
        return jax.tree.map(moment_spec, moments, param_spec_tree,
                            is_leaf=is_q)

    return {"m": tree_for(opt_abstract["m"]),
            "v": tree_for(opt_abstract["v"]),
            "step": P()}


def to_shardings(spec_tree, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
