"""Gateway authentication/authorization helpers.

Two token kinds exist and must not be confused: the *session* token in the
``Authorization: Bearer`` header identifies the user (their profile), and
the *block capability* token minted with each grant (the paper's
``MPD_SECRETWORD``) authorizes the confirm step for one specific block.
This module handles only the former; handlers compare the latter.
"""
from __future__ import annotations

from typing import Mapping, Optional

from repro.gateway.profiles import ProfileStore, UserProfile


class AuthError(Exception):
    """401 (who are you) / 403 (not yours)."""

    def __init__(self, status: int, message: str):
        super().__init__(message)
        self.status = status
        self.message = message


def bearer_token(headers: Mapping[str, str]) -> Optional[str]:
    auth = headers.get("Authorization") or headers.get("authorization")
    if not auth or not auth.startswith("Bearer "):
        return None
    return auth[len("Bearer "):].strip()


def require_user(headers: Mapping[str, str],
                 store: ProfileStore,
                 query: Optional[Mapping[str, str]] = None) -> UserProfile:
    """Resolve the session.  The bearer header is canonical; an
    ``access_token`` query parameter is accepted too because the browser
    ``EventSource`` API (the dashboard's SSE client) cannot set request
    headers."""
    token = bearer_token(headers)
    if token is None and query is not None:
        token = query.get("access_token")
    profile = store.authenticate(token)
    if profile is None:
        raise AuthError(401, "missing or unknown bearer token")
    return profile


def require_admin(profile: UserProfile) -> UserProfile:
    if not profile.admin:
        raise AuthError(403, f"{profile.user} is not an administrator")
    return profile


def require_owner(profile: UserProfile, owner: str) -> UserProfile:
    """Block-level access: the owner or an admin."""
    if profile.user != owner and not profile.admin:
        raise AuthError(403,
                        f"{profile.user} does not own this block")
    return profile
