"""Per-user session profiles — the paper's "different configuration files
specified for each user".

Each public-cluster user gets a profile holding their auth token and their
user-specific scheduling configuration: default priority, per-user quota
(held-chip cap and chip-second budget), default SLO deadline and default
usage period.  ``apply_quotas`` installs the quota half into the
scheduler's ``SchedulingPolicy`` so admission enforces it; the request
defaults are applied by the gateway handlers when a submission omits the
field — a user never has to restate their own configuration per request.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Iterable, Optional


@dataclasses.dataclass
class UserProfile:
    user: str
    token: str                             # gateway auth (bearer) token
    priority: int = 0                      # default admission priority
    max_chips: Optional[int] = None        # quota: concurrent held chips
    max_chip_seconds: Optional[float] = None  # quota: compute budget
    deadline_s: Optional[float] = None     # default SLO deadline
    duration_s: float = 3600.0             # default usage period
    admin: bool = False                    # may review/preempt/resume any
                                           # block and read global feeds

    def public(self) -> Dict:
        """JSON view without the token (served back to the caller)."""
        d = dataclasses.asdict(self)
        del d["token"]
        return d


class ProfileStore:
    """Token -> profile lookup plus policy wiring."""

    def __init__(self, profiles: Iterable[UserProfile] = ()):
        self._by_token: Dict[str, UserProfile] = {}
        self._by_user: Dict[str, UserProfile] = {}
        for p in profiles:
            self.add(p)

    def add(self, profile: UserProfile) -> UserProfile:
        if profile.token in self._by_token:
            raise ValueError(f"duplicate token for {profile.user}")
        self._by_token[profile.token] = profile
        self._by_user[profile.user] = profile
        return profile

    def authenticate(self, token: Optional[str]) -> Optional[UserProfile]:
        if not token:
            return None
        return self._by_token.get(token)

    def for_user(self, user: str) -> Optional[UserProfile]:
        return self._by_user.get(user)

    def __iter__(self):
        return iter(self._by_user.values())

    def __len__(self) -> int:
        return len(self._by_user)

    def apply_quotas(self, policy) -> None:
        """Install every profile's quota into the SchedulingPolicy (the
        enforcement point — the gateway itself never checks quotas)."""
        for p in self._by_user.values():
            if p.max_chips is not None or p.max_chip_seconds is not None:
                policy.set_quota(p.user, max_chips=p.max_chips,
                                 max_chip_seconds=p.max_chip_seconds)

    # ------------------------------------------------------------ persistence
    def snapshot(self) -> list:
        """Full profile dump (tokens included) for the registry-backed
        session store — what lets a restarted gateway keep authenticating
        the same sessions."""
        return [dataclasses.asdict(p) for p in self._by_user.values()]

    def rehydrate(self, dicts: Iterable[Dict]) -> int:
        """Re-add stored profiles that this store doesn't already define.
        Profiles passed to the constructor win (an operator's fresh config
        overrides the snapshot); unknown fields are dropped so older
        snapshots keep loading after UserProfile grows."""
        fields = {f.name for f in dataclasses.fields(UserProfile)}
        n = 0
        for d in dicts or ():
            d = {k: v for k, v in dict(d).items() if k in fields}
            if not d.get("user") or not d.get("token"):
                continue
            if d["user"] in self._by_user or d["token"] in self._by_token:
                continue
            self.add(UserProfile(**d))
            n += 1
        return n

    @classmethod
    def from_file(cls, path: str) -> "ProfileStore":
        """Load profiles from a JSON list of UserProfile field dicts."""
        with open(path) as f:
            return cls(UserProfile(**d) for d in json.load(f))
