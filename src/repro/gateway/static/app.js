/* Public Cluster dashboard — dependency-free browser client.
 *
 * Data flow: REST for snapshots (/v1/cluster, /v1/blocks), Server-Sent
 * Events for liveness. Admin sessions hold one cluster-wide stream;
 * plain users hold one stream per owned block (the gateway scopes the
 * feed to what the session may see). Every data call carries the bearer
 * token; EventSource cannot set headers, so streams pass it as
 * ?access_token= (the gateway accepts both).
 */
"use strict";

const $ = (id) => document.getElementById(id);
let TOKEN = localStorage.getItem("pc_token") || "";
let PROFILE = null;
let sources = [];          // open EventSource objects
let refreshTimer = null;   // debounce: many events -> one refresh

// lifecycle state -> status tone (the badge also always shows the name)
const TONES = {
  running: "good", active: "good", done: "good",
  queued: "warning", preempted: "warning",
  requested: "accent", approved: "accent", confirmed: "accent",
  expired: "serious",
  failed: "critical", denied: "critical",
};

async function api(method, path, body) {
  const res = await fetch(path, {
    method,
    headers: Object.assign(
      { "Authorization": "Bearer " + TOKEN },
      body !== undefined ? { "Content-Type": "application/json" } : {}),
    body: body !== undefined ? JSON.stringify(body) : undefined,
  });
  const data = await res.json().catch(() => ({}));
  if (!res.ok) throw new Error(data.error || res.status + " " + method + " " + path);
  return data;
}

// ------------------------------------------------------------ rendering
function renderCluster(rep) {
  $("free-chips").textContent = rep.free_chips;
  $("total-chips").textContent = rep.n_chips;
  $("queue-depth").textContent = rep.queue_depth;
  const util = rep.queue ? rep.queue.utilization_now : 0;
  $("util-value").textContent = Math.round(util * 100) + "%";
  $("util-meter").style.width = Math.min(100, util * 100) + "%";
  $("dl-hits").textContent = rep.deadlines.deadline_hits;
  $("dl-misses").textContent = rep.deadlines.deadline_misses;
  $("preempted").textContent = rep.preemption.preempted_total;
  $("resumed").textContent = rep.preemption.resumed_total;
  if (rep.compile) {
    const c = rep.compile;
    $("compile-cache").textContent =
      c.compile_hits_total + "/" +
      (c.compile_hits_total + c.compile_misses_total) + " (" +
      Math.round(100 * c.compile_hit_rate) + "%)";
  }
  if (rep.roofline) {
    $("mean-mfu").textContent = rep.roofline.n_modeled
      ? (100 * rep.roofline.mean_mfu).toFixed(1) + "%" : "—";
  }
  const pods = rep.pods || [];
  const live = pods.filter((p) => p.phase !== "dead");
  $("pods-live").textContent = live.length;
  $("migrations").textContent =
    rep.federation ? rep.federation.migrated_total : 0;
  $("pods-detail").textContent = pods.map(
    (p) => p.name + " " + p.free_chips + "/" + p.n_chips +
           (p.phase !== "ready" ? " (" + p.phase + ")" : "")).join(" · ");
  renderObs(rep.obs);
}

function sparkline(svg, points) {
  // points: [[t, v], ...] -> one polyline scaled to the 120x28 viewBox
  svg.replaceChildren();
  if (!points || points.length < 2) return;
  const vs = points.map((p) => p[1]);
  const vmax = Math.max(...vs, 1e-9);
  const step = 120 / (points.length - 1);
  const pts = points.map((p, i) =>
    (i * step).toFixed(1) + "," + (26 - 24 * p[1] / vmax).toFixed(1));
  const line = document.createElementNS("http://www.w3.org/2000/svg",
                                        "polyline");
  line.setAttribute("points", pts.join(" "));
  svg.appendChild(line);
}

function renderObs(obs) {
  if (!obs) return;
  $("pump-p90").textContent = obs.pump_tick && obs.pump_tick.count
    ? (obs.pump_tick.p90 * 1000).toFixed(1) + "ms" : "—";
  sparkline($("pump-spark"), (obs.series || {}).pump_tick_ms);
  $("http-429").textContent = obs.http_429;
  $("http-413").textContent = obs.http_413;
  $("sse-streams").textContent = obs.sse_streams;
  $("stragglers").textContent = (obs.stragglers || []).length;
  const pms = obs.postmortems || [];
  $("postmortems").textContent = pms.length;
  $("postmortem-detail").textContent = pms.length
    ? pms[0].reason + " · " + pms[0].name : "";
}

function fmtDeadline(b) {
  if (b.deadline_at == null) return "—";
  const left = b.deadline_at - Date.now() / 1000;
  if (left < 0) return "missed";
  return left > 120 ? Math.round(left / 60) + "m left"
                    : Math.round(left) + "s left";
}

function blockRow(b) {
  const tr = document.createElement("tr");
  const canAdmin = PROFILE && PROFILE.admin;
  const auto = b.autostep;
  const cells = [
    ["<span class=mono>" + b.app_id + "</span>"],
    [b.user],
    ["<span class=state data-tone=" + (TONES[b.state] || "") + ">" +
     b.state + "</span>" +
     (b.straggler ? "<span class=straggler-badge>straggler</span>" : "")],
    [b.pod == null ? "—" : "pod " + b.pod],
    [b.n_chips, "num"],
    [b.steps, "num"],
    [b.mfu == null ? "—" : (100 * b.mfu).toFixed(1) + "%", "num"],
    [b.priority, "num"],
    [fmtDeadline(b)],
    [auto ? "on · " + auto.steps_driven + " steps" +
            (auto.max_rate_hz ? " · " + auto.max_rate_hz + "/s" : "")
          : "off"],
  ];
  for (const [html, cls] of cells) {
    const td = document.createElement("td");
    if (cls) td.className = cls;
    td.innerHTML = html;
    tr.appendChild(td);
  }
  const td = document.createElement("td");
  td.className = "controls";
  const live = !["expired", "done", "failed", "denied"].includes(b.state);
  const mk = (label, fn, show) => {
    if (!show) return;
    const btn = document.createElement("button");
    btn.textContent = label;
    btn.onclick = () => fn().then(refreshSoon).catch((e) => alert(e.message));
    td.appendChild(btn);
  };
  mk(auto ? "autostep off" : "autostep on",
     () => api("POST", "/v1/blocks/" + b.app_id + "/autostep",
               { enabled: !auto }), live);
  mk("pace", () => {
    const v = prompt("max steps/s (empty = unpaced)", auto && auto.max_rate_hz || "");
    if (v === null) return Promise.resolve();
    return api("POST", "/v1/blocks/" + b.app_id + "/autostep",
               { max_rate_hz: v === "" ? null : Number(v) });
  }, live && !!auto);
  mk("preempt", () => api("POST", "/v1/blocks/" + b.app_id + "/preempt", {}),
     canAdmin && ["running", "active"].includes(b.state));
  mk("resume", () => api("POST", "/v1/blocks/" + b.app_id + "/resume", {}),
     canAdmin && b.state === "preempted");
  mk("expire", () => api("POST", "/v1/blocks/" + b.app_id + "/expire", {}),
     live);
  tr.appendChild(td);
  return tr;
}

async function refresh() {
  const [rep, blocks] = await Promise.all([
    api("GET", "/v1/cluster"), api("GET", "/v1/blocks")]);
  renderCluster(rep);
  const body = $("blocks-body");
  body.replaceChildren(...blocks.blocks.map(blockRow));
  $("no-blocks").hidden = blocks.blocks.length > 0;
  return blocks.blocks;
}

function refreshSoon() {
  if (refreshTimer) return;
  refreshTimer = setTimeout(() => { refreshTimer = null; refresh(); }, 250);
}

// ------------------------------------------------------------ live feed
function logEvent(ev) {
  const log = $("event-log");
  const li = document.createElement("li");
  const seq = document.createElement("span");
  seq.className = "seq";
  seq.textContent = ev.seq;
  const kind = document.createElement("span");
  kind.className = "kind";
  kind.textContent = ev.kind;
  const detail = document.createElement("span");
  detail.textContent = [
    ev.app_id, ev.state, ev.action, ev.reason,
    ev.kind === "step" ? (ev.step_s * 1000).toFixed(1) + "ms" : null,
    ev.kind === "utilization"
      ? Math.round(100 * ev.used_chips / ev.total_chips) + "%" : null,
    ev.kind === "pod" ? "pod " + ev.pod + " (" + ev.name + ")" : null,
    ev.kind === "migrated"
      ? "pod " + ev.from_pod + " → pod " + ev.to_pod : null,
    ev.kind === "postmortem" ? ev.name : null,
  ].filter(Boolean).join(" · ");
  li.append(seq, kind, detail);
  log.prepend(li);
  while (log.children.length > 200) log.lastChild.remove();
}

function openStream(path) {
  const es = new EventSource(
    path + (path.includes("?") ? "&" : "?") + "access_token=" +
    encodeURIComponent(TOKEN));
  es.onopen = () => {
    $("feed-state").textContent = "feed: live";
    $("feed-state").dataset.state = "live";
  };
  es.onmessage = null;      // typed events only (event: <kind>)
  for (const kind of ["state", "admitted", "enqueued", "dequeued",
                      "preempted", "resumed", "registered", "autostep",
                      "step", "compile", "utilization", "session",
                      "generate", "pod", "migrated", "postmortem"]) {
    es.addEventListener(kind, (msg) => {
      const ev = JSON.parse(msg.data);
      if (ev.kind !== "step" && ev.kind !== "utilization") refreshSoon();
      logEvent(ev);
    });
  }
  es.onerror = () => {
    $("feed-state").textContent = "feed: reconnecting";
    $("feed-state").dataset.state = "off";
  };
  sources.push(es);
  return es;
}

function closeStreams() {
  sources.forEach((es) => es.close());
  sources = [];
}

async function connectFeeds(blocks) {
  closeStreams();
  if (PROFILE.admin) {
    openStream("/v1/events/stream");
    return;
  }
  // plain users: one scoped stream per owned, still-interesting block
  for (const b of blocks) {
    if (!["expired", "done", "failed", "denied"].includes(b.state))
      openStream("/v1/blocks/" + b.app_id + "/events/stream");
  }
}

// ----------------------------------------------------------- bootstrap
async function connect() {
  PROFILE = (await api("GET", "/v1/profile")).profile;
  $("whoami").textContent = PROFILE.user + (PROFILE.admin ? " (admin)" : "");
  $("app").hidden = false;
  $("login-hint").hidden = true;
  const blocks = await refresh();
  await connectFeeds(blocks);
  // periodic safety net: SSE covers liveness, this covers clock-driven
  // fields (deadline countdowns) and any missed reconnect window
  setInterval(refreshSoon, 5000);
}

$("auth-form").addEventListener("submit", (e) => {
  e.preventDefault();
  TOKEN = $("token-input").value.trim();
  localStorage.setItem("pc_token", TOKEN);
  connect().catch((err) => {
    $("whoami").textContent = "auth failed: " + err.message;
    $("app").hidden = true;
    $("login-hint").hidden = false;
  });
});

if (TOKEN) {
  $("token-input").value = TOKEN;
  connect().catch(() => { /* stored token went stale: wait for input */ });
}
