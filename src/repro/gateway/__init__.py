"""Web gateway — the paper's "integrated system to fully control and
monitor the whole system over web" (see also arXiv:0711.0528, the
web-based interface companion paper).

Stdlib-only HTTP/JSON front-end over a ``ClusterDaemon``: per-user session
profiles with token auth and user-specific defaults (``profiles``), a
request router exposing the full block lifecycle (``handlers``), and a
threaded HTTP server (``server``).  No third-party dependencies — the
container's toolchain is the ceiling.
"""
from repro.gateway.profiles import ProfileStore, UserProfile
from repro.gateway.server import GatewayServer

__all__ = ["GatewayServer", "ProfileStore", "UserProfile"]
