"""Threaded stdlib HTTP server hosting the GatewayApi.

``ThreadingHTTPServer`` gives each connection its own thread, which is
what makes the long-poll event feed workable: a client parked on
``GET /v1/blocks/<id>/events?timeout_s=20`` holds only its own thread
while other users' requests proceed.  Mutations are safe regardless of
thread count because every one funnels into the ClusterDaemon's command
queue and executes on the single pump thread.
"""
from __future__ import annotations

import json
import threading
import urllib.parse
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional

from repro.gateway.handlers import GatewayApi
from repro.gateway.profiles import ProfileStore


class _Handler(BaseHTTPRequestHandler):
    api: GatewayApi = None            # injected by GatewayServer
    protocol_version = "HTTP/1.1"     # keep-alive (Content-Length always set)
    quiet = True

    def log_message(self, fmt, *args):   # noqa: D102 - silence per-request
        if not self.quiet:
            super().log_message(fmt, *args)

    def _dispatch(self, method: str) -> None:
        parsed = urllib.parse.urlsplit(self.path)
        query = {k: v[0] for k, v in
                 urllib.parse.parse_qs(parsed.query).items()}
        length = int(self.headers.get("Content-Length") or 0)
        body = self.rfile.read(length) if length else b""
        try:
            status, obj = self.api.handle(method, parsed.path, query,
                                          dict(self.headers), body)
        except Exception as e:          # defensive: a handler bug must not
            status, obj = 500, {"error": f"internal error: {e}"}
        data = json.dumps(obj, default=str).encode()
        self.send_response(status)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(data)))
        self.end_headers()
        self.wfile.write(data)

    def do_GET(self) -> None:
        self._dispatch("GET")

    def do_POST(self) -> None:
        self._dispatch("POST")


class GatewayServer:
    """Bind-and-serve wrapper: ``GatewayServer(daemon, profiles).start()``.

    ``port=0`` binds an ephemeral port (tests/benchmarks); read ``url``
    after construction.  ``stop()`` shuts the listener down and joins the
    serving thread; the daemon is left running (the caller owns it).
    """

    def __init__(self, daemon, profiles: ProfileStore,
                 host: str = "127.0.0.1", port: int = 0):
        api = GatewayApi(daemon, profiles)
        handler = type("GatewayHandler", (_Handler,), {"api": api})
        self.httpd = ThreadingHTTPServer((host, port), handler)
        self.httpd.daemon_threads = True
        self._thread: Optional[threading.Thread] = None

    @property
    def host(self) -> str:
        return self.httpd.server_address[0]

    @property
    def port(self) -> int:
        return self.httpd.server_address[1]

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def start(self) -> "GatewayServer":
        if self._thread is None or not self._thread.is_alive():
            self._thread = threading.Thread(
                target=self.httpd.serve_forever, name="gateway-http",
                daemon=True)
            self._thread.start()
        return self

    def stop(self) -> None:
        self.httpd.shutdown()
        self.httpd.server_close()
        if self._thread is not None:
            self._thread.join(5.0)
            self._thread = None

    def __enter__(self) -> "GatewayServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()
