"""Threaded stdlib HTTP server hosting the GatewayApi.

``ThreadingHTTPServer`` gives each connection its own thread, which is
what makes the long-poll event feed *and* the Server-Sent Events streams
workable: a client parked on ``GET /v1/blocks/<id>/events?timeout_s=20``
or holding ``/v1/events/stream`` open occupies only its own thread while
other users' requests proceed.  Mutations are safe regardless of thread
count because every one funnels into the ClusterDaemon's command queue
and executes on the single pump thread.

Hardening knobs (all constructor parameters):

* ``max_body_bytes`` — requests with a larger declared body are refused
  with 413 before the body is read (the connection is closed, so an
  oversized upload cannot occupy the socket);
* ``rate_limit_rps`` / ``rate_limit_burst`` — per-session token-bucket
  rate limiting; an exhausted session gets 429 with a retry hint
  (``None`` disables the limiter).
"""
from __future__ import annotations

import json
import threading
import time
import urllib.parse
import uuid
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional

from repro.gateway.handlers import GatewayApi, SSEStream, StaticFile
from repro.gateway.profiles import ProfileStore
from repro.gateway.ratelimit import RateLimiter
from repro.obs.metrics import REGISTRY
from repro.obs.trace import TRACER


class _Handler(BaseHTTPRequestHandler):
    api: GatewayApi = None            # injected by GatewayServer
    max_body_bytes: int = 1 << 20     # injected by GatewayServer
    protocol_version = "HTTP/1.1"     # keep-alive (Content-Length always set)
    quiet = True

    def log_message(self, fmt, *args):   # noqa: D102 - silence per-request
        if not self.quiet:
            super().log_message(fmt, *args)

    def _send_json(self, status: int, obj) -> None:
        data = json.dumps(obj, default=str).encode()
        self.send_response(status)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(data)))
        self.send_header("X-Request-ID", self._rid)
        self.end_headers()
        self.wfile.write(data)

    def _dispatch(self, method: str) -> None:
        # correlation id: honor the client's X-Request-ID, mint one
        # otherwise; echoed on every response and carried into the trace
        # context (and, through it, into event payloads)
        self._rid = (self.headers.get("X-Request-ID")
                     or f"req-{uuid.uuid4().hex[:12]}")
        t0 = time.perf_counter()
        parsed = urllib.parse.urlsplit(self.path)
        with TRACER.span(f"http.{method}:{parsed.path}", cat="http",
                         request_id=self._rid):
            status = self._serve_one(method, parsed)
        dt = time.perf_counter() - t0
        REGISTRY.inc("repro_http_requests_total",
                     labels={"method": method, "status": str(status)})
        REGISTRY.observe("repro_http_request_seconds", dt,
                         labels={"method": method})
        self.api.record_access(method, parsed.path, status, dt, self._rid)

    def _serve_one(self, method: str, parsed) -> int:
        """Handle one request; returns the response status (for the
        access log / metrics — the response itself is already written)."""
        query = {k: v[0] for k, v in
                 urllib.parse.parse_qs(parsed.query).items()}
        length = int(self.headers.get("Content-Length") or 0)
        if length > self.max_body_bytes:
            # refuse before reading: an oversized body never transits the
            # socket; close the connection (the unread body would otherwise
            # be parsed as the next pipelined request)
            self.close_connection = True
            REGISTRY.inc("repro_http_413_total", labels={"method": method})
            self._send_json(413, {
                "error": f"request body {length} bytes exceeds the "
                         f"{self.max_body_bytes}-byte cap"})
            return 413
        body = self.rfile.read(length) if length else b""
        try:
            status, obj = self.api.handle(method, parsed.path, query,
                                          dict(self.headers), body)
        except Exception as e:          # defensive: a handler bug must not
            status, obj = 500, {"error": f"internal error: {e}"}
        if isinstance(obj, SSEStream):
            # hand the socket to the stream: frames flow until the client
            # disconnects or the gateway shuts down.  No Content-Length,
            # so the connection cannot be reused afterwards.
            self.close_connection = True
            self.send_response(status)
            self.send_header("Content-Type", "text/event-stream")
            self.send_header("Cache-Control", "no-cache")
            self.send_header("Connection", "close")
            self.send_header("X-Request-ID", self._rid)
            self.end_headers()
            obj.serve(self.wfile)
            return status
        if isinstance(obj, StaticFile):
            self.send_response(status)
            self.send_header("Content-Type", obj.content_type)
            self.send_header("Content-Length", str(len(obj.data)))
            self.send_header("X-Request-ID", self._rid)
            self.end_headers()
            self.wfile.write(obj.data)
            return status
        self._send_json(status, obj)
        return status

    def do_GET(self) -> None:
        self._dispatch("GET")

    def do_POST(self) -> None:
        self._dispatch("POST")


class GatewayServer:
    """Bind-and-serve wrapper: ``GatewayServer(daemon, profiles).start()``.

    ``port=0`` binds an ephemeral port (tests/benchmarks); read ``url``
    after construction.  ``stop()`` shuts the listener down, unparks any
    open SSE streams and joins the serving thread; the daemon is left
    running (the caller owns it).
    """

    def __init__(self, daemon, profiles: ProfileStore,
                 host: str = "127.0.0.1", port: int = 0,
                 max_body_bytes: int = 1 << 20,
                 rate_limit_rps: Optional[float] = None,
                 rate_limit_burst: Optional[int] = None):
        limiter = (RateLimiter(rate_limit_rps, burst=rate_limit_burst)
                   if rate_limit_rps else None)
        self.api = GatewayApi(daemon, profiles, rate_limiter=limiter)
        handler = type("GatewayHandler", (_Handler,),
                       {"api": self.api,
                        "max_body_bytes": int(max_body_bytes)})
        self.httpd = ThreadingHTTPServer((host, port), handler)
        self.httpd.daemon_threads = True
        self._thread: Optional[threading.Thread] = None

    @property
    def host(self) -> str:
        return self.httpd.server_address[0]

    @property
    def port(self) -> int:
        return self.httpd.server_address[1]

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def start(self) -> "GatewayServer":
        if self._thread is None or not self._thread.is_alive():
            self.api.closing.clear()
            self._thread = threading.Thread(
                target=self.httpd.serve_forever, name="gateway-http",
                daemon=True)
            self._thread.start()
        return self

    def stop(self) -> None:
        self.api.closing.set()         # drain parked SSE streams
        self.httpd.shutdown()
        self.httpd.server_close()
        if self._thread is not None:
            self._thread.join(5.0)
            self._thread = None
        self.api.flush_sessions()      # write any throttled cursor state

    def __enter__(self) -> "GatewayServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()
