"""Gateway request handlers: the HTTP/JSON surface of the block lifecycle.

Routes (all JSON in/out, ``Authorization: Bearer <session token>``):

  ``POST /v1/register``              step (1): register an application
  ``POST /v1/submit``                register + automated admission
  ``POST /v1/gangs``                 atomic multi-block (gang) submission
  ``POST /v1/blocks/<id>/review``    step (2), admin: assign a block
  ``POST /v1/blocks/<id>/confirm``   step (3): reconfirm w/ capability token
  ``POST /v1/blocks/<id>/activate``  step (4): boot the runtime (job spec)
  ``POST /v1/blocks/<id>/run``       step (5): start the job
  ``POST /v1/blocks/<id>/steps``     drive N steps (event-driven dispatch)
  ``POST /v1/blocks/<id>/autostep``  daemon-side stepping: enable/disable/
                                     pace the autostep engine for the block
  ``GET  /v1/blocks/<id>``           step (6): monitor one block
  ``GET  /v1/blocks/<id>/events``    step (6): long-poll live event feed
  ``GET  /v1/blocks/<id>/events/stream``  the same feed as Server-Sent
                                     Events (``text/event-stream``)
  ``GET  /v1/blocks/<id>/download``  step (7): collect results
  ``POST /v1/blocks/<id>/preempt``   admin: evict (checkpoint + release)
  ``POST /v1/blocks/<id>/resume``    admin: re-admit a preempted block
  ``POST /v1/blocks/<id>/resize``    admin: elastic grow/shrink
  ``POST /v1/blocks/<id>/expire``    owner/admin: end the usage period
  ``GET  /v1/blocks``                my blocks (admin: everyone's)
  ``GET  /v1/cluster``               pod inventory + monitor reports
  ``GET  /v1/pods``                  federation pod directory
  ``POST /v1/pods``                  admin: attach a pod at runtime
  ``POST /v1/pods/<id>/drain``       admin: stop placing on a pod
  ``POST /v1/pods/<id>/detach``      admin: remove a pod (``force`` evicts)
  ``POST /v1/pods/<id>/heartbeat``   pod agent liveness beat
  ``GET  /v1/events``                admin: global event feed (long-poll)
  ``GET  /v1/events/stream``         admin: cluster-wide SSE stream
  ``GET  /v1/profile``               who am I / my session configuration
  ``GET  /v1/profile/cursors``       my persisted event-feed cursors
  ``GET  /metrics``                  Prometheus text exposition (no auth)
  ``GET  /v1/trace``                 admin: Chrome-trace JSON of all spans
  ``GET  /v1/blocks/<id>/trace``     owner: one block's trace
  ``GET  /v1/postmortems``           admin: flight-recorder artifact index
  ``GET  /v1/postmortems/<name>``    admin: one postmortem dump
  ``GET  /v1/access``                admin: recent gateway access log
  ``GET  /ui`` (+ ``/ui/<asset>``)   the browser dashboard (static, no auth
                                     for the assets — data calls need a
                                     session token)

Request defaults (priority, deadline, duration) come from the caller's
session profile when a submission omits them — the paper's per-user
configuration files.  Job specs are dicts: ``{"kind": "sim", "step_s":
0.01}`` boots the device-free simulator; ``{"kind": "train"|"serve",
"arch": "xlstm_350m", ...}`` builds a real ``JobSpec``.

Feed cursors: every served feed page (long-poll or SSE) records the
session's ``next_after`` in the registry-backed session store, and a feed
request may pass ``after=resume`` to continue from the stored cursor —
so a gateway restart (or a browser reopening the dashboard) picks up
where the session left off instead of replaying or skipping events.
"""
from __future__ import annotations

import json
import os
import re
import threading
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

from repro.core.partition import AllocationError
from repro.core.runtime import JobSpec, SimJobSpec
from repro.gateway import auth
from repro.gateway.auth import AuthError
from repro.gateway.profiles import ProfileStore, UserProfile
from repro.gateway.ratelimit import RateLimiter
from repro.obs.flight import RECORDER
from repro.obs.metrics import REGISTRY
from repro.obs.trace import TRACER

MAX_LONGPOLL_S = 30.0
MAX_SSE_S = 3600.0          # hard per-connection cap on an SSE stream
SSE_HEARTBEAT_S = 10.0      # comment frame cadence (detects dead clients)
STATIC_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "static")
_CTYPES = {".html": "text/html; charset=utf-8",
           ".js": "text/javascript; charset=utf-8",
           ".css": "text/css; charset=utf-8",
           ".svg": "image/svg+xml"}


class ApiError(Exception):
    def __init__(self, status: int, message: str):
        super().__init__(message)
        self.status = status
        self.message = message


def parse_job(spec: Optional[Dict]):
    """Job-spec dict -> SimJobSpec / JobSpec (None passes through: the
    block is admitted without auto-activation)."""
    if spec is None:
        return None
    if not isinstance(spec, dict) or "kind" not in spec:
        raise ApiError(400, "job must be a dict with a 'kind'")
    kind = spec["kind"]
    if kind == "sim":
        return SimJobSpec(step_s=float(spec.get("step_s", 0.001)),
                          ckpt_every=int(spec.get("ckpt_every", 0)))
    if kind not in ("train", "serve"):
        raise ApiError(400, f"unknown job kind {kind!r}")
    # real runtimes: resolve the architecture config lazily (importing the
    # model zoo is heavy; sim-only deployments never pay it)
    import repro.configs as configs
    from repro.models.config import ShapeConfig
    from repro.train.optimizer import OptConfig
    arch = spec.get("arch")
    if not arch:
        raise ApiError(400, f"{kind} job needs an 'arch'")
    try:
        cfg = (configs.get_smoke(arch) if spec.get("smoke", True)
               else configs.get(arch))
    except KeyError:
        raise ApiError(400, f"unknown arch {arch!r}")
    shape = ShapeConfig(
        spec.get("shape_name", "gw"),
        "train" if kind == "train" else "serve",
        seq_len=int(spec.get("seq_len", 128)),
        global_batch=int(spec.get("global_batch", 4)),
        microbatch=int(spec.get("microbatch", 1)))
    opt = OptConfig(lr=float(spec.get("lr", 3e-4)),
                    warmup_steps=int(spec.get("warmup_steps", 2)),
                    total_steps=int(spec.get("total_steps", 100)))
    extra = {}
    if kind == "serve":
        # continuous-batching data plane: paged serve jobs expose the
        # generate endpoint (slot batch + shared page pool)
        extra = dict(paged=bool(spec.get("paged", False)),
                     page_size=int(spec.get("page_size", 16)),
                     n_pages=int(spec.get("n_pages", 0)),
                     max_slots=int(spec.get("max_slots", 8)),
                     max_seq_len=int(spec.get("max_seq_len", 0)),
                     decode_sample=bool(spec.get("decode_sample", False)))
    return JobSpec(cfg, shape, kind=kind, opt=opt,
                   seed=int(spec.get("seed", 0)), **extra)


def _grant_dict(grant) -> Optional[Dict]:
    if grant is None:
        return None
    return {"block_id": grant.block_id, "coords": list(grant.coords),
            "mesh_shape": list(grant.mesh_shape), "token": grant.token,
            "expires_at": grant.expires_at}


class StaticFile:
    """A non-JSON response body (the dashboard's assets).  The HTTP server
    recognizes this return type and writes the bytes verbatim."""

    def __init__(self, data: bytes, content_type: str):
        self.data = data
        self.content_type = content_type


class SSEStream:
    """A Server-Sent Events response: the HTTP server hands ``serve`` the
    socket and the stream pushes every matching bus event as one
    ``id:``/``event:``/``data:`` frame until the client disconnects, the
    gateway shuts down, or ``max_s`` elapses.  ``id`` is the bus cursor,
    so a reconnecting ``EventSource`` resumes exactly where it dropped
    (the browser re-sends it as ``Last-Event-ID``)."""

    def __init__(self, daemon, after: int, app_id: Optional[str] = None,
                 kinds=None, max_s: float = MAX_SSE_S,
                 heartbeat_s: float = SSE_HEARTBEAT_S,
                 closing: Optional[threading.Event] = None,
                 on_cursor=None, match=None, until=None):
        self.daemon = daemon
        self.after = after
        self.app_id = app_id
        self.kinds = kinds
        self.max_s = max_s
        self.heartbeat_s = heartbeat_s
        self.closing = closing or threading.Event()
        self.on_cursor = on_cursor          # cursor persistence callback
        self.match = match                  # event predicate (None = all);
                                            # the cursor still advances over
                                            # filtered-out events
        self.until = until                  # sent-event predicate: True
                                            # ends the stream (generate:
                                            # the session's final token)

    def serve(self, wfile) -> None:
        end = time.monotonic() + self.max_s
        next_beat = time.monotonic() + self.heartbeat_s
        after = self.after
        REGISTRY.add_gauge("repro_sse_streams", 1)
        try:
            # an immediate comment flushes headers so EventSource fires
            # its `open` event before the first real event arrives
            wfile.write(b": stream open\n\n")
            wfile.flush()
            while not self.closing.is_set():
                remaining = end - time.monotonic()
                if remaining <= 0:
                    return
                # short waits keep shutdown + heartbeat latency bounded
                evs = self.daemon.wait_events(
                    after, app_id=self.app_id, kinds=self.kinds,
                    timeout=min(1.0, remaining), limit=500)
                if evs:
                    send = [ev for ev in evs
                            if self.match is None or self.match(ev)]
                    chunks = []
                    done = False
                    for ev in send:
                        data = json.dumps(ev.to_dict(), default=str)
                        chunks.append(f"id: {ev.seq}\nevent: {ev.kind}\n"
                                      f"data: {data}\n\n")
                        if self.until is not None and self.until(ev):
                            done = True
                            break
                    if chunks:
                        wfile.write("".join(chunks).encode())
                        wfile.flush()
                        REGISTRY.inc("repro_sse_frames_total",
                                     len(chunks))
                    after = evs[-1].seq
                    if self.on_cursor is not None:
                        self.on_cursor(after)
                    if done:
                        return
                elif time.monotonic() >= next_beat:
                    wfile.write(b": keep-alive\n\n")
                    wfile.flush()
                    next_beat = time.monotonic() + self.heartbeat_s
        except (BrokenPipeError, ConnectionResetError, OSError):
            return      # client went away: normal end of stream
        finally:
            REGISTRY.add_gauge("repro_sse_streams", -1)


class GatewayApi:
    """Routes HTTP requests onto the ClusterDaemon's typed command API.

    Stateless between requests: the daemon serializes every mutation
    through its command queue, so concurrent users are safe by
    construction; handlers only decide *who may ask for what*.
    """

    ROUTES: List[Tuple[str, "re.Pattern", str]] = [
        (m, re.compile(p), fn) for m, p, fn in [
            ("GET", r"^/v1/ping$", "ping"),
            ("GET", r"^/v1/profile$", "profile"),
            ("GET", r"^/v1/profile/cursors$", "profile_cursors"),
            ("GET", r"^/v1/cluster$", "cluster"),
            ("GET", r"^/v1/pods$", "pods"),
            ("POST", r"^/v1/pods$", "attach_pod"),
            ("POST", r"^/v1/pods/(?P<pod_id>\d+)/drain$", "drain_pod"),
            ("POST", r"^/v1/pods/(?P<pod_id>\d+)/detach$", "detach_pod"),
            ("POST", r"^/v1/pods/(?P<pod_id>\d+)/heartbeat$",
             "pod_heartbeat"),
            ("POST", r"^/v1/register$", "register"),
            ("POST", r"^/v1/submit$", "submit"),
            ("POST", r"^/v1/gangs$", "submit_gang"),
            ("GET", r"^/v1/blocks$", "list_blocks"),
            ("GET", r"^/v1/blocks/(?P<app_id>[\w-]+)$", "block_status"),
            ("GET", r"^/v1/blocks/(?P<app_id>[\w-]+)/events$",
             "block_events"),
            ("GET", r"^/v1/blocks/(?P<app_id>[\w-]+)/events/stream$",
             "block_events_stream"),
            ("GET", r"^/v1/blocks/(?P<app_id>[\w-]+)/download$",
             "download"),
            ("POST", r"^/v1/blocks/(?P<app_id>[\w-]+)/review$", "review"),
            ("POST", r"^/v1/blocks/(?P<app_id>[\w-]+)/confirm$",
             "confirm"),
            ("POST", r"^/v1/blocks/(?P<app_id>[\w-]+)/activate$",
             "activate"),
            ("POST", r"^/v1/blocks/(?P<app_id>[\w-]+)/run$", "run"),
            ("POST", r"^/v1/blocks/(?P<app_id>[\w-]+)/steps$", "steps"),
            ("POST", r"^/v1/blocks/(?P<app_id>[\w-]+)/autostep$",
             "autostep"),
            ("POST", r"^/v1/blocks/(?P<app_id>[\w-]+)/generate$",
             "generate"),
            ("POST", r"^/v1/blocks/(?P<app_id>[\w-]+)/preempt$",
             "preempt"),
            ("POST", r"^/v1/blocks/(?P<app_id>[\w-]+)/resume$", "resume"),
            ("POST", r"^/v1/blocks/(?P<app_id>[\w-]+)/resize$", "resize"),
            ("POST", r"^/v1/blocks/(?P<app_id>[\w-]+)/expire$", "expire"),
            ("GET", r"^/v1/events$", "global_events"),
            ("GET", r"^/v1/events/stream$", "global_events_stream"),
            ("GET", r"^/metrics$", "metrics"),
            ("GET", r"^/v1/trace$", "trace_export"),
            ("GET", r"^/v1/blocks/(?P<app_id>[\w-]+)/trace$",
             "block_trace"),
            ("GET", r"^/v1/postmortems$", "postmortems"),
            ("GET", r"^/v1/postmortems/(?P<name>[\w.\-]+)$",
             "postmortem_get"),
            ("GET", r"^/v1/access$", "access_log_report"),
            ("GET", r"^/ui/?$", "ui_index"),
            ("GET", r"^/ui/(?P<asset>[\w][\w.\-]*)$", "ui_asset"),
        ]
    ]

    #: routes served without a session (liveness probe + dashboard assets
    #: — the dashboard's *data* calls all authenticate normally; /metrics
    #: follows scrape-agent convention: no auth, but no secrets either —
    #: metric values and low-cardinality labels only)
    NO_AUTH = frozenset({"ping", "ui_index", "ui_asset", "metrics"})

    #: bounded in-memory access log (newest last)
    ACCESS_LOG_SIZE = 512

    #: the only routes that accept ?access_token= (EventSource cannot set
    #: headers); everywhere else the token must ride the Authorization
    #: header so it never lands in URLs/access logs
    QUERY_TOKEN_OK = frozenset({"block_events_stream",
                                "global_events_stream"})

    #: minimum interval between full session-snapshot writes: cursor
    #: updates ride the event hot path, and every store is a whole
    #: registry persist (fsync) — throttle, and flush on close
    SESSION_FLUSH_S = 1.0

    def __init__(self, daemon, profiles: ProfileStore,
                 rate_limiter: Optional[RateLimiter] = None,
                 static_dir: str = STATIC_DIR):
        self.daemon = daemon
        self.profiles = profiles
        self.rate_limiter = rate_limiter
        self.static_dir = static_dir
        #: set by the server on shutdown so parked SSE streams drain fast
        self.closing = threading.Event()
        # per-request access log: the HTTP server reports every finished
        # request here (status + wall latency + correlation id)
        self._access_lock = threading.Lock()
        self._access: Deque[Dict] = deque(maxlen=self.ACCESS_LOG_SIZE)
        # registry-backed session persistence: a rebuilt gateway over the
        # same daemon (or a daemon rebooted from its state snapshot)
        # rehydrates stored profiles and event-feed cursors, so sessions
        # survive the restart instead of every token going dark
        self._cursor_lock = threading.Lock()
        # serializes snapshot+store pairs: without it two persists could
        # commit out of order and leave the older snapshot on disk
        self._persist_lock = threading.Lock()
        self._sessions_dirty = False
        self._last_session_flush = float("-inf")
        stored = daemon.registry.session_snapshot()
        profiles.rehydrate(stored.get("profiles", ()))
        self._cursors: Dict[str, Dict[str, int]] = {
            t: dict(c) for t, c in (stored.get("cursors") or {}).items()}
        # the paper's per-user configuration becomes live policy
        profiles.apply_quotas(daemon.scheduler.policy)
        self._persist_sessions(force=True)

    # ------------------------------------------------------- rate limiting
    def _rate_limited(self, key: Optional[str]) -> Optional[Tuple[int,
                                                                  Dict]]:
        """Spend one token for ``key`` (None = the shared anonymous
        bucket).  Returns the 429 response when exhausted, else None."""
        if self.rate_limiter is None:
            return None
        ok, retry = self.rate_limiter.allow(key)
        if ok:
            return None
        who = "this session" if key else "unauthenticated requests"
        REGISTRY.inc("repro_http_429_total",
                     labels={"who": "session" if key else "anonymous"})
        return 429, {"error": f"rate limit exceeded for {who}",
                     "retry_after_s": round(retry, 3)}

    # ------------------------------------------------------- access logging
    def record_access(self, method: str, path: str, status: int,
                      dt_s: float, request_id: str) -> None:
        """Called by the HTTP server after every response is written.
        Never raises: a logging bug must not kill the connection
        thread."""
        try:
            with self._access_lock:
                self._access.append({
                    "t": time.time(), "method": method, "path": path,
                    "status": int(status), "ms": round(dt_s * 1e3, 3),
                    "request_id": request_id})
        except Exception:
            pass

    def access_log(self, limit: int = 100) -> List[Dict]:
        """Newest-first slice of the bounded access log."""
        with self._access_lock:
            entries = list(self._access)
        return entries[::-1][:max(1, int(limit))]

    # ----------------------------------------------------- session storage
    def _persist_sessions(self, force: bool = False) -> None:
        """Store the session state in the registry.  The snapshot handed
        over is a deep copy taken under the cursor lock — the registry
        json-serializes it later under its *own* lock, and a live
        reference would race concurrent cursor inserts.  Writes are
        throttled (every store is a full registry persist + fsync);
        ``flush_sessions`` forces the final one."""
        now = time.monotonic()
        with self._persist_lock:
            with self._cursor_lock:
                if not force and now - self._last_session_flush < \
                        self.SESSION_FLUSH_S:
                    self._sessions_dirty = True
                    return
                snap = {t: dict(c) for t, c in self._cursors.items()}
                self._sessions_dirty = False
                self._last_session_flush = now
            self.daemon.registry.store_sessions(
                {"profiles": self.profiles.snapshot(), "cursors": snap})

    def flush_sessions(self) -> None:
        """Write any throttled session state now (gateway shutdown)."""
        with self._cursor_lock:
            dirty = self._sessions_dirty
        if dirty:
            self._persist_sessions(force=True)

    def _remember_cursor(self, token: str, feed: str, after: int) -> None:
        with self._cursor_lock:
            cur = self._cursors.setdefault(token, {})
            if cur.get(feed) == after:
                return
            cur[feed] = after
        self._persist_sessions()

    def _resolve_after(self, profile: UserProfile, feed: str,
                       query: Dict[str, str]) -> int:
        raw = query.get("after", "0")
        if raw == "resume":
            with self._cursor_lock:
                return int(self._cursors.get(profile.token, {})
                           .get(feed, 0))
        try:
            return int(raw)
        except ValueError:
            raise ApiError(400, f"bad cursor {raw!r}")

    # --------------------------------------------------------------- router
    def handle(self, method: str, path: str, query: Dict[str, str],
               headers: Dict[str, str], body: bytes) -> Tuple[int, Dict]:
        try:
            payload = json.loads(body.decode() or "{}") if method == "POST" \
                else {}
        except (ValueError, UnicodeDecodeError):
            return 400, {"error": "request body is not valid JSON"}
        for m, pat, name in self.ROUTES:
            if m != method:
                continue
            match = pat.match(path)
            if match is None:
                continue
            try:
                if name == "ping":           # liveness probe: no auth
                    return 200, {"ok": True}
                if name in self.NO_AUTH:
                    # unauthenticated surfaces share the anonymous bucket
                    # — an asset flood is throttled like any other
                    hit = self._rate_limited(None)
                    if hit is not None:
                        return hit
                    return getattr(self, name)(None, match.groupdict(),
                                               payload, query)
                # browsers resume an SSE stream with Last-Event-ID; fold
                # it into the cursor query the feed handlers already read
                last_id = (headers.get("Last-Event-ID")
                           or headers.get("last-event-id"))
                if last_id and "after" not in query:
                    query = dict(query, after=last_id)
                try:
                    profile = auth.require_user(
                        headers, self.profiles,
                        query=(query if name in self.QUERY_TOKEN_OK
                               else None))
                except AuthError:
                    # a bad-token spray shares ONE anonymous bucket (a
                    # flood of invented tokens can neither fill the
                    # bucket table nor dodge the limiter via 401s)
                    hit = self._rate_limited(None)
                    if hit is not None:
                        return hit
                    raise
                hit = self._rate_limited(profile.token)
                if hit is not None:
                    return hit
                return getattr(self, name)(profile, match.groupdict(),
                                           payload, query)
            except (AuthError, ApiError) as e:
                return e.status, {"error": e.message}
            except KeyError as e:
                return 404, {"error": f"unknown application {e}"}
            except (AllocationError, ValueError, PermissionError,
                    AssertionError) as e:
                # AllocationError: pod-full is an expected, retryable
                # conflict, not an internal error
                return 409, {"error": str(e)}
        return 404, {"error": f"no route for {method} {path}"}

    # ---------------------------------------------------------- block access
    def _owned_block(self, profile: UserProfile, app_id: str):
        blk = self.daemon.registry.get(app_id)      # KeyError -> 404
        auth.require_owner(profile, blk.request.user)
        return blk

    def _status_for(self, profile: UserProfile, app_id: str) -> Dict:
        blk = self._owned_block(profile, app_id)
        st = self.daemon.status(app_id)
        # the block capability token is part of the owner's view (they
        # need it for the confirm step) but never anyone else's
        st["token"] = blk.grant.token if blk.grant else None
        return st

    # ------------------------------------------------------------- handlers
    def profile(self, profile, path_args, body, query):
        return 200, {"profile": profile.public()}

    def profile_cursors(self, profile, path_args, body, query):
        """The session's persisted event-feed cursors (feed key -> last
        served seq) — what ``after=resume`` continues from."""
        with self._cursor_lock:
            return 200, {"cursors":
                         dict(self._cursors.get(profile.token, {}))}

    def cluster(self, profile, path_args, body, query):
        return 200, self.daemon.cluster_report()

    # ------------------------------------------------------------ federation
    def pods(self, profile, path_args, body, query):
        return 200, {"pods": self.daemon.list_pods()}

    def attach_pod(self, profile, path_args, body, query):
        auth.require_admin(profile)
        try:
            pod_x = int(body["pod_x"])
            pod_y = int(body["pod_y"])
        except (KeyError, TypeError, ValueError):
            raise ApiError(400, "attach needs integer pod_x and pod_y")
        if not (1 <= pod_x <= 64 and 1 <= pod_y <= 64):
            raise ApiError(400, "pod_x/pod_y must be in [1, 64]")
        budget = body.get("power_budget_chips")
        try:
            budget = None if budget is None else float(budget)
        except (TypeError, ValueError):
            raise ApiError(400, "bad power_budget_chips")
        name = body.get("name")
        pod = self.daemon.attach_pod(
            pod_x, pod_y, name=(None if name is None else str(name)),
            power_budget_chips=budget)
        return 201, {"pod": pod}

    def _pod_id(self, path_args) -> int:
        return int(path_args["pod_id"])

    def drain_pod(self, profile, path_args, body, query):
        auth.require_admin(profile)
        pid = self._pod_id(path_args)
        try:
            return 200, {"pod": self.daemon.drain_pod(pid)}
        except KeyError:
            raise ApiError(404, f"unknown pod {pid}")

    def detach_pod(self, profile, path_args, body, query):
        auth.require_admin(profile)
        pid = self._pod_id(path_args)
        try:
            # residents + no force -> ValueError -> 409 via the router
            return 200, self.daemon.detach_pod(
                pid, force=bool(body.get("force", False)))
        except KeyError:
            raise ApiError(404, f"unknown pod {pid}")

    def pod_heartbeat(self, profile, path_args, body, query):
        auth.require_admin(profile)
        pid = self._pod_id(path_args)
        try:
            return 200, {"pod": self.daemon.pod_heartbeat(pid)}
        except KeyError:
            raise ApiError(404, f"unknown pod {pid}")

    def _submission_kwargs(self, profile: UserProfile, body: Dict) -> Dict:
        """Merge the request with the user's profile defaults.  All values
        are coerced (a JSON string where a number belongs must fail *this*
        request, not poison the waitlist for everyone), and a non-admin
        cannot outrank their own profile's priority — the profile is the
        per-user configuration the gateway enforces, not a suggestion."""
        priority = int(body.get("priority", profile.priority))
        if not profile.admin:
            priority = min(priority, profile.priority)
        deadline_s = (body["deadline_s"] if "deadline_s" in body
                      else profile.deadline_s)
        est_steps = body.get("est_steps")
        try:
            return {
                "priority": priority,
                "duration_s": float(body.get("duration_s",
                                             profile.duration_s)),
                "deadline_s": (None if deadline_s is None
                               else float(deadline_s)),
                "est_steps": (None if est_steps is None
                              else int(est_steps)),
            }
        except (TypeError, ValueError) as e:
            raise ApiError(400, f"bad submission field: {e}")

    def register(self, profile, path_args, body, query):
        if "n_chips" not in body:
            raise ApiError(400, "n_chips is required")
        kw = self._submission_kwargs(profile, body)
        app_id = self.daemon.register(
            profile.user, body.get("job_description", ""),
            int(body["n_chips"]), arch=body.get("arch", ""), **kw)
        return 201, {"app_id": app_id,
                     "state": self.daemon.status(app_id)["state"]}

    def submit(self, profile, path_args, body, query):
        if "n_chips" not in body:
            raise ApiError(400, "n_chips is required")
        kw = self._submission_kwargs(profile, body)
        auto = body.get("autostep")
        auto_kw = None
        if isinstance(auto, dict) and auto.get("enabled", True):
            # coerce *before* submitting: a malformed autostep field must
            # fail this request outright, not 400 after the block was
            # already admitted (an orphan holding chips under an app_id
            # the caller never received)
            auto_kw = self._autostep_kwargs(auto)
        app_id, grant = self.daemon.submit(
            profile.user, body.get("job_description", ""),
            int(body["n_chips"]), job=parse_job(body.get("job")), **kw)
        st = self.daemon.status(app_id)
        if auto_kw is not None and st["state"] not in ("denied", "expired"):
            # arm the engine at submission: the block autosteps from the
            # moment it is RUNNING (now, or whenever the pump admits it)
            self.daemon.autostep_enable(app_id, **auto_kw)
            st = self.daemon.status(app_id)
        return 201, {"app_id": app_id, "admitted": grant is not None,
                     "grant": _grant_dict(grant),
                     "state": st["state"],
                     "autostep": st["autostep"]}

    def submit_gang(self, profile, path_args, body, query):
        members = body.get("members")
        if not members or not isinstance(members, list):
            raise ApiError(400, "members must be a non-empty list")
        tuples = []
        for m in members:
            if "n_chips" not in m:
                raise ApiError(400, "every gang member needs n_chips")
            tuples.append((m.get("job_description", ""),
                           int(m["n_chips"]), parse_job(m.get("job"))))
        kw = self._submission_kwargs(profile, body)
        kw.pop("est_steps", None)         # gang-level estimate unsupported
        app_ids, grants = self.daemon.submit_gang(profile.user, tuples,
                                                  **kw)
        return 201, {
            "app_ids": app_ids, "admitted": grants is not None,
            "grants": ({a: _grant_dict(g) for a, g in grants.items()}
                       if grants else None)}

    def list_blocks(self, profile, path_args, body, query):
        user = None if profile.admin else profile.user
        return 200, {"blocks": self.daemon.list_apps(user=user)}

    def block_status(self, profile, path_args, body, query):
        return 200, self._status_for(profile, path_args["app_id"])

    def review(self, profile, path_args, body, query):
        auth.require_admin(profile)
        grant = self.daemon.review(
            path_args["app_id"], approve=bool(body.get("approve", True)),
            n_chips=body.get("n_chips"), pod=body.get("pod"))
        return 200, {"approved": grant is not None,
                     "grant": _grant_dict(grant)}

    def confirm(self, profile, path_args, body, query):
        app_id = path_args["app_id"]
        self._owned_block(profile, app_id)
        if "token" not in body:
            raise ApiError(400, "confirm needs the block capability token")
        self.daemon.confirm(app_id, body["token"])
        return 200, {"state": self.daemon.status(app_id)["state"]}

    def activate(self, profile, path_args, body, query):
        app_id = path_args["app_id"]
        self._owned_block(profile, app_id)
        job = parse_job(body.get("job"))
        if job is None:
            raise ApiError(400, "activate needs a job spec")
        self.daemon.activate(app_id, job)
        return 200, {"state": self.daemon.status(app_id)["state"]}

    def run(self, profile, path_args, body, query):
        app_id = path_args["app_id"]
        self._owned_block(profile, app_id)
        self.daemon.run(app_id)
        return 200, {"state": self.daemon.status(app_id)["state"]}

    def steps(self, profile, path_args, body, query):
        app_id = path_args["app_id"]
        self._owned_block(profile, app_id)
        rounds = int(body.get("rounds", 1))
        if rounds < 1 or rounds > 10000:
            raise ApiError(400, "rounds must be in [1, 10000]")
        out = self.daemon.run_steps({app_id: rounds},
                                    max_inflight=body.get("max_inflight"))
        recs = out.get(app_id, [])
        return 200, {"completed": len(recs),
                     "records": recs[-10:],
                     "steps": self.daemon.status(app_id)["steps"]}

    @staticmethod
    def _autostep_kwargs(body: Dict) -> Dict:
        """Coerce an autostep config object; raises a 400 ``ApiError``
        without touching the daemon."""
        try:
            return dict(
                max_rate_hz=(None if body.get("max_rate_hz") is None
                             else float(body["max_rate_hz"])),
                until_steps=(None if body.get("until_steps") is None
                             else int(body["until_steps"])),
                until_t=(None if body.get("until_t") is None
                         else float(body["until_t"])),
                stop_at_deadline=bool(body.get("stop_at_deadline", False)),
                ckpt_every=int(body.get("ckpt_every", 0)))
        except (TypeError, ValueError) as e:
            raise ApiError(400, f"bad autostep field: {e}")

    def autostep(self, profile, path_args, body, query):
        """Daemon-side stepping controls: ``{"enabled": true, ...config}``
        arms (or re-configures) the engine for the block, ``{"enabled":
        false}`` disarms, ``{"max_rate_hz": X}`` alone re-paces a running
        drive.  The owner controls their own block; admins any."""
        app_id = path_args["app_id"]
        self._owned_block(profile, app_id)
        enabled = bool(body.get("enabled", True))
        if not enabled:
            self.daemon.autostep_disable(
                app_id, reason=f"disabled by {profile.user}")
            return 200, {"autostep": None}
        kw = self._autostep_kwargs(body)         # 400 on malformed fields
        if set(body) == {"max_rate_hz"}:
            # a bare pace re-paces a *running* drive only — it must never
            # silently arm a fresh unbounded drive on a disarmed block
            if not self.daemon.engine.enabled(app_id):
                raise ApiError(409, "autostep is not enabled for this "
                                    "block; POST a full config to arm it")
            cfg = self.daemon.autostep_pace(app_id, kw["max_rate_hz"])
            return 200, {"autostep": cfg}
        # a terminal-state block raises ValueError -> 409 via the router
        return 200, {"autostep": self.daemon.autostep_enable(app_id, **kw)}

    def generate(self, profile, path_args, body, query):
        """Submit a generate session to a paged serve block.  Default is
        an SSE stream of the session's ``generate``/``session`` events
        (token-by-token, ending at the final token); ``{"stream": false}``
        long-polls the bus and returns the whole completion as JSON."""
        app_id = path_args["app_id"]
        self._owned_block(profile, app_id)
        prompt = body.get("prompt")
        if (not isinstance(prompt, list) or not prompt
                or not all(isinstance(t, int) and not isinstance(t, bool)
                           and t >= 0 for t in prompt)):
            raise ApiError(400, "prompt must be a non-empty list of "
                                "non-negative token ids")
        try:
            max_new = int(body.get("max_new_tokens", 16))
        except (TypeError, ValueError):
            raise ApiError(400, "bad max_new_tokens")
        if not 1 <= max_new <= 100000:
            raise ApiError(400, "max_new_tokens must be in [1, 100000]")
        eos = body.get("eos_id")
        eos = None if eos is None else int(eos)
        # cursor taken BEFORE submission: the session's first tokens can
        # land the moment the pump's next engine round runs, and a cursor
        # taken after the submit would lose them
        cursor = self.daemon.bus.latest_seq
        sid = self.daemon.generate(app_id, prompt, max_new_tokens=max_new,
                                   eos_id=eos)   # ValueError -> 409
        if not self.daemon.engine.enabled(app_id):
            # nothing decodes without a drive: arm daemon-side stepping
            self.daemon.autostep_enable(app_id)
        own = {"generate", "session"}

        def match(ev):
            return ev.payload.get("session") == sid

        def until(ev):
            return ((ev.kind == "generate" and ev.payload.get("done"))
                    or (ev.kind == "session"
                        and ev.payload.get("action") == "finished"))

        if bool(body.get("stream", True)):
            max_s = min(float(body.get("max_s", MAX_SSE_S)), MAX_SSE_S)
            return 200, SSEStream(self.daemon, cursor, app_id=app_id,
                                  kinds=own, max_s=max_s,
                                  closing=self.closing,
                                  match=match, until=until)
        timeout = min(float(body.get("timeout_s", MAX_LONGPOLL_S)),
                      MAX_LONGPOLL_S)
        deadline = time.monotonic() + timeout
        after, tokens, done = cursor, [], False
        while not done:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                break
            evs = self.daemon.wait_events(after, app_id=app_id, kinds=own,
                                          timeout=min(1.0, remaining))
            if not evs:
                continue
            after = evs[-1].seq
            for ev in evs:
                if not match(ev):
                    continue
                if ev.kind == "generate":
                    tokens.append(ev.payload["token"])
                done = done or until(ev)
        return 200, {"session": sid, "tokens": tokens, "done": done}

    def preempt(self, profile, path_args, body, query):
        auth.require_admin(profile)
        self.daemon.preempt(path_args["app_id"],
                            reason=body.get("reason",
                                            f"admin {profile.user}"))
        return 200, {"state": self.daemon.status(
            path_args["app_id"])["state"]}

    def resume(self, profile, path_args, body, query):
        auth.require_admin(profile)
        grant = self.daemon.resume(path_args["app_id"],
                                   n_chips=body.get("n_chips"))
        return 200, {"grant": _grant_dict(grant)}

    def resize(self, profile, path_args, body, query):
        auth.require_admin(profile)
        if "n_chips" not in body:
            raise ApiError(400, "resize needs n_chips")
        self.daemon.resize(path_args["app_id"], int(body["n_chips"]))
        return 200, self.daemon.status(path_args["app_id"])

    def expire(self, profile, path_args, body, query):
        app_id = path_args["app_id"]
        self._owned_block(profile, app_id)
        self.daemon.expire(app_id)
        return 200, {"state": self.daemon.status(app_id)["state"]}

    def download(self, profile, path_args, body, query):
        app_id = path_args["app_id"]
        self._owned_block(profile, app_id)
        return 200, self.daemon.download(app_id)

    # ------------------------------------------------------------ event feed
    def _feed(self, profile: UserProfile, query: Dict[str, str],
              app_id: Optional[str]) -> Tuple[int, Dict]:
        feed_key = app_id or "*"
        after = self._resolve_after(profile, feed_key, query)
        timeout = min(float(query.get("timeout_s", 0.0)), MAX_LONGPOLL_S)
        kinds = (set(query["kinds"].split(","))
                 if query.get("kinds") else None)
        if timeout > 0:
            evs = self.daemon.wait_events(after, app_id=app_id,
                                          kinds=kinds, timeout=timeout)
        else:
            evs = self.daemon.events_since(after, app_id=app_id,
                                           kinds=kinds)
        # no events -> cursor unchanged: advancing past unmatched seqs
        # could skip a matching event racing the poll
        next_after = evs[-1].seq if evs else after
        if evs:
            self._remember_cursor(profile.token, feed_key, next_after)
        return 200, {"events": [e.to_dict() for e in evs],
                     "next_after": next_after}

    def _stream(self, profile: UserProfile, query: Dict[str, str],
                app_id: Optional[str]) -> Tuple[int, SSEStream]:
        feed_key = app_id or "*"
        after = self._resolve_after(profile, feed_key, query)
        kinds = (set(query["kinds"].split(","))
                 if query.get("kinds") else None)
        max_s = min(float(query.get("max_s", MAX_SSE_S)), MAX_SSE_S)
        token = profile.token
        return 200, SSEStream(
            self.daemon, after, app_id=app_id, kinds=kinds, max_s=max_s,
            closing=self.closing,
            on_cursor=lambda seq: self._remember_cursor(token, feed_key,
                                                        seq))

    def block_events(self, profile, path_args, body, query):
        app_id = path_args["app_id"]
        self._owned_block(profile, app_id)
        return self._feed(profile, query, app_id)

    def block_events_stream(self, profile, path_args, body, query):
        app_id = path_args["app_id"]
        self._owned_block(profile, app_id)
        return self._stream(profile, query, app_id)

    def global_events(self, profile, path_args, body, query):
        auth.require_admin(profile)
        return self._feed(profile, query, None)

    def global_events_stream(self, profile, path_args, body, query):
        auth.require_admin(profile)
        return self._stream(profile, query, None)

    # -------------------------------------------------------- observability
    def metrics(self, profile, path_args, body, query):
        """Prometheus text exposition of the process-global registry."""
        return 200, StaticFile(
            REGISTRY.render().encode(),
            "text/plain; version=0.0.4; charset=utf-8")

    def trace_export(self, profile, path_args, body, query):
        """Chrome-trace JSON of every recorded span (open it in
        chrome://tracing or Perfetto)."""
        auth.require_admin(profile)
        return 200, TRACER.chrome_trace()

    def block_trace(self, profile, path_args, body, query):
        """One block's spans — the owner's view of their request's
        journey through the control plane."""
        app_id = path_args["app_id"]
        self._owned_block(profile, app_id)
        return 200, TRACER.chrome_trace(app_id=app_id)

    def postmortems(self, profile, path_args, body, query):
        auth.require_admin(profile)
        return 200, {"postmortems": RECORDER.dumps()}

    def postmortem_get(self, profile, path_args, body, query):
        auth.require_admin(profile)
        dump = RECORDER.read(path_args["name"])
        if dump is None:
            raise ApiError(404,
                           f"no postmortem {path_args['name']!r}")
        return 200, dump

    def access_log_report(self, profile, path_args, body, query):
        auth.require_admin(profile)
        try:
            limit = int(query.get("limit", 100))
        except ValueError:
            raise ApiError(400, "bad limit")
        return 200, {"access": self.access_log(limit)}

    # ------------------------------------------------------------ dashboard
    def _static(self, name: str) -> Tuple[int, object]:
        if "/" in name or ".." in name:
            raise ApiError(404, "no such asset")
        path = os.path.join(self.static_dir, name)
        try:
            with open(path, "rb") as f:
                data = f.read()
        except OSError:
            raise ApiError(404, f"no such asset {name!r}")
        ctype = _CTYPES.get(os.path.splitext(name)[1],
                            "application/octet-stream")
        return 200, StaticFile(data, ctype)

    def ui_index(self, profile, path_args, body, query):
        return self._static("index.html")

    def ui_asset(self, profile, path_args, body, query):
        return self._static(path_args["asset"])
