"""Gateway request handlers: the HTTP/JSON surface of the block lifecycle.

Routes (all JSON in/out, ``Authorization: Bearer <session token>``):

  ``POST /v1/register``              step (1): register an application
  ``POST /v1/submit``                register + automated admission
  ``POST /v1/gangs``                 atomic multi-block (gang) submission
  ``POST /v1/blocks/<id>/review``    step (2), admin: assign a block
  ``POST /v1/blocks/<id>/confirm``   step (3): reconfirm w/ capability token
  ``POST /v1/blocks/<id>/activate``  step (4): boot the runtime (job spec)
  ``POST /v1/blocks/<id>/run``       step (5): start the job
  ``POST /v1/blocks/<id>/steps``     drive N steps (event-driven dispatch)
  ``GET  /v1/blocks/<id>``           step (6): monitor one block
  ``GET  /v1/blocks/<id>/events``    step (6): long-poll live event feed
  ``GET  /v1/blocks/<id>/download``  step (7): collect results
  ``POST /v1/blocks/<id>/preempt``   admin: evict (checkpoint + release)
  ``POST /v1/blocks/<id>/resume``    admin: re-admit a preempted block
  ``POST /v1/blocks/<id>/resize``    admin: elastic grow/shrink
  ``POST /v1/blocks/<id>/expire``    owner/admin: end the usage period
  ``GET  /v1/blocks``                my blocks (admin: everyone's)
  ``GET  /v1/cluster``               pod inventory + monitor reports
  ``GET  /v1/events``                admin: global event feed (long-poll)
  ``GET  /v1/profile``               who am I / my session configuration

Request defaults (priority, deadline, duration) come from the caller's
session profile when a submission omits them — the paper's per-user
configuration files.  Job specs are dicts: ``{"kind": "sim", "step_s":
0.01}`` boots the device-free simulator; ``{"kind": "train"|"serve",
"arch": "xlstm_350m", ...}`` builds a real ``JobSpec``.
"""
from __future__ import annotations

import json
import re
from typing import Dict, List, Optional, Tuple

from repro.core.partition import AllocationError
from repro.core.runtime import JobSpec, SimJobSpec
from repro.gateway import auth
from repro.gateway.auth import AuthError
from repro.gateway.profiles import ProfileStore, UserProfile

MAX_LONGPOLL_S = 30.0


class ApiError(Exception):
    def __init__(self, status: int, message: str):
        super().__init__(message)
        self.status = status
        self.message = message


def parse_job(spec: Optional[Dict]):
    """Job-spec dict -> SimJobSpec / JobSpec (None passes through: the
    block is admitted without auto-activation)."""
    if spec is None:
        return None
    if not isinstance(spec, dict) or "kind" not in spec:
        raise ApiError(400, "job must be a dict with a 'kind'")
    kind = spec["kind"]
    if kind == "sim":
        return SimJobSpec(step_s=float(spec.get("step_s", 0.001)),
                          ckpt_every=int(spec.get("ckpt_every", 0)))
    if kind not in ("train", "serve"):
        raise ApiError(400, f"unknown job kind {kind!r}")
    # real runtimes: resolve the architecture config lazily (importing the
    # model zoo is heavy; sim-only deployments never pay it)
    import repro.configs as configs
    from repro.models.config import ShapeConfig
    from repro.train.optimizer import OptConfig
    arch = spec.get("arch")
    if not arch:
        raise ApiError(400, f"{kind} job needs an 'arch'")
    try:
        cfg = (configs.get_smoke(arch) if spec.get("smoke", True)
               else configs.get(arch))
    except KeyError:
        raise ApiError(400, f"unknown arch {arch!r}")
    shape = ShapeConfig(
        spec.get("shape_name", "gw"),
        "train" if kind == "train" else "serve",
        seq_len=int(spec.get("seq_len", 128)),
        global_batch=int(spec.get("global_batch", 4)),
        microbatch=int(spec.get("microbatch", 1)))
    opt = OptConfig(lr=float(spec.get("lr", 3e-4)),
                    warmup_steps=int(spec.get("warmup_steps", 2)),
                    total_steps=int(spec.get("total_steps", 100)))
    return JobSpec(cfg, shape, kind=kind, opt=opt,
                   seed=int(spec.get("seed", 0)))


def _grant_dict(grant) -> Optional[Dict]:
    if grant is None:
        return None
    return {"block_id": grant.block_id, "coords": list(grant.coords),
            "mesh_shape": list(grant.mesh_shape), "token": grant.token,
            "expires_at": grant.expires_at}


class GatewayApi:
    """Routes HTTP requests onto the ClusterDaemon's typed command API.

    Stateless between requests: the daemon serializes every mutation
    through its command queue, so concurrent users are safe by
    construction; handlers only decide *who may ask for what*.
    """

    ROUTES: List[Tuple[str, "re.Pattern", str]] = [
        (m, re.compile(p), fn) for m, p, fn in [
            ("GET", r"^/v1/ping$", "ping"),
            ("GET", r"^/v1/profile$", "profile"),
            ("GET", r"^/v1/cluster$", "cluster"),
            ("POST", r"^/v1/register$", "register"),
            ("POST", r"^/v1/submit$", "submit"),
            ("POST", r"^/v1/gangs$", "submit_gang"),
            ("GET", r"^/v1/blocks$", "list_blocks"),
            ("GET", r"^/v1/blocks/(?P<app_id>[\w-]+)$", "block_status"),
            ("GET", r"^/v1/blocks/(?P<app_id>[\w-]+)/events$",
             "block_events"),
            ("GET", r"^/v1/blocks/(?P<app_id>[\w-]+)/download$",
             "download"),
            ("POST", r"^/v1/blocks/(?P<app_id>[\w-]+)/review$", "review"),
            ("POST", r"^/v1/blocks/(?P<app_id>[\w-]+)/confirm$",
             "confirm"),
            ("POST", r"^/v1/blocks/(?P<app_id>[\w-]+)/activate$",
             "activate"),
            ("POST", r"^/v1/blocks/(?P<app_id>[\w-]+)/run$", "run"),
            ("POST", r"^/v1/blocks/(?P<app_id>[\w-]+)/steps$", "steps"),
            ("POST", r"^/v1/blocks/(?P<app_id>[\w-]+)/preempt$",
             "preempt"),
            ("POST", r"^/v1/blocks/(?P<app_id>[\w-]+)/resume$", "resume"),
            ("POST", r"^/v1/blocks/(?P<app_id>[\w-]+)/resize$", "resize"),
            ("POST", r"^/v1/blocks/(?P<app_id>[\w-]+)/expire$", "expire"),
            ("GET", r"^/v1/events$", "global_events"),
        ]
    ]

    def __init__(self, daemon, profiles: ProfileStore):
        self.daemon = daemon
        self.profiles = profiles
        # the paper's per-user configuration becomes live policy
        profiles.apply_quotas(daemon.scheduler.policy)

    # --------------------------------------------------------------- router
    def handle(self, method: str, path: str, query: Dict[str, str],
               headers: Dict[str, str], body: bytes) -> Tuple[int, Dict]:
        try:
            payload = json.loads(body.decode() or "{}") if method == "POST" \
                else {}
        except (ValueError, UnicodeDecodeError):
            return 400, {"error": "request body is not valid JSON"}
        for m, pat, name in self.ROUTES:
            if m != method:
                continue
            match = pat.match(path)
            if match is None:
                continue
            try:
                if name == "ping":           # liveness probe: no auth
                    return 200, {"ok": True}
                profile = auth.require_user(headers, self.profiles)
                return getattr(self, name)(profile, match.groupdict(),
                                           payload, query)
            except (AuthError, ApiError) as e:
                return e.status, {"error": e.message}
            except KeyError as e:
                return 404, {"error": f"unknown application {e}"}
            except (AllocationError, ValueError, PermissionError,
                    AssertionError) as e:
                # AllocationError: pod-full is an expected, retryable
                # conflict, not an internal error
                return 409, {"error": str(e)}
        return 404, {"error": f"no route for {method} {path}"}

    # ---------------------------------------------------------- block access
    def _owned_block(self, profile: UserProfile, app_id: str):
        blk = self.daemon.registry.get(app_id)      # KeyError -> 404
        auth.require_owner(profile, blk.request.user)
        return blk

    def _status_for(self, profile: UserProfile, app_id: str) -> Dict:
        blk = self._owned_block(profile, app_id)
        st = self.daemon.status(app_id)
        # the block capability token is part of the owner's view (they
        # need it for the confirm step) but never anyone else's
        st["token"] = blk.grant.token if blk.grant else None
        return st

    # ------------------------------------------------------------- handlers
    def profile(self, profile, path_args, body, query):
        return 200, {"profile": profile.public()}

    def cluster(self, profile, path_args, body, query):
        return 200, self.daemon.cluster_report()

    def _submission_kwargs(self, profile: UserProfile, body: Dict) -> Dict:
        """Merge the request with the user's profile defaults.  All values
        are coerced (a JSON string where a number belongs must fail *this*
        request, not poison the waitlist for everyone), and a non-admin
        cannot outrank their own profile's priority — the profile is the
        per-user configuration the gateway enforces, not a suggestion."""
        priority = int(body.get("priority", profile.priority))
        if not profile.admin:
            priority = min(priority, profile.priority)
        deadline_s = (body["deadline_s"] if "deadline_s" in body
                      else profile.deadline_s)
        est_steps = body.get("est_steps")
        try:
            return {
                "priority": priority,
                "duration_s": float(body.get("duration_s",
                                             profile.duration_s)),
                "deadline_s": (None if deadline_s is None
                               else float(deadline_s)),
                "est_steps": (None if est_steps is None
                              else int(est_steps)),
            }
        except (TypeError, ValueError) as e:
            raise ApiError(400, f"bad submission field: {e}")

    def register(self, profile, path_args, body, query):
        if "n_chips" not in body:
            raise ApiError(400, "n_chips is required")
        kw = self._submission_kwargs(profile, body)
        app_id = self.daemon.register(
            profile.user, body.get("job_description", ""),
            int(body["n_chips"]), arch=body.get("arch", ""), **kw)
        return 201, {"app_id": app_id,
                     "state": self.daemon.status(app_id)["state"]}

    def submit(self, profile, path_args, body, query):
        if "n_chips" not in body:
            raise ApiError(400, "n_chips is required")
        kw = self._submission_kwargs(profile, body)
        app_id, grant = self.daemon.submit(
            profile.user, body.get("job_description", ""),
            int(body["n_chips"]), job=parse_job(body.get("job")), **kw)
        return 201, {"app_id": app_id, "admitted": grant is not None,
                     "grant": _grant_dict(grant),
                     "state": self.daemon.status(app_id)["state"]}

    def submit_gang(self, profile, path_args, body, query):
        members = body.get("members")
        if not members or not isinstance(members, list):
            raise ApiError(400, "members must be a non-empty list")
        tuples = []
        for m in members:
            if "n_chips" not in m:
                raise ApiError(400, "every gang member needs n_chips")
            tuples.append((m.get("job_description", ""),
                           int(m["n_chips"]), parse_job(m.get("job"))))
        kw = self._submission_kwargs(profile, body)
        kw.pop("est_steps", None)         # gang-level estimate unsupported
        app_ids, grants = self.daemon.submit_gang(profile.user, tuples,
                                                  **kw)
        return 201, {
            "app_ids": app_ids, "admitted": grants is not None,
            "grants": ({a: _grant_dict(g) for a, g in grants.items()}
                       if grants else None)}

    def list_blocks(self, profile, path_args, body, query):
        user = None if profile.admin else profile.user
        return 200, {"blocks": self.daemon.list_apps(user=user)}

    def block_status(self, profile, path_args, body, query):
        return 200, self._status_for(profile, path_args["app_id"])

    def review(self, profile, path_args, body, query):
        auth.require_admin(profile)
        grant = self.daemon.review(
            path_args["app_id"], approve=bool(body.get("approve", True)),
            n_chips=body.get("n_chips"), pod=body.get("pod"))
        return 200, {"approved": grant is not None,
                     "grant": _grant_dict(grant)}

    def confirm(self, profile, path_args, body, query):
        app_id = path_args["app_id"]
        self._owned_block(profile, app_id)
        if "token" not in body:
            raise ApiError(400, "confirm needs the block capability token")
        self.daemon.confirm(app_id, body["token"])
        return 200, {"state": self.daemon.status(app_id)["state"]}

    def activate(self, profile, path_args, body, query):
        app_id = path_args["app_id"]
        self._owned_block(profile, app_id)
        job = parse_job(body.get("job"))
        if job is None:
            raise ApiError(400, "activate needs a job spec")
        self.daemon.activate(app_id, job)
        return 200, {"state": self.daemon.status(app_id)["state"]}

    def run(self, profile, path_args, body, query):
        app_id = path_args["app_id"]
        self._owned_block(profile, app_id)
        self.daemon.run(app_id)
        return 200, {"state": self.daemon.status(app_id)["state"]}

    def steps(self, profile, path_args, body, query):
        app_id = path_args["app_id"]
        self._owned_block(profile, app_id)
        rounds = int(body.get("rounds", 1))
        if rounds < 1 or rounds > 10000:
            raise ApiError(400, "rounds must be in [1, 10000]")
        out = self.daemon.run_steps({app_id: rounds},
                                    max_inflight=body.get("max_inflight"))
        recs = out.get(app_id, [])
        return 200, {"completed": len(recs),
                     "records": recs[-10:],
                     "steps": self.daemon.status(app_id)["steps"]}

    def preempt(self, profile, path_args, body, query):
        auth.require_admin(profile)
        self.daemon.preempt(path_args["app_id"],
                            reason=body.get("reason",
                                            f"admin {profile.user}"))
        return 200, {"state": self.daemon.status(
            path_args["app_id"])["state"]}

    def resume(self, profile, path_args, body, query):
        auth.require_admin(profile)
        grant = self.daemon.resume(path_args["app_id"],
                                   n_chips=body.get("n_chips"))
        return 200, {"grant": _grant_dict(grant)}

    def resize(self, profile, path_args, body, query):
        auth.require_admin(profile)
        if "n_chips" not in body:
            raise ApiError(400, "resize needs n_chips")
        self.daemon.resize(path_args["app_id"], int(body["n_chips"]))
        return 200, self.daemon.status(path_args["app_id"])

    def expire(self, profile, path_args, body, query):
        app_id = path_args["app_id"]
        self._owned_block(profile, app_id)
        self.daemon.expire(app_id)
        return 200, {"state": self.daemon.status(app_id)["state"]}

    def download(self, profile, path_args, body, query):
        app_id = path_args["app_id"]
        self._owned_block(profile, app_id)
        return 200, self.daemon.download(app_id)

    # ------------------------------------------------------------ event feed
    def _feed(self, query: Dict[str, str],
              app_id: Optional[str]) -> Tuple[int, Dict]:
        after = int(query.get("after", 0))
        timeout = min(float(query.get("timeout_s", 0.0)), MAX_LONGPOLL_S)
        kinds = (set(query["kinds"].split(","))
                 if query.get("kinds") else None)
        if timeout > 0:
            evs = self.daemon.wait_events(after, app_id=app_id,
                                          kinds=kinds, timeout=timeout)
        else:
            evs = self.daemon.events_since(after, app_id=app_id,
                                           kinds=kinds)
        # no events -> cursor unchanged: advancing past unmatched seqs
        # could skip a matching event racing the poll
        next_after = evs[-1].seq if evs else after
        return 200, {"events": [e.to_dict() for e in evs],
                     "next_after": next_after}

    def block_events(self, profile, path_args, body, query):
        app_id = path_args["app_id"]
        self._owned_block(profile, app_id)
        return self._feed(query, app_id)

    def global_events(self, profile, path_args, body, query):
        auth.require_admin(profile)
        return self._feed(query, None)
