"""Per-session token-bucket rate limiting (gateway hardening).

One bucket per session token: ``rate_per_s`` tokens flow in continuously
up to a ``burst`` cap, every handled request spends one.  An empty bucket
means 429 with a retry hint — the public cluster's gateway must survive a
misbehaving client without starving the other tenants' sessions, and the
autostep engine removes the legitimate reason to hammer ``/steps`` in a
tight loop.

Buckets are created lazily and only store two floats, so the table stays
tiny even with many sessions; unauthenticated requests share one bucket
(key ``None``) — a spray of bad tokens cannot fill the table either.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, Optional, Tuple


class RateLimiter:
    def __init__(self, rate_per_s: float, burst: Optional[int] = None):
        assert rate_per_s > 0, "rate_per_s must be positive"
        self.rate_per_s = float(rate_per_s)
        self.burst = float(burst if burst is not None
                           else max(1.0, rate_per_s))
        self._lock = threading.Lock()
        self._buckets: Dict[Optional[str], Tuple[float, float]] = {}

    def allow(self, key: Optional[str],
              now: Optional[float] = None) -> Tuple[bool, float]:
        """Spend one token for ``key``.  Returns ``(allowed,
        retry_after_s)`` — the hint is how long until one token has
        refilled (0.0 when allowed)."""
        now = now if now is not None else time.monotonic()
        with self._lock:
            tokens, last = self._buckets.get(key, (self.burst, now))
            tokens = min(self.burst, tokens + (now - last) * self.rate_per_s)
            if tokens >= 1.0:
                self._buckets[key] = (tokens - 1.0, now)
                return True, 0.0
            self._buckets[key] = (tokens, now)
            return False, (1.0 - tokens) / self.rate_per_s
