"""Version-compatibility shims for jax.

``shard_map`` moved from ``jax.experimental.shard_map`` to the top-level
``jax`` namespace, and the partial-auto parameter changed along the way:
old jax takes ``auto`` (the axes left to GSPMD), new jax takes
``axis_names`` (the axes made manual).  This module exports a single
``shard_map`` with the *new* calling convention (``axis_names``) that runs
on both, translating ``axis_names`` into ``auto`` on old versions.

On jax<=0.4 a partial-auto shard_map additionally requires
``check_rep=False`` and must be called under ``jit``; callers here already
jit their step functions, and the shim forces ``check_rep`` off whenever
any mesh axis stays auto.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax

_NATIVE = getattr(jax, "shard_map", None)
if _NATIVE is None:
    from jax.experimental.shard_map import shard_map as _LEGACY
else:
    _LEGACY = None


def shard_map(f: Optional[Callable] = None, *, mesh, in_specs, out_specs,
              axis_names=None, check_rep=None, **kwargs):
    """``jax.shard_map`` with ``axis_names`` semantics on every jax version.

    ``axis_names`` is the set of mesh axes made *manual*; every other mesh
    axis stays under GSPMD auto-sharding.  ``None`` means fully manual.
    Usable directly or via ``functools.partial`` as a decorator.
    """
    if f is None:
        return functools.partial(shard_map, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, axis_names=axis_names,
                                 check_rep=check_rep, **kwargs)
    if _NATIVE is not None:
        if axis_names is not None:
            kwargs["axis_names"] = set(axis_names)
        if check_rep is not None:
            kwargs["check_rep"] = check_rep
        return _NATIVE(f, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, **kwargs)
    mesh_axes = set(getattr(mesh, "axis_names", ()))
    if axis_names is None:
        auto = frozenset(kwargs.pop("auto", frozenset()))
    else:
        auto = frozenset(mesh_axes - set(axis_names))
    if auto:
        check_rep = False
    return _LEGACY(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   auto=auto,
                   check_rep=True if check_rep is None else check_rep,
                   **kwargs)
