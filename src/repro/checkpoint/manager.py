"""Fault-tolerant checkpointing: atomic, async, namespaced, self-describing.

Layout (one directory per step, per block namespace):

    <root>/<namespace>/step_<n>/
        manifest.json      # tree structure, shapes, dtypes, crc32 per leaf
        leaf_00000.npy ...

Writes go to ``step_<n>.tmp`` and are atomically renamed, so a crash mid-save
never corrupts the latest checkpoint.  ``save_async`` runs serialization on a
background thread (off the training critical path).  Restore re-places leaves
with any target sharding (elastic resize / failure migration re-sharding).
"""
from __future__ import annotations

import concurrent.futures as cf
import json
import os
import shutil
import zlib
from typing import Any, Dict, List, Optional

import jax
import ml_dtypes
import numpy as np

# numpy can't serialize bf16/fp8 natively: store a byte view + logical dtype
_EXOTIC = {"bfloat16", "float8_e4m3fn", "float8_e5m2"}


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, root: str, namespace: str = "default", keep: int = 3):
        self.root = root
        self.namespace = namespace
        self.keep = keep
        self.dir = os.path.join(root, namespace)
        os.makedirs(self.dir, exist_ok=True)
        self._pool = cf.ThreadPoolExecutor(max_workers=1)
        self._pending: Optional[cf.Future] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree) -> str:
        """Synchronous atomic save.  Returns the checkpoint path."""
        # Pull to host first (cheap for test-sized states; on real pods this
        # is where a sharded-save fan-out would slot in).
        host_leaves = [np.asarray(l) for l in jax.tree.leaves(tree)]
        return self._write(step, tree, host_leaves)

    def save_async(self, step: int, tree) -> None:
        """Async save: device->host copy happens now; file IO in background."""
        self.wait()
        host_leaves = [np.asarray(l) for l in jax.tree.leaves(tree)]
        self._pending = self._pool.submit(self._write, step, tree, host_leaves)

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def _write(self, step: int, tree, host_leaves: List[np.ndarray]) -> str:
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        treedef = jax.tree_util.tree_structure(tree)
        manifest = {"step": step, "treedef": str(treedef), "leaves": []}
        for i, leaf in enumerate(host_leaves):
            fname = f"leaf_{i:05d}.npy"
            logical = str(leaf.dtype)
            to_write = (leaf.view(np.uint8).reshape(*leaf.shape, -1)
                        if logical in _EXOTIC else leaf)
            np.save(os.path.join(tmp, fname), to_write)
            manifest["leaves"].append({
                "file": fname,
                "shape": list(leaf.shape),
                "dtype": logical,
                "crc32": zlib.crc32(np.ascontiguousarray(to_write).tobytes()),
            })
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()
        return final

    # --------------------------------------------------------------- restore
    def steps(self) -> List[int]:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, like_tree, step: Optional[int] = None, shardings=None,
                verify: bool = True):
        """Restore into the structure of ``like_tree``.

        ``shardings``: optional pytree (same structure) of NamedShardings —
        leaves are re-placed with them, enabling *cross-geometry* restore:
        checkpoints hold full (unsharded) host leaves, so a block saved on
        one mesh can be restored onto a different chip set, device count or
        mesh shape (elastic resize / failure migration / preemption resume)
        — each leaf is resharded onto the target mesh by ``device_put``.
        Logical leaf *shapes* must match the manifest; only placement may
        differ.
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        leaves, treedef = jax.tree_util.tree_flatten(like_tree)
        if len(manifest["leaves"]) != len(leaves):
            raise ValueError(
                f"checkpoint has {len(manifest['leaves'])} leaves, "
                f"expected {len(leaves)}")
        # flatten shardings against like_tree's structure, so a None in a
        # leaf position means "default placement" while empty subtrees
        # (e.g. a model with no decode cache) can never shift the pairing
        shard_leaves = (treedef.flatten_up_to(shardings)
                        if shardings is not None else [None] * len(leaves))
        out = []
        for meta, like, shd in zip(manifest["leaves"], leaves, shard_leaves):
            like_shape = list(getattr(like, "shape", []) or [])
            if like_shape != meta["shape"]:
                raise ValueError(
                    f"{meta['file']}: checkpoint leaf shape {meta['shape']} "
                    f"!= target shape {like_shape} — cross-geometry restore "
                    f"reshards placement onto a new mesh, it cannot change "
                    f"logical shapes (did the model config change?)")
            arr = np.load(os.path.join(path, meta["file"]))
            if verify:
                crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
                if crc != meta["crc32"]:
                    raise IOError(f"crc mismatch in {meta['file']} "
                                  f"(corrupt checkpoint {path})")
            if meta["dtype"] in _EXOTIC:
                arr = arr.view(getattr(ml_dtypes, meta["dtype"])).reshape(
                    meta["shape"])
            if shd is not None:
                out.append(jax.device_put(arr, shd))
            elif hasattr(like, "dtype"):
                out.append(jax.numpy.asarray(arr, dtype=like.dtype))
            else:   # python scalar leaf (e.g. step counters)
                out.append(arr.item() if getattr(arr, "ndim", 0) == 0 else arr)
        return jax.tree_util.tree_unflatten(treedef, out), step

    # -------------------------------------------------------------------- gc
    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)
