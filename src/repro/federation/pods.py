"""Pod registry: dynamic capacity units that join and leave at runtime.

A ``Pod`` is one unit of attachable capacity — its own single-pod
``Topology`` and its own ``Partitioner`` inventory, operating in *local*
coordinates ``(0, x, y)``.  The federation addresses chips by *global*
coordinates ``(pod_id, x, y)``; translation happens at the
``FederatedPartitioner`` boundary so each pod's allocator stays oblivious
to the pods around it (the paper's independent-block property).

Pod lifecycle is a flat phase string, deliberately separate from the block
lifecycle state machine:

    ready ──(missed heartbeats)──> degraded ──(more missed)──> dead
      │  ^──(heartbeat: false-positive grace)──┘
      └──(admin drain)──> draining ──(admin detach / health)──> gone|dead

Only ``ready`` pods receive new placements; ``draining``/``degraded`` pods
keep their residents; ``dead`` pods get their residents evicted by the
controller.  Every phase change is announced as a kind="pod" event.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional, Sequence

from repro.core.partition import Partitioner
from repro.core.topology import Coord, Topology

POD_READY = "ready"
POD_DEGRADED = "degraded"
POD_DRAINING = "draining"
POD_DEAD = "dead"
POD_PHASES = (POD_READY, POD_DEGRADED, POD_DRAINING, POD_DEAD)

# phase -> event action announced on the bus
_PHASE_ACTION = {POD_READY: "recovered", POD_DEGRADED: "degraded",
                 POD_DRAINING: "drained", POD_DEAD: "dead"}


def to_global(pod_id: int, coords: Sequence[Coord]) -> List[Coord]:
    return [(pod_id, x, y) for (_p, x, y) in coords]


def to_local(coords: Sequence[Coord]) -> List[Coord]:
    return [(0, x, y) for (_p, x, y) in coords]


@dataclasses.dataclass
class Pod:
    """One attachable capacity unit.  Mutable fields (phase, last_beat) are
    only written through ``PodRegistry`` methods under its lock."""
    pod_id: int
    name: str
    topo: Topology                 # local single-pod topology (n_pods == 1)
    part: Partitioner              # local-coordinate chip inventory
    devices: List = dataclasses.field(default_factory=list)
    phase: str = POD_READY
    joined_at: float = 0.0
    last_beat: Optional[float] = None   # None until the first heartbeat
    power_budget_chips: Optional[float] = None  # adaptive pacing budget
    boot: bool = False             # carved from the boot topology

    @property
    def n_chips(self) -> int:
        return self.topo.n_chips

    def describe(self) -> Dict:
        return {
            "pod_id": self.pod_id, "name": self.name,
            "pod_x": self.topo.pod_x, "pod_y": self.topo.pod_y,
            "n_chips": self.n_chips,
            "free_chips": len(self.part.free_chips()),
            "phase": self.phase, "joined_at": self.joined_at,
            "last_beat": self.last_beat,
            "power_budget_chips": self.power_budget_chips,
            "boot": self.boot,
        }


class PodRegistry:
    """Thread-safe pod directory.  Attach/detach/phase changes mutate the
    directory under ``_lock`` and publish kind="pod" events after releasing
    it (so the bus's subscriber chain never runs under a registry lock)."""

    def __init__(self, bus=None):
        self._lock = threading.RLock()
        self._pods: Dict[int, Pod] = {}
        self._next_id = 0
        self.bus = bus

    # -------------------------------------------------------------- attach
    def attach(self, pod_x: int, pod_y: int, devices: Sequence,
               name: Optional[str] = None,
               power_budget_chips: Optional[float] = None,
               boot: bool = False, pod_id: Optional[int] = None,
               now: Optional[float] = None) -> Pod:
        topo = Topology(n_pods=1, pod_x=pod_x, pod_y=pod_y)
        if len(devices) < topo.n_chips:
            raise ValueError(
                f"pod needs {topo.n_chips} devices, have {len(devices)}")
        t = now if now is not None else time.time()
        with self._lock:
            pid = pod_id if pod_id is not None else self._next_id
            if pid in self._pods:
                raise ValueError(f"pod {pid} already attached")
            self._next_id = max(self._next_id, pid) + 1
            pod = Pod(pod_id=pid, name=name or f"pod{pid}", topo=topo,
                      part=Partitioner(topo), devices=list(devices),
                      joined_at=t, power_budget_chips=power_budget_chips,
                      boot=boot)
            self._pods[pid] = pod
        self._publish("joined", pod, now=t)
        return pod

    def detach(self, pod_id: int, now: Optional[float] = None) -> Pod:
        """Remove a pod from the directory.  The caller (controller) is
        responsible for having evicted or migrated its residents first."""
        with self._lock:
            pod = self._pods.pop(pod_id)       # KeyError -> unknown pod
        self._publish("left", pod, now=now)
        return pod

    def set_phase(self, pod_id: int, phase: str,
                  now: Optional[float] = None) -> Pod:
        assert phase in POD_PHASES, phase
        with self._lock:
            pod = self._pods[pod_id]
            changed = pod.phase != phase
            pod.phase = phase
        if changed:
            self._publish(_PHASE_ACTION[phase], pod, now=now)
        return pod

    def beat(self, pod_id: int, now: Optional[float] = None) -> Pod:
        t = now if now is not None else time.time()
        with self._lock:
            pod = self._pods[pod_id]
            pod.last_beat = t
        return pod

    # --------------------------------------------------------------- reads
    def get(self, pod_id: int) -> Optional[Pod]:
        with self._lock:
            return self._pods.get(pod_id)

    def pod(self, pod_id: int) -> Pod:
        with self._lock:
            return self._pods[pod_id]          # KeyError -> unknown pod

    def pods(self) -> List[Pod]:
        """All pods (any phase), pod_id order."""
        with self._lock:
            return [self._pods[k] for k in sorted(self._pods)]

    def live(self) -> List[Pod]:
        """Pods that still hold capacity (everything but dead)."""
        return [p for p in self.pods() if p.phase != POD_DEAD]

    def placeable(self) -> List[Pod]:
        """Pods eligible for *new* placements."""
        return [p for p in self.pods() if p.phase == POD_READY]

    def total_chips(self) -> int:
        return sum(p.n_chips for p in self.live())

    def describe_all(self) -> List[Dict]:
        return [p.describe() for p in self.pods()]

    def snapshot(self) -> List[Dict]:
        """Persistable pod directory state (no devices — those are rebuilt
        on attach).  Round-trips through ``Registry`` under the reserved
        ``"_pods"`` key."""
        out = []
        for p in self.pods():
            out.append({
                "pod_id": p.pod_id, "name": p.name,
                "pod_x": p.topo.pod_x, "pod_y": p.topo.pod_y,
                "phase": p.phase, "joined_at": p.joined_at,
                "power_budget_chips": p.power_budget_chips,
                "boot": p.boot,
            })
        return out

    # ------------------------------------------------------------- events
    def _publish(self, action: str, pod: Pod,
                 now: Optional[float] = None) -> None:
        if self.bus is None:
            return
        self.bus.publish("pod", now=now, action=action, pod=pod.pod_id,
                         name=pod.name, phase=pod.phase, n_chips=pod.n_chips)
