"""Elastic multi-pod federation — dynamic capacity for the control plane.

The paper's public cluster is built from independent node blocks that are
attached, carved up, and retired while the control plane keeps serving
everyone else (arXiv:0708.3446, and the openPC toolkit, arXiv:1012.2499).
This package is that elasticity for the TPU reproduction:

* ``PodRegistry`` / ``Pod`` — pods register and deregister with the daemon
  at runtime; each pod is its own single-pod ``Topology`` plus its own
  ``Partitioner`` inventory (pods.py);
* ``FederatedPartitioner`` — a drop-in ``Partitioner`` facade that carves
  rectangles across every attached pod, so the controller/scheduler keep
  their single-partitioner API (partition.py);
* ``HealthMonitor`` — heartbeat-fed pod health with a false-positive grace
  period; dead pods get their residents evicted into PREEMPTED and migrated
  toward surviving capacity via cross-geometry checkpoint restore
  (health.py);
* ``FederatedPlacer`` — per-pod placement scoring (free capacity, health,
  gang locality) plus the interference penalty that wires
  ``core/interference.py`` into admission (placer.py).
"""
from repro.federation.health import HealthMonitor
from repro.federation.partition import FederatedPartitioner
from repro.federation.placer import FederatedPlacer
from repro.federation.pods import (POD_DEAD, POD_DEGRADED, POD_DRAINING,
                                   POD_PHASES, POD_READY, Pod, PodRegistry)

__all__ = [
    "FederatedPartitioner", "FederatedPlacer", "HealthMonitor", "Pod",
    "PodRegistry", "POD_READY", "POD_DEGRADED", "POD_DRAINING", "POD_DEAD",
    "POD_PHASES",
]
