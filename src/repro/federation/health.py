"""Heartbeat-fed pod health with a false-positive grace period.

Pods attached over the gateway are expected to heartbeat
(``POST /v1/pods/<id>/heartbeat``).  A pod that has *ever* heartbeat is
monitored; boot pods (and sim pods nobody heartbeats) never decay — the
daemon cannot tell "no agent" from "dead agent", so silence only counts
against pods that once spoke.

Decay is two-stage, which is the grace period:

    ready --(degraded_after_s silent)--> degraded
    degraded --(heartbeat)--> ready          (false positive cleared)
    degraded --(dead_after_s silent)--> dead (controller evicts residents)

``degraded`` pods stop receiving new placements (the placer only considers
``ready`` pods) but keep their residents running — nothing is evicted on a
single missed heartbeat.  Only ``dead`` triggers migration.
"""
from __future__ import annotations

import time
from typing import List, Optional

from repro.federation.pods import (POD_DEAD, POD_DEGRADED, POD_READY,
                                   Pod, PodRegistry)


class HealthMonitor:
    """Stateless policy over the ``PodRegistry`` — all health state lives
    on the pods themselves (``last_beat``, ``phase``), so a registry
    snapshot carries it for free."""

    def __init__(self, pods: PodRegistry,
                 degraded_after_s: float = 5.0,
                 dead_after_s: float = 15.0):
        self.pods = pods
        self.degraded_after_s = degraded_after_s
        self.dead_after_s = dead_after_s

    def beat(self, pod_id: int, now: Optional[float] = None) -> Pod:
        """Record a heartbeat; a degraded pod recovers (false positive)."""
        t = now if now is not None else time.time()
        pod = self.pods.beat(pod_id, t)        # KeyError -> unknown pod
        if pod.phase == POD_DEGRADED:
            pod = self.pods.set_phase(pod_id, POD_READY, now=t)
        return pod

    def check(self, now: Optional[float] = None) -> List[int]:
        """Advance decay; returns pod ids newly declared dead so the
        controller can evict and migrate their residents."""
        t = now if now is not None else time.time()
        died: List[int] = []
        for pod in self.pods.pods():
            if pod.last_beat is None or pod.phase == POD_DEAD:
                continue
            age = t - pod.last_beat
            if age >= self.dead_after_s:
                self.pods.set_phase(pod.pod_id, POD_DEAD, now=t)
                died.append(pod.pod_id)
            elif age >= self.degraded_after_s and pod.phase == POD_READY:
                self.pods.set_phase(pod.pod_id, POD_DEGRADED, now=t)
        return died
