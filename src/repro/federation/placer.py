"""Per-pod placement scoring for federated admission.

The placer answers two questions for the ``FederatedPartitioner``:

* **which pod first?** — ``order()`` ranks placeable pods by free capacity
  (most-free first, stable by pod id), spreading load across the
  federation so a newly joined pod immediately attracts the waitlist;
* **is this rectangle a good neighbour?** — ``rect_penalty()`` predicts
  the cross-block interference a candidate rectangle would create against
  the pod's residents using the seed link-contention model
  (``core/interference.py``: ``analyze_blocks`` ring-collective footprints;
  ``bisection_bandwidth`` is the same model's bandwidth view).  Candidates
  whose predicted worst-case slowdown exceeds ``max_slowdown`` are
  *deprioritized*, never rejected — a penalized rectangle is still used
  when it is the only way to admit.  ``interference_penalty=False``
  disables the scoring entirely (the knob the satellite task requires).

Gang locality is the third scoring input: with ``allow_gang_split=False``
(the default) a gang's unpinned members are only placed when one pod fits
all of them, so co-scheduled blocks never straddle the DCN.
"""
from __future__ import annotations

from typing import List, Sequence

from repro.core.interference import analyze_blocks
from repro.core.topology import Coord
from repro.federation.pods import Pod

# ownership tags that are not real resident blocks (grant reservations are
# real — they are about to become blocks — so they stay in the model)
_CANDIDATE = "__candidate__"


class FederatedPlacer:
    def __init__(self, interference_penalty: bool = True,
                 max_slowdown: float = 1.0,
                 allow_gang_split: bool = False):
        self.interference_penalty = interference_penalty
        self.max_slowdown = max_slowdown
        self.allow_gang_split = allow_gang_split

    def order(self, pods: Sequence[Pod]) -> List[Pod]:
        """Placement order: most free capacity first, then pod id."""
        return sorted(pods, key=lambda p: (-len(p.part.free_chips()),
                                           p.pod_id))

    def rect_penalty(self, pod: Pod, coords: Sequence[Coord]) -> float:
        """Predicted interference cost of placing this rectangle in this
        pod: 0.0 when the candidate stays within the slowdown threshold
        against every resident, else how far past the threshold the worst
        block lands.  Coordinates are pod-local."""
        if not self.interference_penalty:
            return 0.0
        placements = pod.part.placements()
        placements[_CANDIDATE] = list(coords)
        if len(placements) == 1:
            return 0.0                   # empty pod: nothing to interfere
        rep = analyze_blocks(pod.topo, placements)
        worst = max(rep.slowdown.values())
        return max(0.0, worst - self.max_slowdown)
