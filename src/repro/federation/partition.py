"""FederatedPartitioner — the single-partitioner API over many pods.

The controller and scheduler were written against one ``Partitioner``; the
federation keeps that contract.  This facade implements the same surface
(allocate / can_fit / allocate_many / can_fit_many / resize / retag /
release / ...) by fanning out to each attached pod's own inventory, with
two twists:

* **coordinates are global** — callers see ``(pod_id, x, y)``; each pod's
  ``Partitioner`` only ever sees its local ``(0, x, y)`` frame;
* **pod choice is scored** — the ``FederatedPlacer`` orders placeable pods
  (free capacity, health via the placeable filter, gang locality) and
  deprioritizes rectangles whose predicted interference against resident
  blocks exceeds the threshold.

Gang semantics: unpinned gang members are co-placed inside one pod unless
the placer's ``allow_gang_split`` knob is set — co-scheduled blocks talk,
and the DCN link between pods is the one link rectangles cannot own.
Cross-pod ``resize`` doubles as migration: when the home pod cannot grow a
block (or is dead), the replacement rectangle is carved from another pod
and ownership moves atomically.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.partition import AllocationError
from repro.core.topology import Coord, rect_coords
from repro.federation.placer import FederatedPlacer
from repro.federation.pods import POD_READY, Pod, PodRegistry, to_global


class FederatedPartitioner:
    """Drop-in ``Partitioner`` facade over the pod federation.  Holds no
    lock of its own: each pod's inventory is internally locked, and every
    multi-pod mutation runs under the daemon's control-plane serialization
    (the "thin federation layer" — cross-pod decisions are serialized by
    construction)."""

    def __init__(self, pods: PodRegistry,
                 placer: Optional[FederatedPlacer] = None):
        self.pods = pods
        self.placer = placer or FederatedPlacer()

    # ------------------------------------------------------------- helpers
    def _alloc_pods(self, pod: Optional[int]) -> List[Pod]:
        """Pods eligible to receive this placement, in placer order."""
        if pod is not None:
            p = self.pods.get(pod)
            return [p] if p is not None and p.phase == POD_READY else []
        return self.placer.order(self.pods.placeable())

    # ----------------------------------------------------------- inventory
    @property
    def chips(self) -> Dict[Coord, object]:
        """Merged chip inventory under global coordinates (read-only
        snapshot view — ``Partitioner.chips`` drop-in for inspection)."""
        out: Dict[Coord, object] = {}
        for p in self.pods.pods():
            for c, info in p.part.chips.items():
                out[(p.pod_id,) + c[1:]] = info
        return out

    def free_chips(self, pod: Optional[int] = None) -> List[Coord]:
        if pod is not None:
            p = self.pods.get(pod)
            return to_global(pod, p.part.free_chips()) if p else []
        out: List[Coord] = []
        for p in self.pods.placeable():
            out.extend(to_global(p.pod_id, p.part.free_chips()))
        return out

    def owner_of(self, coord: Coord) -> Optional[str]:
        return self.pods.pod(coord[0]).part.owner_of((0,) + coord[1:])

    def mark_unhealthy(self, coord: Coord) -> Optional[str]:
        return self.pods.pod(coord[0]).part.mark_unhealthy((0,) + coord[1:])

    def mark_healthy(self, coord: Coord) -> None:
        self.pods.pod(coord[0]).part.mark_healthy((0,) + coord[1:])

    # ------------------------------------------------------------ allocate
    def allocate(self, n_chips: int, block_id: str,
                 pod: Optional[int] = None) -> List[Coord]:
        """First fit across pods in placer order, preferring the first
        zero-interference rectangle; a penalized rectangle is still used
        when nothing better exists anywhere."""
        pods = self._alloc_pods(pod)
        best: Optional[Tuple[float, int, Pod]] = None
        for idx, p in enumerate(pods):
            try:
                found = p.part._find_rect(n_chips, 0)   # racy-ok dry probe
            except AllocationError:
                continue                                # shape never fits p
            if found is None:
                continue
            pen = self.placer.rect_penalty(p, rect_coords(*found))
            if best is None or (pen, idx) < (best[0], best[1]):
                best = (pen, idx, p)
            if pen <= 0.0:
                break
        if best is None:
            raise AllocationError(
                f"no contiguous {n_chips}-chip rectangle free in any "
                f"placeable pod ({len(pods)} pods, "
                f"free={len(self.free_chips(pod))})")
        coords = best[2].part.allocate(n_chips, block_id, pod=0)
        return to_global(best[2].pod_id, coords)

    def can_fit(self, n_chips: int, pod: Optional[int] = None) -> bool:
        return any(p.part.can_fit(n_chips, 0) for p in self._alloc_pods(pod))

    def allocate_many(self, specs: Sequence[Tuple[int, str, Optional[int]]]
                      ) -> Dict[str, List[Coord]]:
        """Gang allocation, all-or-nothing across the federation.  Pinned
        members go to their pod; unpinned members are co-placed inside one
        pod unless the placer allows gang splits."""
        placed: Dict[str, List[Coord]] = {}
        try:
            unpinned: List[Tuple[int, str]] = []
            for n_chips, block_id, pod in specs:
                if block_id in placed or any(b == block_id
                                             for _n, b in unpinned):
                    raise AllocationError(
                        f"duplicate gang block id {block_id}")
                if pod is not None:
                    placed[block_id] = self.allocate(n_chips, block_id,
                                                     pod=pod)
                else:
                    unpinned.append((n_chips, block_id))
            if unpinned:
                if self.placer.allow_gang_split:
                    for n_chips, block_id in unpinned:
                        placed[block_id] = self.allocate(n_chips, block_id)
                else:
                    placed.update(self._gang_one_pod(unpinned))
        except AllocationError:
            for block_id in placed:
                self.release(block_id)
            raise
        return placed

    def _gang_one_pod(self, specs: Sequence[Tuple[int, str]]
                      ) -> Dict[str, List[Coord]]:
        """Place every (n_chips, block_id) inside a single pod, trying pods
        in placer order; rolls the pod back between attempts."""
        for p in self._alloc_pods(None):
            placed: Dict[str, List[Coord]] = {}
            ok = True
            for n_chips, block_id in specs:
                try:
                    coords = p.part.allocate(n_chips, block_id, pod=0)
                except AllocationError:
                    ok = False
                    break
                placed[block_id] = to_global(p.pod_id, coords)
            if ok:
                return placed
            for block_id in placed:
                p.part.release(block_id)
        raise AllocationError(
            f"gang of {len(specs)} members fits no single pod "
            f"(gang split disabled)")

    def can_fit_many(self, specs: Sequence[Tuple[int, Optional[int]]],
                     freed_block_ids: Sequence[str] = ()) -> bool:
        """Gang admission dry-run (optionally a preemption what-if): runs
        the real ``allocate_many`` under temporary ids with the freed
        blocks' chips suspended, then rolls everything back — so the answer
        agrees with the commit path by construction."""
        saved = [(p, p.part.suspend_owners(freed_block_ids))
                 for p in self.pods.pods()]
        dry = [(n, f"_fdry_{i}", pod) for i, (n, pod) in enumerate(specs)]
        try:
            try:
                placed = self.allocate_many(dry)
            except AllocationError:
                return False
            for block_id in placed:
                self.release(block_id)
            return True
        finally:
            for p, s in saved:
                p.part.restore_owners(s)

    def can_fit_excluding(self, n_chips: int, freed_block_ids: Sequence[str],
                          pod: Optional[int] = None) -> bool:
        return self.can_fit_many([(n_chips, pod)], freed_block_ids)

    def shape_possible(self, n_chips: int) -> bool:
        """Could this request ever fit some live pod's geometry?"""
        return any(p.part.shape_possible(n_chips) for p in self.pods.live())

    def free_capacity(self, pod: Optional[int] = None) -> int:
        return len(self.free_chips(pod))

    def retag(self, old_id: str, new_id: str) -> int:
        return sum(p.part.retag(old_id, new_id) for p in self.pods.pods())

    def release(self, block_id: str) -> int:
        return sum(p.part.release(block_id) for p in self.pods.pods())

    def owned_by(self, block_id: str) -> List[Coord]:
        out: List[Coord] = []
        for p in self.pods.pods():
            out.extend(to_global(p.pod_id, p.part.owned_by(block_id)))
        return out

    def placements(self) -> Dict[str, List[Coord]]:
        out: Dict[str, List[Coord]] = {}
        for p in self.pods.pods():
            for block_id, coords in p.part.placements().items():
                out.setdefault(block_id, []).extend(
                    to_global(p.pod_id, coords))
        return out

    # ------------------------------------------------------------- elastic
    def resize(self, block_id: str, new_n_chips: int,
               pod: Optional[int] = None) -> List[Coord]:
        """Grow/shrink in place when the home pod can, else migrate: carve
        the replacement rectangle from another placeable pod and move
        ownership.  On failure the block keeps its old chips."""
        home: Optional[Pod] = None
        for p in self.pods.pods():
            if p.part.owned_by(block_id):
                home = p
                break
        if (home is not None and home.phase == POD_READY
                and (pod is None or pod == home.pod_id)):
            try:
                return to_global(home.pod_id,
                                 home.part.resize(block_id, new_n_chips, 0))
            except AllocationError:
                pass                          # fall through to migration
        tmp = f"_fmove_{block_id}"
        coords = self.allocate(new_n_chips, tmp, pod=pod)   # may raise
        self.release(block_id)
        self.retag(tmp, block_id)
        return coords

    # ---------------------------------------------------------- invariants
    def check_invariants(self) -> None:
        seen: Dict[str, int] = {}
        for p in self.pods.pods():
            p.part.check_invariants()
            for block_id in p.part.placements():
                if block_id in seen:
                    raise AssertionError(
                        f"block {block_id} owns chips in pods "
                        f"{seen[block_id]} and {p.pod_id}")
                seen[block_id] = p.pod_id
