"""Autostep execution engine — daemon-side stepping of RUNNING blocks.

The paper's public cluster runs jobs through per-user daemons: once a
block is RUNNING, the *cluster* makes it progress — the user only watches
(openPC, arXiv:1012.2499, gives the daemon full ownership of job
execution).  Before this package the repo's daemon only ticked: RUNNING
blocks advanced solely when a client POSTed ``/steps``.  The
``AutostepEngine`` closes that gap: an opt-in per-block autostep loop
driven from the ``ClusterDaemon`` pump thread (or inline, deterministically,
for tests) that keeps each enabled block's in-flight dispatch window fed,
paced by a pluggable ``PacingPolicy``.
"""
from repro.engine.autostep import AutostepConfig, AutostepEngine
from repro.engine.pacing import BlockView, PacingPolicy

__all__ = ["AutostepConfig", "AutostepEngine", "BlockView", "PacingPolicy"]
