"""AutostepEngine — the daemon-owned autostep loop.

One engine instance hangs off the ``ClusterDaemon``'s controller.  A block
opts in with ``enable()`` (directly, via the daemon's ``autostep_*``
commands, or over the gateway's ``POST /v1/blocks/<id>/autostep``); from
then on the engine keeps the block's in-flight dispatch window fed from
every ``run_round()`` — the daemon pump calls it between commands, so
RUNNING blocks make progress with **zero** client ``POST /steps`` traffic.

Each round:

1. harvest completed steps from every enabled RUNNING block (non-blocking
   ``poll``) and publish them as ``step`` events — identical payloads to
   client-driven dispatch, so the Monitor's accounting cannot tell the
   difference;
2. write periodic checkpoints (``ckpt_every``) and apply run-until
   termination: a block that reaches ``until_steps`` drains its window and
   transitions to DONE; one that reaches ``until_t`` (or its own SLO
   deadline with ``stop_at_deadline``) stops dispatching and disarms;
3. plan new dispatches with the ``PacingPolicy`` (weighted fair
   interleave + per-block token-bucket rate caps) under the existing
   in-flight-window backpressure (``scheduler.max_inflight``).

Preemption interplay: the controller calls ``drain_block()`` before
suspending an engine-driven victim, so in-flight completions are harvested
and *published* rather than silently discarded; the drive config survives
the eviction and the engine re-arms automatically when the block resumes
to RUNNING.

Determinism: the engine mutates nothing unless a block is enabled, and
``run_round(now=...)`` keeps every published event on the model clock —
the daemon's deterministic inline mode (tests, ``benchmarks/
policy_admission.py``) is bit-for-bit unchanged unless a test drives
rounds itself.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

from repro.analysis import runtime_check
from repro.core.block import BlockState
from repro.engine.pacing import BlockView, PacingPolicy
from repro.obs.metrics import REGISTRY
from repro.obs.trace import TRACER

#: lifecycle states from which a block can never run again — the engine
#: drops its drive (an EXPIRED/DONE block re-enabled later starts fresh)
_TERMINAL = (BlockState.DONE, BlockState.EXPIRED, BlockState.FAILED,
             BlockState.DENIED)


@dataclasses.dataclass
class AutostepConfig:
    max_rate_hz: Optional[float] = None   # per-block step-rate cap
    until_steps: Optional[int] = None     # stop + DONE at this step_count
    until_t: Optional[float] = None       # stop dispatching at this time
    stop_at_deadline: bool = False        # treat the block's SLO deadline
                                          # as an until_t
    ckpt_every: int = 0                   # periodic checkpoint interval
                                          # (0 = the job spec's, if any)

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class _Drive:
    """Per-block engine state: the opt-in config plus pacing bookkeeping
    and a cached identity snapshot so publishing a step event costs no
    registry work."""
    config: AutostepConfig
    user: str = ""
    block_id: Optional[str] = None
    n_chips: int = 0
    priority: int = 0
    pod: Optional[int] = None             # grant's federation pod
    deficit: float = 0.0                  # PacingPolicy credit
    allowance: float = 1.0                # token bucket (rate cap)
    last_refill: Optional[float] = None
    steps_driven: int = 0
    derived_rate_hz: Optional[float] = None  # adaptive (budget-derived) cap


class AutostepEngine:
    def __init__(self, ctl, policy: Optional[PacingPolicy] = None):
        self.ctl = ctl
        self.policy = policy or PacingPolicy()
        self._drives: Dict[str, _Drive] = {}
        self.steps_driven = 0            # completions harvested, lifetime
        #: True when the last round dispatched/harvested or left work in
        #: flight — the pump uses it to pick its idle timeout
        self.last_round_busy = False

    # ------------------------------------------------------------- opt-in
    @property
    def armed(self) -> bool:
        return bool(self._drives)

    def enabled(self, app_id: str) -> bool:
        return app_id in self._drives

    def enable(self, app_id: str, max_rate_hz: Optional[float] = None,
               until_steps: Optional[int] = None,
               until_t: Optional[float] = None,
               stop_at_deadline: bool = False,
               ckpt_every: int = 0,
               now: Optional[float] = None) -> Dict:
        """Arm (or re-configure) autostep for one block.  Legal in any
        non-terminal state — a queued or preempted block starts stepping
        the moment it is RUNNING."""
        blk = self.ctl.registry.get(app_id)          # KeyError -> caller 404
        if blk.state in _TERMINAL:
            raise ValueError(
                f"cannot autostep {app_id}: block is {blk.state.value}")
        cfg = AutostepConfig(max_rate_hz=max_rate_hz,
                             until_steps=until_steps, until_t=until_t,
                             stop_at_deadline=stop_at_deadline,
                             ckpt_every=int(ckpt_every or 0))
        drive = self._drives.get(app_id)
        if drive is None:
            drive = self._drives[app_id] = _Drive(config=cfg)
        else:
            drive.config = cfg
        drive.user = blk.request.user
        drive.priority = blk.request.priority
        self._refresh_grant(drive, blk)
        self.ctl.bus.publish("autostep", app_id=app_id,
                             block_id=drive.block_id, user=drive.user,
                             now=now, action="enabled", **cfg.to_dict())
        return self.describe(app_id)

    def disable(self, app_id: str, reason: str = "disabled",
                now: Optional[float] = None) -> bool:
        drive = self._drives.pop(app_id, None)
        if drive is None:
            return False
        self.ctl.bus.publish("autostep", app_id=app_id,
                             block_id=drive.block_id, user=drive.user,
                             now=now, action="disabled", reason=reason,
                             steps_driven=drive.steps_driven)
        return True

    def set_pace(self, app_id: str, max_rate_hz: Optional[float],
                 now: Optional[float] = None) -> Dict:
        drive = self._drives.get(app_id)
        if drive is None:
            raise KeyError(app_id)
        drive.config.max_rate_hz = (None if max_rate_hz is None
                                    else float(max_rate_hz))
        drive.allowance = min(drive.allowance, 1.0)
        self.ctl.bus.publish("autostep", app_id=app_id,
                             block_id=drive.block_id, user=drive.user,
                             now=now, action="paced",
                             max_rate_hz=drive.config.max_rate_hz)
        return self.describe(app_id)

    def describe(self, app_id: str) -> Optional[Dict]:
        """Public autostep view for one block (``None`` = not enabled) —
        what the daemon's ``status()`` and the dashboard serve."""
        drive = self._drives.get(app_id)
        if drive is None:
            return None
        return {"enabled": True, "steps_driven": drive.steps_driven,
                "derived_rate_hz": drive.derived_rate_hz,
                **drive.config.to_dict()}

    # ------------------------------------------------------------- driving
    def _refresh_grant(self, drive: _Drive, blk) -> None:
        if blk.grant is not None:
            drive.block_id = blk.grant.block_id
            drive.n_chips = blk.grant.n_chips
            if blk.grant.coords:
                drive.pod = blk.grant.coords[0][0]
        drive.priority = blk.request.priority

    def _publish_step(self, app_id: str, drive: _Drive, rec: Dict,
                      now: Optional[float]) -> None:
        # identical payload to scheduler.run_dispatch's on_step: the
        # Monitor (and any feed consumer) sees the same stream whether the
        # client or the engine drove the step
        metrics = {k: v for k, v in rec.items() if k != "step_s"}
        self.ctl.bus.publish("step", app_id=app_id,
                             block_id=drive.block_id, user=drive.user,
                             now=now, step_s=rec["step_s"],
                             n_chips=drive.n_chips, metrics=metrics or None)
        drive.steps_driven += 1
        self.steps_driven += 1

    def _harvest_generate(self, app_id: str, drive: _Drive, rt,
                          now: Optional[float]) -> int:
        """Publish a paged serve block's buffered continuous-batching
        emissions: one ``generate`` event per token, one ``session`` event
        per lifecycle edge (admitted/evicted/finished).  The gateway's
        generate endpoint streams exactly these off the bus."""
        harvest = getattr(rt, "harvest", None)
        if harvest is None:
            return 0
        ems = harvest()
        for em in ems:
            detail = {k: v for k, v in em.items()
                      if k not in ("event", "session")}
            if em["event"] == "token":
                self.ctl.bus.publish("generate", app_id=app_id,
                                     block_id=drive.block_id,
                                     user=drive.user, now=now,
                                     session=em["session"], **detail)
            else:
                self.ctl.bus.publish("session", app_id=app_id,
                                     block_id=drive.block_id,
                                     user=drive.user, now=now,
                                     action=em["event"],
                                     session=em["session"], **detail)
        return len(ems)

    def _maybe_checkpoint(self, drive: _Drive, rt) -> None:
        """Periodic checkpoint under autostep (client-driven drivers used
        to call ``daemon.save`` themselves between step batches).  Only
        runtimes with a checkpoint surface participate — SimRuntime keeps
        its own ``ckpt_every`` accounting."""
        every = drive.config.ckpt_every or getattr(
            getattr(rt, "job", None), "ckpt_every", 0)
        if not every:
            return
        save = getattr(rt, "save", None)
        if save is None:
            return
        if rt.step_count - getattr(rt, "last_saved_step", 0) >= every:
            save(async_=True)

    def _until_t(self, drive: _Drive, blk) -> Optional[float]:
        t = drive.config.until_t
        if drive.config.stop_at_deadline and blk.deadline_at is not None:
            t = blk.deadline_at if t is None else min(t, blk.deadline_at)
        return t

    def _slack_s(self, blk, now: float) -> Optional[float]:
        """Effective deadline slack (time-to-deadline minus estimated
        remaining service time) — same notion the scheduler's waitlist
        ordering uses, feeding the policy's deadline boost."""
        if blk.deadline_at is None:
            return None
        slack = blk.deadline_at - now
        est = blk.request.est_steps
        if est:
            mon = self.ctl.monitor
            step_s = mon.step_time_estimate(blk.block_id)
            if step_s:
                slack -= max(0, est - mon.steps_done(blk.block_id)) * step_s
        return slack

    def drain_block(self, app_id: str, now: Optional[float] = None) -> int:
        """Harvest (and publish) every in-flight completion of an
        engine-driven block.  The controller calls this before suspending
        a victim so the eviction hides no finished work; the drive stays
        armed and re-arms automatically on resume."""
        drive = self._drives.get(app_id)
        rt = self.ctl.runtimes.get(app_id)
        if drive is None or rt is None:
            return 0
        recs = rt.drain()
        for rec in recs:
            self._publish_step(app_id, drive, rec, now)
        self._harvest_generate(app_id, drive, rt, now)
        return len(recs)

    def _pod_budget_shares(self) -> Dict[int, float]:
        """Per-pod power budget split evenly across that pod's runnable
        engine-driven blocks: pod_id -> chips-per-block share.  Pods
        without a declared ``power_budget_chips`` are absent (uncapped)."""
        reg = self.ctl.registry
        counts: Dict[int, int] = {}
        for app_id in self._drives:
            blk = reg.apps.get(app_id)
            if blk is None or blk.state is not BlockState.RUNNING or \
                    blk.grant is None or not blk.grant.coords:
                continue
            rt = self.ctl.runtimes.get(app_id)
            if rt is None or getattr(rt, "suspended", False):
                continue
            pid = blk.grant.coords[0][0]
            counts[pid] = counts.get(pid, 0) + 1
        shares: Dict[int, float] = {}
        pods = getattr(self.ctl, "pods", None)
        if pods is None:
            return shares
        for pid, n in counts.items():
            p = pods.get(pid)
            if p is not None and p.power_budget_chips is not None:
                shares[pid] = p.power_budget_chips / n
        return shares

    @runtime_check.guard_serialized("control-plane")
    def run_round(self, now: Optional[float] = None,
                  budget: Optional[int] = None,
                  pod: Optional[int] = None) -> int:
        """One engine round: harvest, checkpoint, terminate, dispatch.
        Returns the number of completions harvested plus dispatches made
        (0 = nothing to do).  Callers serialize rounds with every other
        mutation (the daemon runs them on the pump thread / under its
        inline lock).

        ``pod`` restricts harvesting/dispatch to blocks granted on that
        federation pod — each per-pod daemon worker drives only its own
        residents, so one slow pod cannot stall another's pump.  Drive
        cleanup (vanished/terminal blocks) always runs unfiltered."""
        if not self._drives:
            self.last_round_busy = False
            return 0
        round_t0 = time.perf_counter()
        t = now if now is not None else time.time()
        reg = self.ctl.registry
        shares = self._pod_budget_shares()
        work = 0
        pending = 0
        views: List[BlockView] = []
        runnable: Dict[str, object] = {}
        rated: set = set()       # blocks whose dispatches burn allowance
        for app_id in list(self._drives):
            drive = self._drives[app_id]
            blk = reg.apps.get(app_id)
            if blk is None:
                del self._drives[app_id]
                continue
            if blk.state in _TERMINAL:
                self.disable(app_id, reason=f"block {blk.state.value}",
                             now=now)
                continue
            if blk.state is not BlockState.RUNNING:
                continue             # queued/preempted: stay armed, idle
            rt = self.ctl.runtimes.get(app_id)
            if rt is None or getattr(rt, "suspended", False):
                continue
            self._refresh_grant(drive, blk)
            if pod is not None and drive.pod != pod:
                continue             # another pod's worker drives this one
            # harvest under a per-app span that joins the block's *bound*
            # trace (the request that bound it), not the worker thread's
            # incidental stack — see Tracer.span(parent="binding")
            with TRACER.span("engine.harvest", cat="engine", app_id=app_id,
                             parent="binding"):
                for rec in rt.poll(block=False):
                    self._publish_step(app_id, drive, rec, now)
                    work += 1
                work += self._harvest_generate(app_id, drive, rt, now)
            self._maybe_checkpoint(drive, rt)
            cfg = drive.config
            if cfg.until_steps is not None and \
                    rt.step_count >= cfg.until_steps:
                if rt.inflight_depth:
                    pending += rt.inflight_depth
                    continue         # harvest the stragglers next round
                reg.set_state(app_id, BlockState.DONE,
                              f"autostep ran to {rt.step_count} steps")
                self.ctl.bus.publish("autostep", app_id=app_id,
                                     block_id=drive.block_id,
                                     user=drive.user, now=now,
                                     action="done", steps=rt.step_count)
                del self._drives[app_id]
                continue
            until_t = self._until_t(drive, blk)
            if until_t is not None and t >= until_t:
                if rt.inflight_depth:
                    pending += rt.inflight_depth
                    continue
                self.disable(app_id, reason="run-until time reached",
                             now=now)
                continue
            if getattr(rt, "idle_serve", False):
                pending += rt.inflight_depth
                continue             # paged serve with no sessions: stay
                                     # armed, dispatch nothing (the next
                                     # generate command wakes it)
            room = self.ctl.scheduler.max_inflight - rt.inflight_depth
            if cfg.until_steps is not None:
                room = min(room, cfg.until_steps - rt.step_count
                           - rt.inflight_depth)
            # `is not None`, not truthiness: max_rate_hz=0.0 is a *pause*
            # (same falsy-zero class as the model-time fixes in PR 3)
            rate = cfg.max_rate_hz
            drive.derived_rate_hz = None
            if rate is None and drive.pod in shares:
                # adaptive pacing: the pod's power budget (chip-seconds
                # per second) split across its runnable blocks, converted
                # to a step rate with the online-learned step cost.  No
                # estimate yet -> uncapped warm-up until steps land.
                step_s = self.ctl.monitor.step_time_estimate(drive.block_id)
                if step_s:
                    rate = shares[drive.pod] / (step_s
                                                * max(1, drive.n_chips))
                    drive.derived_rate_hz = rate
            if rate is None:
                rate = self.policy.default_rate_hz
            if rate is not None:
                rated.add(app_id)
                if rate <= 0:
                    room = 0                 # paused, stays armed
                else:
                    if drive.last_refill is not None:
                        drive.allowance = min(
                            max(1.0, rate * 0.25),  # burst: a 1/4 second
                            drive.allowance
                            + (t - drive.last_refill) * rate)
                    drive.last_refill = t
                    room = min(room, int(drive.allowance))
            pending += rt.inflight_depth
            if room <= 0:
                continue
            view = BlockView(app_id=app_id, priority=drive.priority,
                             n_chips=drive.n_chips,
                             slack_s=self._slack_s(blk, t), room=room,
                             deficit=drive.deficit)
            views.append(view)
            runnable[app_id] = rt
        plan = self.policy.allocate(views, budget)
        for view in views:
            self._drives[view.app_id].deficit = view.deficit
        for app_id in plan:
            # paged serve decode rounds run synchronously inside
            # dispatch(), so their spans nest under this one
            with TRACER.span("engine.dispatch", cat="engine",
                             app_id=app_id, parent="binding"):
                runnable[app_id].dispatch()
            drive = self._drives[app_id]
            if app_id in rated:
                drive.allowance -= 1.0
            work += 1
            pending += 1
        self.last_round_busy = work > 0 or pending > 0
        REGISTRY.observe("repro_engine_round_seconds",
                         time.perf_counter() - round_t0)
        return work
