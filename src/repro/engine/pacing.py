"""PacingPolicy — who gets the next dispatch slot, and how many.

Each engine round has a bounded dispatch budget (the pump thread must get
back to commands and ticks quickly).  The policy splits that budget across
the runnable autostep blocks by *weighted deficit round-robin*:

* every runnable block accrues credit proportional to its weight each
  round (``deficit``, persisted on the engine's per-block drive state);
* weight = a priority term, divided by the chips the block already holds
  (fair interleave: a 2x-bigger block gets half the dispatch slots — it
  does 2x the work per step), boosted when the block's *effective
  deadline slack* (time-to-deadline minus estimated remaining service
  time) is shrinking below ``boost_slack_s``;
* slots go to the highest-credit block first, one dispatch at a time,
  re-ranking after every grant — work-conserving: leftover budget flows
  to whoever still has window room even if their credit is negative.

Backpressure is structural, not policy: a block whose in-flight window is
full (``scheduler.max_inflight``) or whose per-block token bucket
(``max_rate_hz``) is empty is simply not a candidate this round, and its
deficit does not accrue (a stalled block must not bank unbounded credit
and then monopolize the budget when it wakes).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional


@dataclasses.dataclass
class BlockView:
    """One runnable autostep block, as the policy sees it this round."""
    app_id: str
    priority: int = 0
    n_chips: int = 1
    slack_s: Optional[float] = None    # effective deadline slack (None = no SLO)
    room: int = 0                      # dispatches the window/rate/run-until
                                       # targets still allow this round
    deficit: float = 0.0               # accrued credit (engine persists it)


class PacingPolicy:
    """Fair-interleave pacing with priority weighting and deadline boost.

    Subclass and override ``weight`` (or all of ``allocate``) to plug in a
    different pacing discipline; the engine only calls these two hooks.
    """

    def __init__(self, priority_weight: float = 0.5,
                 chip_fairness: bool = True,
                 boost_slack_s: float = 30.0,
                 deadline_boost: float = 4.0,
                 round_budget: int = 16,
                 default_rate_hz: Optional[float] = None):
        self.priority_weight = priority_weight
        self.chip_fairness = chip_fairness
        self.boost_slack_s = boost_slack_s
        self.deadline_boost = deadline_boost
        self.round_budget = round_budget
        #: per-block step-rate cap applied when the block's own config
        #: leaves ``max_rate_hz`` unset (None = unpaced)
        self.default_rate_hz = default_rate_hz

    # --------------------------------------------------------------- hooks
    def weight(self, view: BlockView) -> float:
        """Relative share of the dispatch budget this block earns per
        round.  Must be > 0 for every runnable block."""
        w = 1.0 + max(0, view.priority) * self.priority_weight
        if self.chip_fairness:
            w /= max(1, view.n_chips)
        if view.slack_s is not None and view.slack_s < self.boost_slack_s:
            # deadline-aware boost, scaling up as the slack keeps shrinking
            # (a block already past its deadline gets the full boost)
            frac = max(0.0, view.slack_s) / self.boost_slack_s
            w *= 1.0 + (self.deadline_boost - 1.0) * (1.0 - frac)
        return w

    def allocate(self, views: List[BlockView],
                 budget: Optional[int] = None) -> List[str]:
        """Split ``budget`` dispatch slots across ``views`` (one list entry
        per dispatch, in dispatch order).  Mutates each view's ``deficit``;
        the engine writes them back to its per-block drives."""
        budget = self.round_budget if budget is None else budget
        live = [v for v in views if v.room > 0]
        if not live or budget <= 0:
            return []
        weights: Dict[str, float] = {v.app_id: self.weight(v) for v in live}
        norm = sum(weights.values()) or 1.0
        for v in live:
            v.deficit += budget * weights[v.app_id] / norm
            # bank at most one round of credit: a block rate-capped for a
            # while must not starve everyone else when it becomes eligible
            v.deficit = min(v.deficit, float(budget))
        plan: List[str] = []
        while budget > 0:
            v = max((x for x in live if x.room > 0),
                    key=lambda x: x.deficit, default=None)
            if v is None:
                break
            plan.append(v.app_id)
            v.deficit -= 1.0
            v.room -= 1
            budget -= 1
        return plan
