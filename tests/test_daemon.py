"""ClusterDaemon service layer + event bus: command serialization in both
execution modes, the registry's per-transition state events, and the
Monitor-as-subscriber equivalence with the old direct-call accounting."""
import threading
import time

import jax
import pytest

from repro.core.block import BlockState
from repro.core.daemon import ClusterDaemon
from repro.core.events import EventBus
from repro.core.runtime import SimJobSpec
from repro.core.topology import Topology


def make_daemon(tmp_path, pod_x=4, pod_y=2, **kw):
    topo = Topology(n_pods=1, pod_x=pod_x, pod_y=pod_y)
    dev = jax.devices()[0]
    return ClusterDaemon(topo, devices=[dev] * topo.n_chips,
                         ckpt_root=str(tmp_path / "ckpt"), **kw)


SIM = SimJobSpec(step_s=0.001, ckpt_every=2)


# --------------------------------------------------------------- event bus

def test_event_bus_orders_filters_and_replays():
    bus = EventBus(history=3)
    got = []
    bus.subscribe(lambda ev: got.append(ev.kind), kinds={"admitted"})
    for i in range(3):
        bus.publish("state", app_id=f"a{i}", state="queued")
    bus.publish("admitted", app_id="a0", wait_s=0.0)
    assert got == ["admitted"]                   # kind filter on subscribe
    assert bus.latest_seq == 4
    evs = bus.events_since(0)
    assert [e.seq for e in evs] == [2, 3, 4]     # ring evicted seq 1
    assert [e.seq for e in bus.events_since(0, app_id="a1")] == [2]
    assert bus.events_since(4) == []
    ev = evs[-1]
    assert ev.to_dict()["wait_s"] == 0.0

    bus.unsubscribe(got.append)                  # not registered: no-op
    blocker = bus.wait(after_seq=4, timeout=0.05)
    assert blocker == []                         # times out empty

    def later():
        time.sleep(0.05)
        bus.publish("admitted", app_id="a9")

    t = threading.Thread(target=later)
    t.start()
    woke = bus.wait(after_seq=4, timeout=5.0)
    t.join()
    assert [e.app_id for e in woke] == ["a9"]


def test_event_uses_model_time_when_given():
    bus = EventBus()
    ev = bus.publish("admitted", app_id="a", now=123.0)
    assert ev.t == 123.0


# ----------------------------------------------------- monitor subscription

def test_monitor_accounting_driven_entirely_by_events(tmp_path):
    """The Monitor no longer gets called by scheduler/controller — every
    number in its reports must arrive via bus events and match the old
    direct-call behavior (admission waits, preemption counts, resumes,
    utilization, per-step EWMA)."""
    d = make_daemon(tmp_path)
    mon = d.monitor
    lo, g = d.submit("alice", "victim", 8, job=SIM, priority=0)
    assert g is not None
    d.run_steps({lo: 4})
    bid = d.registry.get(lo).block_id
    assert mon.stats[bid].steps == 4             # step events -> EWMA
    assert mon.stats[bid].ewma_step_s is not None
    hi, g2 = d.submit("bob", "urgent", 8, job=SIM, priority=5, now=50.0)
    assert g2 is not None                        # preempted alice
    assert mon.preempted_total == 1
    assert mon.queue_depth == 1                  # alice parked for resume
    d.registry.get(hi).grant.expires_at = 51.0
    d.tick(now=60.0)                             # expire bob, resume alice
    assert mon.resumed_total == 1
    assert mon.resume_waits[-1] == 10.0          # model clock end to end
    assert mon.queue_depth == 0
    assert mon.util_samples                      # tick published a sample
    rep = mon.preemption_report()
    assert rep["preempted_total"] == 1 and rep["resumed_total"] == 1


def test_registry_emits_state_event_for_every_transition(tmp_path):
    d = make_daemon(tmp_path)
    app, grant = d.submit("alice", "watched", 4)
    d.confirm(app, grant.token)
    d.activate(app, SIM)
    d.run(app)
    d.download(app)
    d.expire(app)
    states = [e.payload["state"]
              for e in d.bus.events_since(0, app_id=app)
              if e.kind == "state"]
    assert states == ["approved", "confirmed", "active", "running",
                      "done", "expired"]
    kinds = [e.kind for e in d.bus.events_since(0, app_id=app)]
    assert kinds[0] == "registered"
    assert "admitted" in kinds


# ------------------------------------------------------------ daemon modes

def test_deterministic_mode_runs_inline_with_model_time(tmp_path):
    """Default mode: no thread, caller-driven tick, now= plumbing intact —
    the exact pre-daemon semantics tests and benchmarks rely on."""
    d = make_daemon(tmp_path)
    assert not d.running
    filler, _ = d.submit("zed", "filler", 8, now=100.0)
    q, g = d.submit("bob", "queued", 8, deadline_s=50.0, now=100.0)
    assert g is None
    d.registry.get(filler).grant.expires_at = 109.0
    d.tick(now=110.0)
    assert d.registry.get(q).state == BlockState.APPROVED
    assert d.monitor.queue_waits[-1] == 10.0


def test_background_mode_serializes_commands_from_many_threads(tmp_path):
    """Service mode: concurrent submitters all funnel through the pump
    thread; admissions + waitlist stay consistent and the partitioner
    invariants hold."""
    d = make_daemon(tmp_path, background=True, tick_interval_s=0.01)
    try:
        results = {}

        def submit(i):
            results[i] = d.submit(f"user{i}", f"job {i}", 4, job=SIM)

        threads = [threading.Thread(target=submit, args=(i,))
                   for i in range(6)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        admitted = [a for a, g in results.values() if g is not None]
        assert len(admitted) == 2                # 8 chips / 4 each
        d.partitioner.check_invariants()
        # the pump auto-admits the rest as earlier blocks expire
        for a in admitted:
            d.expire(a)
        deadline = time.monotonic() + 5.0
        while time.monotonic() < deadline:
            states = {a: d.registry.get(a).state
                      for a, _ in results.values()}
            if sum(s == BlockState.RUNNING for s in states.values()) == 2:
                break
            time.sleep(0.02)
        else:
            raise AssertionError(f"pump never admitted the queue: {states}")
    finally:
        d.stop()
    assert not d.running


def test_command_errors_propagate_to_caller(tmp_path):
    d = make_daemon(tmp_path, background=True)
    try:
        with pytest.raises(KeyError):
            d.download("app_nope")
        with pytest.raises(ValueError):
            d.call("not_a_command")
    finally:
        d.stop()


def test_daemon_status_and_reports(tmp_path):
    d = make_daemon(tmp_path)
    app, grant = d.submit("alice", "status me", 4, job=SIM, priority=2)
    st = d.status(app)
    assert st["state"] == "running" and st["n_chips"] == 4
    assert st["block_id"] == grant.block_id and st["priority"] == 2
    assert [b["app_id"] for b in d.list_apps(user="alice")] == [app]
    assert d.list_apps(user="nobody") == []
    rep = d.cluster_report()
    assert rep["n_chips"] == 8 and rep["free_chips"] == 4
    assert rep["queue_depth"] == 0
