"""Per-architecture smoke tests (reduced configs) + decode/prefill
consistency against the full forward pass."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.data import pipeline
from repro.models import model
from repro.models.config import ShapeConfig

KEY = jax.random.PRNGKey(7)
B, S = 2, 32


def make_batch(cfg, with_labels=True):
    ks = jax.random.split(KEY, 4)
    if cfg.frontend == "frame":
        b = {"frames": jax.random.normal(ks[0], (B, S, cfg.frontend_dim)),
             "mask": jax.random.bernoulli(ks[1], 0.3, (B, S))}
        if with_labels:
            b["labels"] = jax.random.randint(ks[2], (B, S), 0, cfg.vocab_size)
        return b
    if cfg.frontend == "patch":
        n_p = 4
        b = {"tokens": jax.random.randint(ks[0], (B, S - n_p), 0,
                                          cfg.vocab_size),
             "patches": jax.random.normal(ks[1], (B, n_p, cfg.frontend_dim))}
        if with_labels:
            b["labels"] = jax.random.randint(ks[2], (B, S - n_p), 0,
                                             cfg.vocab_size)
        return b
    b = {"tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size)}
    if with_labels:
        b["labels"] = jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size)
    return b


@pytest.mark.parametrize("arch", C.ARCH_IDS)
def test_smoke_forward_loss(arch):
    """Assigned-architecture smoke: reduced config, one loss eval, finite."""
    cfg = C.get_smoke(arch)
    params = model.init_params(cfg, KEY)
    loss, metrics = jax.jit(
        lambda p, b: model.loss_fn(p, cfg, b))(params, make_batch(cfg))
    assert np.isfinite(float(loss)), (arch, loss)
    # random-init loss should be near ln(vocab)
    assert float(loss) < np.log(cfg.vocab_size) + 2.0


@pytest.mark.parametrize("arch", C.ARCH_IDS)
def test_smoke_train_step(arch):
    """One full train step: grads flow, params update, loss finite."""
    from repro.train import optimizer as opt_lib
    from repro.train import train_step as train_lib
    cfg = C.get_smoke(arch)
    shape = ShapeConfig("t", "train", seq_len=S, global_batch=B, microbatch=1)
    opt_cfg = opt_lib.OptConfig(warmup_steps=1, total_steps=4)
    state = train_lib.make_train_state(cfg, KEY, opt_cfg)
    step = jax.jit(train_lib.make_train_step(cfg, shape, opt_cfg))
    p0 = jax.tree.map(lambda x: np.asarray(x, np.float32), state["params"])
    state, metrics = step(state, make_batch(cfg))
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    changed = jax.tree.map(
        lambda a, b: not np.allclose(a, np.asarray(b, np.float32)), p0,
        state["params"])
    assert any(jax.tree.leaves(changed)), f"{arch}: no param moved"


@pytest.mark.parametrize("arch", [a for a in C.ARCH_IDS
                                  if not C.get(a).is_encoder])
def test_decode_consistency(arch):
    """prefill + decode token-by-token == one full causal forward pass."""
    cfg = C.get_smoke(arch).replace(param_dtype="float32")
    if cfg.moe is not None:
        # decode routes per-step with tiny per-call capacity; boost capacity
        # so no tokens drop and the math is exactly comparable.  f32 params
        # keep top-k routing decisions stable between the two paths (bf16
        # wobble can flip an expert choice, which is a discontinuity).
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                  capacity_factor=16.0))
    params = model.init_params(cfg, KEY)
    batch = make_batch(cfg, with_labels=False)
    smax = S + 4

    # full forward logits
    x = model.embed_inputs(params, cfg, batch)
    full_logits, _, _ = jax.jit(
        lambda p, xx: model.forward(p, cfg, xx,
                                    positions=jnp.arange(xx.shape[1]))
    )(params, x)

    # prefill over the first P positions, then decode the rest
    P = S - 3
    if cfg.frontend == "patch":
        pf_batch = {"tokens": batch["tokens"][:, :P - 4],
                    "patches": batch["patches"]}
        tail_tokens = batch["tokens"][:, P - 4:]
    else:
        pf_batch = {"tokens": batch["tokens"][:, :P]}
        tail_tokens = batch["tokens"][:, P:]
    cache = model.init_cache(cfg, B, smax)
    logits_last, cache = jax.jit(
        lambda p, b, c: model.prefill(p, cfg, b, c))(params, pf_batch, cache)
    np.testing.assert_allclose(
        np.asarray(logits_last, np.float32),
        np.asarray(full_logits[:, P - 1], np.float32), atol=3e-2, rtol=3e-2)

    dec = jax.jit(lambda p, t, c, l: model.decode_step(p, cfg, t, c, l))
    for i in range(tail_tokens.shape[1]):
        tok = tail_tokens[:, i:i + 1]
        logits, cache = dec(params, tok, cache, jnp.int32(P + i))
        np.testing.assert_allclose(
            np.asarray(logits, np.float32),
            np.asarray(full_logits[:, P + i], np.float32),
            atol=3e-2, rtol=3e-2)


@pytest.mark.parametrize("arch", C.ARCH_IDS)
def test_input_specs_cover_cells(arch):
    """input_specs produces specs for every executed cell of this arch."""
    cfg = C.get(arch)
    for shape_name in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
        status = C.cell_status(arch, shape_name)
        if status != "run":
            assert "skip" in status
            continue
        shape = C.shape(shape_name)
        if shape.kind in ("train", "prefill"):
            specs = pipeline.input_specs(cfg, shape)
            assert specs, (arch, shape_name)
            for v in specs.values():
                assert v.shape[0] == shape.global_batch


def test_param_counts_match_published():
    expected = {  # billions, tolerance 15%
        "llama4_maverick_400b": 400, "deepseek_v2_236b": 236,
        "starcoder2_15b": 15, "deepseek_7b": 7, "mistral_nemo_12b": 12,
        "yi_34b": 34, "pixtral_12b": 12, "hubert_xlarge": 1.0,
        "zamba2_2p7b": 2.7, "xlstm_350m": 0.35,
    }
    for arch, want_b in expected.items():
        n = model.count_params(model.abstract_params(C.get(arch))) / 1e9
        assert abs(n - want_b) / want_b < 0.4, (arch, n, want_b)
