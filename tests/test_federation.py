"""Elastic multi-pod federation: runtime attach/drain/detach, heartbeat
health decay with a false-positive grace period, cross-pod migration of
queued/preempted/granted blocks, gang no-split placement, pod-state
snapshot round-trip, per-pod engine rounds and budget-derived pacing."""
import jax
import pytest

from repro.core.block import BlockState
from repro.core.controller import ClusterController
from repro.core.partition import AllocationError
from repro.core.scheduler import SimRuntime
from repro.core.topology import Topology
from repro.engine import AutostepEngine
from repro.federation import (FederatedPlacer, HealthMonitor, PodRegistry,
                              POD_DEAD, POD_DEGRADED, POD_READY)


def make_ctl(tmp_path, pod_x=2, pod_y=2, state=False, placer=None):
    topo = Topology(n_pods=1, pod_x=pod_x, pod_y=pod_y)
    dev = jax.devices()[0]
    return ClusterController(
        topo, devices=[dev] * topo.n_chips,
        ckpt_root=str(tmp_path / "ckpt"),
        state_path=str(tmp_path / "state.json") if state else None,
        placer=placer)


def submit_running(ctl, user, n_chips, pod=None, step_s=0.001):
    app_id, grant = ctl.submit(user, f"{user} job", n_chips, pod=pod)
    assert grant is not None, f"{user} did not fit"
    ctl.confirm(app_id, grant.token)
    ctl.registry.set_state(app_id, BlockState.ACTIVE)
    ctl.registry.set_state(app_id, BlockState.RUNNING)
    ctl.runtimes[app_id] = SimRuntime(step_s)
    return app_id


def held_pods(ctl, app_id):
    coords = ctl.registry.get(app_id).grant.coords
    return {c[0] for c in coords}


# ------------------------------------------------------- join/leave/fail

def test_attach_grows_capacity_and_publishes(tmp_path):
    ctl = make_ctl(tmp_path)                               # boot: 4 chips
    assert ctl.total_chips() == 4
    pod = ctl.attach_pod(2, 2, name="edge")
    assert pod["phase"] == POD_READY and pod["n_chips"] == 4
    assert ctl.total_chips() == 8
    assert ctl.partitioner.free_capacity() == 8
    evs = [e for e in ctl.bus.events_since(0) if e.kind == "pod"]
    assert [e.payload["action"] for e in evs][-1] == "joined"
    assert evs[-1].payload["name"] == "edge"


def test_drain_stops_placement_residents_keep_running(tmp_path):
    ctl = make_ctl(tmp_path)
    pod = ctl.attach_pod(2, 2, name="edge")
    app = submit_running(ctl, "alice", 4, pod=pod["pod_id"])
    ctl.drain_pod(pod["pod_id"])
    assert ctl.pods.pod(pod["pod_id"]).phase == "draining"
    # resident untouched, but the drained pod takes nothing new
    assert ctl.registry.get(app).state == BlockState.RUNNING
    _, grant = ctl.submit("bob", "job", 2)
    assert grant is not None and held_pods(ctl, _) == {0}


def test_detach_refuses_residents_then_force_migrates(tmp_path):
    ctl = make_ctl(tmp_path)
    pod = ctl.attach_pod(2, 2, name="edge")
    app, grant = ctl.submit("alice", "job", 2, pod=pod["pod_id"])
    assert grant is not None and held_pods(ctl, app) == {pod["pod_id"]}
    with pytest.raises(ValueError, match="resident"):
        ctl.detach_pod(pod["pod_id"])
    ctl.detach_pod(pod["pod_id"], force=True)
    # the APPROVED block's grant migrated onto the surviving boot pod
    assert held_pods(ctl, app) == {0}
    assert ctl.registry.get(app).state == BlockState.APPROVED
    assert ctl.pods.get(pod["pod_id"]) is None
    ctl.partitioner.check_invariants()
    migs = [e for e in ctl.bus.events_since(0) if e.kind == "migrated"]
    assert migs and migs[-1].payload["from_pod"] == pod["pod_id"]
    assert migs[-1].payload["to_pod"] == 0


def test_pod_death_mid_dispatch_zero_leaks_and_auto_resume(tmp_path):
    """Acceptance: kill a pod while a resident has steps in flight —
    no chip stays owned on the dead pod, the victim is preempted and
    auto-resumed on surviving capacity, co-tenants are untouched."""
    ctl = make_ctl(tmp_path)                               # boot 2x2
    pod = ctl.attach_pod(2, 2, name="edge")
    # both unpinned: the placer's most-free-first order sends alice to
    # the boot pod (tie -> lowest id) and bob to the emptier new pod
    a = submit_running(ctl, "alice", 2)                    # survivor
    b = submit_running(ctl, "bob", 2)                      # victim
    assert held_pods(ctl, b) == {pod["pod_id"]}
    ctl.runtimes[b].dispatch()                             # mid-dispatch
    victims = ctl.fail_pod(pod["pod_id"], reason="power loss")
    assert victims == [b]
    dead = ctl.pods.pod(pod["pod_id"])
    assert dead.phase == POD_DEAD
    assert all(info.owner is None
               for info in dead.part.chips.values())   # zero leaked chips
    ctl.partitioner.check_invariants()
    # blast radius confined: the co-tenant never moved
    assert ctl.registry.get(a).state == BlockState.RUNNING
    assert held_pods(ctl, a) == {0}
    # victim auto-resumed onto the surviving pod by the post-failure pump
    blk_b = ctl.registry.get(b)
    assert blk_b.state == BlockState.RUNNING
    assert held_pods(ctl, b) == {0}
    assert blk_b.preempt_count == 1


# ----------------------------------------------------- elastic admission

def test_queued_block_admitted_on_runtime_attach(tmp_path):
    ctl = make_ctl(tmp_path)                               # 4 chips total
    submit_running(ctl, "alice", 4)
    b, grant = ctl.submit("bob", "job", 4)
    assert grant is None
    assert ctl.registry.get(b).state == BlockState.QUEUED
    ctl.attach_pod(2, 2, name="edge")      # pump runs inside attach_pod
    blk = ctl.registry.get(b)
    assert blk.state == BlockState.APPROVED
    assert held_pods(ctl, b) == {1}


def test_preempted_block_migrates_to_new_pod(tmp_path):
    ctl = make_ctl(tmp_path)
    a = submit_running(ctl, "alice", 4)
    ctl.preempt(a, reason="make room")
    # a higher class outranks the parked victim and refills the boot pod
    # (a same-class submission would wait its turn behind the victim)
    app_c, grant_c = ctl.submit("carol", "job", 4, priority=10)
    assert grant_c is not None
    assert ctl.registry.get(a).state == BlockState.PREEMPTED
    ctl.attach_pod(2, 2, name="edge")
    blk = ctl.registry.get(a)
    assert blk.state == BlockState.RUNNING          # auto-resumed
    assert held_pods(ctl, a) == {1}                 # ...on the new pod
    migs = [e for e in ctl.bus.events_since(0) if e.kind == "migrated"]
    assert migs and migs[-1].payload["from_pod"] == 0
    assert migs[-1].payload["to_pod"] == 1
    assert migs[-1].payload["n_chips"] == 4


# ------------------------------------------------------------------ gangs

def test_gang_never_splits_across_pods(tmp_path):
    ctl = make_ctl(tmp_path)
    ctl.attach_pod(2, 2, name="edge")
    # 4+2 chips: fits the 8-chip federation but no single 4-chip pod
    with pytest.raises(AllocationError, match="no single pod"):
        ctl.partitioner.allocate_many([(4, "g1", None), (2, "g2", None)])
    # nothing half-placed by the failed attempt
    assert ctl.partitioner.free_capacity() == 8
    ctl.partitioner.check_invariants()


def test_gang_split_knob_allows_cross_pod(tmp_path):
    ctl = make_ctl(tmp_path, placer=FederatedPlacer(allow_gang_split=True))
    ctl.attach_pod(2, 2, name="edge")
    placed = ctl.partitioner.allocate_many([(4, "g1", None),
                                            (2, "g2", None)])
    pods_used = {c[0] for coords in placed.values() for c in coords}
    assert pods_used == {0, 1}             # split was required, and allowed
    ctl.partitioner.check_invariants()


# ----------------------------------------------------------------- health

def test_health_grace_period_false_positive_recovers(tmp_path):
    ctl = make_ctl(tmp_path)
    pod = ctl.attach_pod(2, 2, name="edge")
    pid = pod["pod_id"]
    app = submit_running(ctl, "alice", 2, pod=pid)
    ctl.pod_heartbeat(pid, now=0.0)        # first beat arms monitoring
    ctl.tick(now=6.0)                      # past degraded_after_s=5
    assert ctl.pods.pod(pid).phase == POD_DEGRADED
    # degraded is a grace state: nothing was evicted
    assert ctl.registry.get(app).state == BlockState.RUNNING
    ctl.pod_heartbeat(pid, now=7.0)        # late beat clears the flap
    assert ctl.pods.pod(pid).phase == POD_READY
    # silence past dead_after_s=15 since the last beat kills the pod
    ctl.tick(now=23.0)
    assert ctl.pods.pod(pid).phase == POD_DEAD
    assert ctl.registry.get(app).state != BlockState.RUNNING


def test_pods_that_never_beat_are_exempt_from_decay(tmp_path):
    ctl = make_ctl(tmp_path)
    ctl.attach_pod(2, 2, name="sim")
    ctl.tick(now=1e9)
    assert all(p.phase == POD_READY for p in ctl.pods.pods())


def test_health_monitor_unit_transitions():
    reg = PodRegistry()
    pod = reg.attach(2, 2, [object()] * 4, name="p")
    mon = HealthMonitor(reg, degraded_after_s=1.0, dead_after_s=3.0)
    mon.beat(pod.pod_id, now=0.0)
    assert mon.check(now=0.5) == []
    assert pod.phase == POD_READY
    assert mon.check(now=2.0) == []
    assert pod.phase == POD_DEGRADED
    assert mon.check(now=3.5) == [pod.pod_id]
    assert pod.phase == POD_DEAD
    assert mon.check(now=9.0) == []        # dead pods report only once


# -------------------------------------------------------------- snapshot

def test_pod_directory_snapshot_roundtrip(tmp_path):
    ctl = make_ctl(tmp_path, state=True)
    pod = ctl.attach_pod(2, 1, name="edge", power_budget_chips=3.0)
    ctl.drain_pod(pod["pod_id"])
    ctl2 = make_ctl(tmp_path, state=True)
    back = ctl2.pods.pod(pod["pod_id"])
    assert back.name == "edge"
    assert back.phase == "draining"
    assert back.power_budget_chips == 3.0
    assert (back.topo.pod_x, back.topo.pod_y) == (2, 1)
    assert not back.boot
    # boot pod rebuilt from the topology, not duplicated from the snapshot
    assert [p.pod_id for p in ctl2.pods.pods()] == [0, pod["pod_id"]]
    assert ctl2.total_chips() == 4 + 2


# ----------------------------------------------------- per-pod engine

def test_engine_round_pod_filter(tmp_path):
    ctl = make_ctl(tmp_path)
    pod = ctl.attach_pod(2, 2, name="edge")
    engine = AutostepEngine(ctl)
    ctl.engine = engine
    a = submit_running(ctl, "alice", 2, pod=0, step_s=0.0)
    b = submit_running(ctl, "bob", 2, pod=pod["pod_id"], step_s=0.0)
    engine.enable(a)
    engine.enable(b)
    engine.run_round(now=0.0, pod=0)
    assert ctl.runtimes[a].inflight_depth > 0      # pod 0 progressed
    assert ctl.runtimes[b].inflight_depth == 0     # pod 1 untouched
    engine.run_round(now=0.0, pod=pod["pod_id"])
    assert ctl.runtimes[b].inflight_depth > 0


def test_adaptive_pacing_derives_rate_from_pod_budget(tmp_path):
    ctl = make_ctl(tmp_path)
    pod = ctl.attach_pod(2, 2, name="edge", power_budget_chips=2.0)
    engine = AutostepEngine(ctl)
    ctl.engine = engine
    app = submit_running(ctl, "alice", 4, pod=pod["pod_id"], step_s=0.0)
    blk = ctl.registry.get(app)
    engine.enable(app)
    # before any step cost is learned: uncapped warm-up
    engine.run_round(now=0.0)
    assert engine.describe(app)["derived_rate_hz"] is None
    # teach the monitor a 0.1 s/step cost, then rates derive from it:
    # (2 budget chips / 1 runnable block) / (0.1 s * 4 chips) = 5 Hz
    for i in range(8):
        ctl.bus.publish("step", app_id=app, block_id=blk.block_id,
                        user="alice", now=float(i), step_s=0.1, n_chips=4)
    engine.run_round(now=1.0)
    rate = engine.describe(app)["derived_rate_hz"]
    est = ctl.monitor.step_time_estimate(blk.block_id)
    assert est is not None and rate == pytest.approx(2.0 / (est * 4))
    # an explicit per-block cap still wins over the derived rate
    engine.set_pace(app, 1.0)
    engine.run_round(now=2.0)
    assert engine.describe(app)["derived_rate_hz"] is None


# ----------------------------------------------------- placement scoring

def test_interference_penalty_knob():
    reg = PodRegistry()
    pod = reg.attach(8, 1, [object()] * 8, name="row")
    pod.part.allocate(3, "resident", pod=0)        # occupies x=0..2
    fragmented = [(0, 1, 0), (0, 4, 0)]   # routes through the resident
    on = FederatedPlacer(interference_penalty=True)
    off = FederatedPlacer(interference_penalty=False)
    assert on.rect_penalty(pod, fragmented) > 0.0
    assert off.rect_penalty(pod, fragmented) == 0.0
    # a disjoint contiguous rectangle is free under either knob
    clean = [(0, 5, 0), (0, 6, 0)]
    assert on.rect_penalty(pod, clean) == 0.0


def test_federation_counters(tmp_path):
    ctl = make_ctl(tmp_path)
    pod = ctl.attach_pod(2, 2, name="edge")
    app, _ = ctl.submit("alice", "job", 2, pod=pod["pod_id"])
    ctl.fail_pod(pod["pod_id"])
    rep = ctl.monitor.federation_report()
    assert rep["pods_joined_total"] >= 2           # boot + edge
    assert rep["pods_lost_total"] == 1
    assert rep["migrated_total"] == 1              # APPROVED grant moved
    assert held_pods(ctl, app) == {0}
