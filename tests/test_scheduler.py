"""BlockScheduler: admission waitlist, fair-share ordering, dispatch
backpressure, plus the previously-untested tick() auto-expire and
inject_chip_failure -> recover_block paths."""
import time

import jax
import pytest

from repro.core.block import BlockState
from repro.core.controller import ClusterController
from repro.core.partition import AllocationError, Partitioner
from repro.core.scheduler import SimRuntime, drive
from repro.core.topology import Topology


def make_ctl(tmp_path, pod_x=2, pod_y=2):
    """In-process controller: the single real CPU device stands in for every
    chip (fine for admission/queueing logic, which never builds a mesh)."""
    topo = Topology(n_pods=1, pod_x=pod_x, pod_y=pod_y)
    dev = jax.devices()[0]
    return ClusterController(topo, devices=[dev] * topo.n_chips,
                             ckpt_root=str(tmp_path / "ckpt"))


# ------------------------------------------------------------- partitioner

def test_retag_is_atomic_rename():
    part = Partitioner(Topology(n_pods=1, pod_x=2, pod_y=2))
    coords = part.allocate(4, "tmp_id")
    assert part.retag("tmp_id", "blk_real") == 4
    assert all(part.owner_of(c) == "blk_real" for c in coords)
    assert part.release("tmp_id") == 0          # old id owns nothing
    assert part.release("blk_real") == 4


def test_can_fit_and_free_capacity():
    part = Partitioner(Topology(n_pods=1, pod_x=4, pod_y=2))
    assert part.free_capacity() == 8
    assert part.can_fit(8)
    part.allocate(4, "a")          # takes a 2x2 corner; a 2x2 region remains
    assert part.free_capacity() == 4
    assert part.can_fit(4) and not part.can_fit(8)
    assert not part.can_fit(3)     # 3 needs a 3x1 run; free region is 2x2
    part.release("a")
    assert part.can_fit(3)


# --------------------------------------------------------------- admission

def test_submit_queues_instead_of_raising(tmp_path):
    ctl = make_ctl(tmp_path)                    # 4 chips
    a1, g1 = ctl.submit("alice", "train", 4)
    assert g1 is not None
    a2, g2 = ctl.submit("bob", "train", 4)      # oversubscribed
    assert g2 is None
    assert ctl.registry.get(a2).state == BlockState.QUEUED
    assert ctl.registry.queued() == [a2]
    assert ctl.scheduler.queue_depth() == 1
    assert ctl.monitor.queue_report()["depth"] == 1
    # the raise-on-full path still exists at the partitioner layer
    with pytest.raises(AllocationError):
        ctl.partitioner.allocate(4, "direct")


def test_waitlist_admitted_on_expiry(tmp_path):
    ctl = make_ctl(tmp_path)
    a1, g1 = ctl.submit("alice", "train", 4)
    a2, g2 = ctl.submit("bob", "train", 4)
    assert g2 is None
    ctl.registry.get(a1).grant.expires_at = time.time() - 1
    expired = ctl.tick()
    assert expired == [a1]
    blk2 = ctl.registry.get(a2)
    assert blk2.state == BlockState.APPROVED and blk2.grant is not None
    assert blk2.grant.n_chips == 4
    rep = ctl.monitor.queue_report()
    assert rep["depth"] == 0 and rep["admitted_total"] == 1
    assert rep["max_wait_s"] >= 0.0
    assert rep["utilization_now"] == 1.0        # bob now holds all 4 chips


def test_fair_share_prefers_user_holding_fewer_chips(tmp_path):
    ctl = make_ctl(tmp_path, pod_x=4, pod_y=2)  # 8 chips
    a1, _ = ctl.submit("alice", "j", 4)         # alice holds 4
    b1, _ = ctl.submit("bob", "j", 4)           # bob holds 4 -> pod full
    a2, g = ctl.submit("alice", "more", 4)      # queued first
    b2, g2 = ctl.submit("bob", "more", 4)       # queued second
    assert g is None and g2 is None
    ctl.expire(b1)                              # bob now holds 0, 4 free
    # fair share: bob's entry (0 held) is admitted ahead of alice's (4 held)
    # despite alice's earlier enqueue
    assert ctl.registry.get(b2).state == BlockState.APPROVED
    assert ctl.registry.get(a2).state == BlockState.QUEUED


def test_priority_beats_fair_share(tmp_path):
    ctl = make_ctl(tmp_path, pod_x=4, pod_y=2)
    a1, _ = ctl.submit("alice", "j", 4)
    b1, _ = ctl.submit("bob", "j", 4)
    b2, _ = ctl.submit("bob", "urgent", 4, priority=5)
    a2, _ = ctl.submit("alice", "more", 4)
    ctl.expire(a1)                              # alice holds 0, bob holds 4
    # priority 5 wins even though bob holds more chips and enqueued... first
    assert ctl.registry.get(b2).state == BlockState.APPROVED
    assert ctl.registry.get(a2).state == BlockState.QUEUED


def test_queue_drains_in_order_as_capacity_frees(tmp_path):
    ctl = make_ctl(tmp_path, pod_x=4, pod_y=2)
    a1, _ = ctl.submit("alice", "j", 8)         # whole pod
    b1, g = ctl.submit("bob", "big", 8)         # queued
    c1, g2 = ctl.submit("carol", "small", 2)    # queued behind bob
    assert g is None and g2 is None
    ctl.registry.get(a1).grant.expires_at = time.time() - 1
    ctl.tick()                                  # 8 free: bob admitted first
    assert ctl.registry.get(b1).state == BlockState.APPROVED
    assert ctl.registry.get(c1).state == BlockState.QUEUED  # no room left
    ctl.expire(b1)                              # carol admitted on release
    assert ctl.registry.get(c1).state == BlockState.APPROVED


def test_backfill_small_fits_while_large_waits(tmp_path):
    ctl = make_ctl(tmp_path, pod_x=4, pod_y=2)
    a1, _ = ctl.submit("alice", "j", 4)         # 4 free remain
    b1, g = ctl.submit("bob", "big", 8)         # can never fit now -> queued
    c1, g2 = ctl.submit("carol", "small", 2)    # fits: backfilled past bob
    assert g is None
    assert g2 is not None
    assert ctl.registry.get(c1).state == BlockState.APPROVED
    assert ctl.registry.get(b1).state == BlockState.QUEUED


def test_impossible_requests_denied_not_queued(tmp_path):
    """A request that can never fit the pod geometry (too big, zero, or
    negative) is denied at submission, not waitlisted forever."""
    ctl = make_ctl(tmp_path)                    # 2x2 pod, 4 chips
    for n in (32, 3, 0, -1):                    # 3 has no shape on a 2x2 pod
        app, g = ctl.submit("greedy", f"ask {n}", n)
        assert g is None
        assert ctl.registry.get(app).state == BlockState.DENIED
    assert ctl.scheduler.queue_depth() == 0
    ctl.tick()                                  # nothing to pump, no raise


def test_expired_or_denied_queued_app_is_pruned(tmp_path):
    """Regression: a QUEUED app that is force-expired or denied must leave
    the waitlist; admitting it later would be an illegal transition and
    would leak the chips allocated before the approve raised."""
    ctl = make_ctl(tmp_path)
    a1, _ = ctl.submit("alice", "j", 4)
    a2, g = ctl.submit("bob", "j", 4)
    a3, g2 = ctl.submit("carol", "j", 4)
    assert g is None and g2 is None
    ctl.expire(a2)                              # bob gives up while queued
    ctl.registry.deny(a3, "admin denied")       # carol rejected by admin
    assert ctl.scheduler.queue_depth() == 0
    assert ctl.monitor.queue_report()["depth"] == 0
    ctl.registry.get(a1).grant.expires_at = time.time() - 1
    ctl.tick()                                  # must not raise or leak
    assert ctl.registry.get(a2).state == BlockState.EXPIRED
    assert ctl.registry.get(a3).state == BlockState.DENIED
    assert ctl.partitioner.free_capacity() == 4  # nothing leaked


# --------------------------------------------------------------- dispatch

class CountingRuntime:
    """Fake runtime recording the deepest in-flight window it ever saw."""

    def __init__(self):
        self.inflight = 0
        self.max_seen = 0
        self.done = 0

    @property
    def inflight_depth(self):
        return self.inflight

    def oldest_dispatch_t(self):
        return 0.0 if self.inflight else float("inf")

    def dispatch(self):
        self.inflight += 1
        self.max_seen = max(self.max_seen, self.inflight)

    def poll(self, block=False):
        if self.inflight:
            self.inflight -= 1
            self.done += 1
            return [{"step_s": 1e-4}]
        return []


def test_double_review_raises_without_leaking_chips(tmp_path):
    """Regression: review() of an already-approved app must fail the state
    transition AND give the freshly-allocated chips back."""
    ctl = make_ctl(tmp_path)
    a1 = ctl.register("alice", "j", 2)
    ctl.review(a1)
    with pytest.raises(ValueError):
        ctl.review(a1)
    assert ctl.partitioner.free_capacity() == 2    # only the first grant held


def test_step_time_not_inflated_by_dispatch_depth():
    """Regression: at depth 2 each step's step_s must not include the wait
    behind its predecessor (would double-bill chip_seconds/EWMA)."""
    rt = SimRuntime(0.010)
    out = drive({"b": rt}, {"b": 10}, max_inflight=2)["b"]
    total = sum(r["step_s"] for r in out)
    assert 0.095 <= total <= 0.125, total           # ~10 x 10ms, not ~2x


def test_dispatch_backpressure_cap():
    rts = {"a": CountingRuntime(), "b": CountingRuntime()}
    out = drive(rts, {"a": 10, "b": 7}, max_inflight=2)
    assert len(out["a"]) == 10 and len(out["b"]) == 7
    assert rts["a"].max_seen <= 2 and rts["b"].max_seen <= 2
    assert rts["a"].done == 10


def test_run_dispatch_feeds_monitor(tmp_path):
    ctl = make_ctl(tmp_path)
    a1, g1 = ctl.submit("alice", "j", 2)
    ctl.confirm(a1, g1.token)
    ctl.registry.set_state(a1, BlockState.ACTIVE)
    ctl.registry.set_state(a1, BlockState.RUNNING)
    ctl.runtimes[a1] = SimRuntime(0.001)
    out = ctl.step_all(rounds=3)
    assert len(out[a1]) == 3
    bid = ctl.registry.get(a1).block_id
    assert ctl.monitor.stats[bid].steps == 3
    assert ctl.monitor.stats[bid].chip_seconds > 0


def test_slow_block_does_not_stall_fast_blocks():
    """3 fast blocks (10ms) + 1 slow (40ms); fast blocks need 8 steps, slow
    needs 2 (equal compute).  Event-driven wall-clock beats the old
    fixed-order round-robin emulation."""
    def mk():
        return {"f0": SimRuntime(0.010), "f1": SimRuntime(0.010),
                "f2": SimRuntime(0.010), "slow": SimRuntime(0.040)}

    targets = {"f0": 8, "f1": 8, "f2": 8, "slow": 2}

    rts = mk()
    t0 = time.perf_counter()
    out = drive(rts, targets, max_inflight=2)
    t_event = time.perf_counter() - t0
    assert {a: len(v) for a, v in out.items()} == targets

    # old step_all: rounds of dispatch-all then fixed-order blocking waits;
    # every round is gated by the slowest still-active block
    rts = mk()
    remaining = dict(targets)
    t0 = time.perf_counter()
    while any(remaining.values()):
        active = [a for a, n in remaining.items() if n > 0]
        for a in active:
            rts[a].dispatch()
            remaining[a] -= 1
        for a in active:
            rts[a].poll(block=True)
    t_rr = time.perf_counter() - t0

    # event: max chain = 80ms; round-robin: 2*40ms + 6*10ms = 140ms
    assert t_event < t_rr, (t_event, t_rr)


# ------------------------------------------- tick / failure-recovery paths

def test_tick_auto_expires_past_blocks(tmp_path):
    ctl = make_ctl(tmp_path)
    a1, g1 = ctl.submit("alice", "j", 4)
    assert ctl.tick() == []                     # nothing expired yet
    ctl.registry.get(a1).grant.expires_at = time.time() - 1
    assert ctl.tick() == [a1]
    assert ctl.registry.get(a1).state == BlockState.EXPIRED
    assert ctl.partitioner.free_capacity() == 4
    assert ctl.tick() == []                     # idempotent
    assert len(ctl.monitor.util_samples) == 3


@pytest.mark.slow
def test_inject_chip_failure_recovers_block(tmp_path):
    """Previously untested end-to-end path: chip failure -> FAILED ->
    re-carve -> checkpoint restore -> RUNNING (on the real BlockRuntime,
    single-device 1-chip block)."""
    import repro.configs as C
    from repro.core.runtime import JobSpec
    from repro.models.config import ShapeConfig
    from repro.train.optimizer import OptConfig

    ctl = make_ctl(tmp_path)
    shape = ShapeConfig("t", "train", seq_len=16, global_batch=2,
                        microbatch=1)
    job = JobSpec(C.get_smoke("xlstm_350m"), shape,
                  opt=OptConfig(warmup_steps=1, total_steps=8))
    a1, g1 = ctl.submit("alice", "train", 1, job=job)
    assert ctl.registry.get(a1).state == BlockState.RUNNING
    ctl.step_all(rounds=2)
    rt = ctl.runtimes[a1]
    assert rt.step_count == 2
    rt.save(async_=False)

    failed = ctl.inject_chip_failure(g1.coords[0])
    assert failed == a1
    blk = ctl.registry.get(a1)
    assert blk.state == BlockState.RUNNING          # recovered + resumed
    assert blk.grant.coords != g1.coords            # re-carved elsewhere
    assert blk.grant.block_id == g1.block_id        # same identity
    assert ctl.runtimes[a1].step_count == 2         # restored from ckpt
    ctl.step_all(rounds=1)
    assert ctl.runtimes[a1].step_count == 3
    ctl.partitioner.check_invariants()
