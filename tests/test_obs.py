"""Observability: tracer span parenting (property test), Prometheus
scrape format, flight-recorder postmortems on pod death, trace-context
survival across preempt/resume, and the gateway's /metrics, /v1/trace,
X-Request-ID and 429/413 surfaces."""
import json
import re
import time
import urllib.error
import urllib.request

import jax
import pytest

from _hypothesis_compat import given, settings, st
from repro.core.daemon import ClusterDaemon
from repro.core.runtime import SimJobSpec
from repro.core.topology import Topology
from repro.gateway import GatewayServer, ProfileStore, UserProfile
from repro.obs.flight import RECORDER, FlightRecorder
from repro.obs.metrics import REGISTRY, MetricsRegistry
from repro.obs.trace import TRACER, Tracer

SIM = {"kind": "sim", "step_s": 0.001}


@pytest.fixture(autouse=True)
def _obs_isolation():
    """The tracer/registry/recorder are process-global singletons; reset
    them around every test so traced daemons here don't bleed state into
    (or inherit state from) the rest of the suite."""
    def scrub():
        TRACER.disable()
        TRACER.reset()
        REGISTRY.reset()
        RECORDER.reset()
        RECORDER.dir = None
    scrub()
    yield
    scrub()


def make_daemon(tmp_path, **kw):
    topo = Topology(n_pods=kw.pop("n_pods", 1), pod_x=2, pod_y=1)
    dev = jax.devices()[0]
    return ClusterDaemon(topo, devices=[dev] * topo.n_chips,
                         ckpt_root=str(tmp_path / "ckpt"), **kw)


def req(server, method, path, token=None, body=None, headers=None,
        timeout=15):
    r = urllib.request.Request(server.url + path, method=method,
                               data=(json.dumps(body).encode()
                                     if body is not None else None))
    if token:
        r.add_header("Authorization", f"Bearer {token}")
    for k, v in (headers or {}).items():
        r.add_header(k, v)
    try:
        with urllib.request.urlopen(r, timeout=timeout) as resp:
            return resp.status, json.loads(resp.read()), resp.headers
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read()), e.headers


# ==================================================== metrics registry

def test_registry_counters_gauges_hists():
    reg = MetricsRegistry()
    reg.inc("a_total", labels={"k": "x"})
    reg.inc("a_total", 2, labels={"k": "x"})
    reg.inc("a_total", labels={"k": "y"})
    assert reg.counter_value("a_total", labels={"k": "x"}) == 3
    assert reg.counter_total("a_total") == 4
    reg.set_gauge("g", 7)
    assert reg.gauge_value("g") == 7
    for v in (0.001, 0.002, 0.004, 0.1):
        reg.observe("h_seconds", v)
    s = reg.hist_summary("h_seconds")
    assert s["count"] == 4 and abs(s["sum"] - 0.107) < 1e-9
    assert s["min"] <= s["p50"] <= s["p90"] <= s["p99"] <= s["max"]


def test_add_gauge_is_atomic_and_clamps():
    reg = MetricsRegistry()
    assert reg.add_gauge("g", 1) == 1
    assert reg.add_gauge("g", 1) == 2
    assert reg.add_gauge("g", -5) == 0          # clamps, never negative
    assert reg.gauge_value("g") == 0


def test_sample_ring_is_bounded():
    reg = MetricsRegistry()
    for i in range(3 * MetricsRegistry.RING):
        reg.sample("s", i, now=float(i))
    pts = reg.series("s")["s"]
    assert len(pts) == MetricsRegistry.RING
    assert pts[-1] == [float(3 * MetricsRegistry.RING - 1),
                       float(3 * MetricsRegistry.RING - 1)]


# one metric line: name, optional {labels}, numeric value
_PROM_LINE = re.compile(
    r"^[a-zA-Z_:][a-zA-Z0-9_:]*"
    r"(\{[a-zA-Z_][a-zA-Z0-9_]*=\"[^\"]*\""
    r"(,[a-zA-Z_][a-zA-Z0-9_]*=\"[^\"]*\")*\})?"
    r" -?[0-9]+(\.[0-9]+)?([eE][+-]?[0-9]+)?$")


def assert_prometheus_text(text):
    """Every non-comment line must parse as a Prometheus sample."""
    assert text.endswith("\n")
    for line in text.strip().split("\n"):
        if line.startswith("# HELP ") or line.startswith("# TYPE "):
            continue
        assert _PROM_LINE.match(line), f"bad scrape line: {line!r}"


def test_render_prometheus_scrape_format():
    reg = MetricsRegistry()
    reg.describe("a_total", "a counter")
    reg.inc("a_total", labels={"user": "alice"})
    reg.set_gauge("g", 1.5)
    reg.observe("h_seconds", 0.01, labels={"name": "tick"})
    text = reg.render()
    assert_prometheus_text(text)
    assert "# HELP a_total a counter" in text
    assert "# TYPE a_total counter" in text
    assert 'a_total{user="alice"} 1' in text
    assert "# TYPE h_seconds summary" in text
    assert 'h_seconds{name="tick",quantile="0.5"}' in text
    assert 'h_seconds_sum{name="tick"}' in text
    assert 'h_seconds_count{name="tick"} 1' in text


# ============================================================= tracer

def test_disabled_tracer_is_inert():
    tr = Tracer()
    sp = tr.span("anything", app_id="app-1")
    assert not sp                               # the shared falsy no-op
    with sp as s:
        s.set(key="ignored")
    tr.record("done", 0.0, 1.0)
    tr.bind("app-1")
    assert tr.spans() == []
    assert tr.context() is None
    assert tr.current_request_id() is None
    assert tr.block_trace("app-1") is None


def check_span_forest(spans):
    """The structural invariants every exported trace must satisfy:
    (1) each parent_id names a span in the set (no dangling edges),
    (2) parent chains terminate at a root (no cycles),
    (3) parent and child agree on the trace id."""
    by_id = {s.span_id: s for s in spans}
    for s in spans:
        if s.parent_id is None:
            continue
        assert s.parent_id in by_id, f"{s.name}: dangling parent"
        assert by_id[s.parent_id].trace_id == s.trace_id
        seen, cur = set(), s
        while cur.parent_id is not None:
            assert cur.span_id not in seen, f"{s.name}: parent cycle"
            seen.add(cur.span_id)
            cur = by_id[cur.parent_id]


@settings(max_examples=20)
@given(st.lists(st.integers(min_value=0, max_value=3),
                min_size=1, max_size=8))
def test_span_parenting_property(depths):
    """Random nesting (same-thread stacks + cross-'thread' ctx handoffs):
    the exported forest always satisfies ``check_span_forest`` and each
    nested child opens within its parent's window."""
    tr = Tracer().enable()
    for d in depths:
        open_spans = [tr.span("root")]
        for i in range(d):
            open_spans.append(tr.span(f"nest{i}"))
        # one cross-thread-style handoff per chain: explicit ctx parent
        ctx = tr.context()
        t0 = time.perf_counter()
        tr.record("queue-wait", t0, time.perf_counter(), ctx=ctx)
        for sp in reversed(open_spans):
            sp.__exit__(None, None, None)
    spans = tr.spans()
    assert len(spans) == sum(d + 2 for d in depths)
    check_span_forest(spans)
    by_id = {s.span_id: s for s in spans}
    for s in spans:
        if s.parent_id is not None:
            assert s.t0 >= by_id[s.parent_id].t0


def test_queue_and_exec_spans_tile_daemon_call(tmp_path):
    """Background daemon: the pump's queue-wait and exec spans for one
    command share the claim timestamp (queue.t1 == exec.t0 exactly) and
    both parent back to the caller's ``daemon.call`` span."""
    d = make_daemon(tmp_path, background=True, tick_interval_s=0.01,
                    trace=True)
    try:
        app, grant = d.submit("alice", "traced", 1)
        assert grant is not None
    finally:
        d.stop()
    spans = {s.name: s for s in TRACER.spans()}
    call = spans["daemon.call:submit"]
    queue = spans["daemon.queue:submit"]
    execs = spans["daemon.exec:submit"]
    assert queue.trace_id == execs.trace_id == call.trace_id
    assert queue.parent_id == call.span_id
    assert execs.parent_id == call.span_id
    assert queue.t1 == execs.t0                 # exact tiling (shared claim)
    assert call.t0 <= queue.t0 and execs.t1 <= call.t1
    check_span_forest(list(TRACER.spans()))


def test_trace_context_survives_preempt_resume(tmp_path):
    """The block binding keys the trace by app_id and outlives the
    runtime object: engine spans recorded after a preempt/resume
    round-trip join the same trace the submit request opened.  (The
    preempt/resume *control* spans correctly belong to their own admin
    requests' traces.)"""
    d = make_daemon(tmp_path, trace=True)
    app, _ = d.submit("alice", "w", 1, job=SimJobSpec(step_s=0.001))
    trace0 = TRACER.block_trace(app)
    assert trace0 is not None
    d.autostep_enable(app)
    d.autostep_round(now=1.0)
    before = [s for s in TRACER.spans(app_id=app) if s.cat == "engine"]
    assert before and all(s.trace_id == trace0 for s in before)

    d.preempt(app, reason="obs test")
    d.resume(app)
    assert TRACER.block_trace(app) == trace0    # binding survived
    d.autostep_enable(app)
    d.autostep_round(now=2.0)
    after = [s for s in TRACER.spans(app_id=app) if s.cat == "engine"]
    assert len(after) > len(before)             # new post-resume spans...
    assert all(s.trace_id == trace0 for s in after)   # ...same trace
    names = {s.name for s in TRACER.spans(app_id=app)}
    assert "ctl.preempt" in names and "ctl.resume" in names
    check_span_forest(list(TRACER.spans()))


# ===================================================== flight recorder

def test_flight_recorder_dump_on_pod_death(tmp_path):
    """Killing a pod writes a postmortem artifact holding the victims'
    final events and spans, publishes a ``postmortem`` event, and the
    artifact file lands under <ckpt_root>/postmortems."""
    d = make_daemon(tmp_path, n_pods=2, trace=True)
    app, _ = d.submit("alice", "victim", 1,
                      job=SimJobSpec(step_s=0.001))
    pod = d.status(app)["pod"]
    victims = d.fail_pod(pod, reason="chaos test")
    assert app in victims
    dumps = RECORDER.dumps()
    assert dumps and dumps[0]["reason"] == "pod_death"
    art = RECORDER.read(dumps[0]["name"])
    assert app in art["apps"]
    assert any(e["app_id"] == app for e in art["events"])
    assert art["per_app_events"][app], "victim's event tail missing"
    assert any(s.get("app_id") == app or s.get("name") == "ctl.preempt"
               for s in art["spans"]), "victim's final spans missing"
    path = dumps[0]["path"]
    assert path and path.startswith(str(tmp_path))
    with open(path) as f:
        on_disk = json.load(f)
    assert on_disk["reason"] == "pod_death"
    assert on_disk["detail"]["pod"] == pod
    # the dump announces itself on the bus and in the counters
    assert any(e.kind == "postmortem" for e in d.events_since(0))
    assert REGISTRY.counter_total("repro_postmortems_total") >= 1


def test_flight_recorder_in_memory_without_dir():
    rec = FlightRecorder(max_events=8)
    meta = rec.dump("unit", apps=None, now=1.0, detail={"x": 1})
    assert meta["path"] is None                 # no dir: in-memory only
    assert rec.last["detail"] == {"x": 1}
    assert rec.read(meta["name"])["reason"] == "unit"
    assert rec.read("nope") is None


# ============================================================ gateway

@pytest.fixture
def gw(tmp_path):
    """Traced background daemon + HTTP gateway (small body cap so the
    413 path is testable with a reasonable payload)."""
    topo = Topology(n_pods=1, pod_x=4, pod_y=2)
    dev = jax.devices()[0]
    daemon = ClusterDaemon(topo, devices=[dev] * topo.n_chips,
                           ckpt_root=str(tmp_path / "ckpt"),
                           background=True, tick_interval_s=0.01,
                           trace=True)
    profiles = ProfileStore([
        UserProfile("alice", "tok-alice", priority=0),
        UserProfile("root", "tok-admin", admin=True),
    ])
    server = GatewayServer(daemon, profiles,
                           max_body_bytes=4096).start()
    yield server, daemon
    server.stop()
    daemon.stop()


def test_metrics_endpoint_scrapes(gw):
    """GET /metrics needs no auth and returns valid Prometheus text
    including the pump-loop and admission-wait histograms."""
    server, daemon = gw
    # a queued admission so the admission-wait histogram has a sample:
    # alice fills the pod, the second submit waits, expiring the first
    # admits it
    s, a, _ = req(server, "POST", "/v1/submit", "tok-alice",
                  {"n_chips": 8, "job": SIM})
    assert s == 201 and a["admitted"]
    s, b, _ = req(server, "POST", "/v1/submit", "tok-alice",
                  {"n_chips": 8, "job": SIM})
    assert s == 201 and not b["admitted"]
    req(server, "POST", f"/v1/blocks/{a['app_id']}/expire", "tok-alice",
        {})
    deadline = time.monotonic() + 5.0
    while time.monotonic() < deadline:
        s, st, _ = req(server, "GET", f"/v1/blocks/{b['app_id']}",
                       "tok-alice")
        if st["state"] == "running":
            break
        time.sleep(0.02)
    time.sleep(0.05)                  # a few pump ticks for the histogram
    r = urllib.request.urlopen(server.url + "/metrics")   # no auth header
    assert r.status == 200
    assert r.headers["Content-Type"].startswith("text/plain")
    text = r.read().decode()
    assert_prometheus_text(text)
    assert 'repro_pump_tick_seconds{quantile="0.5"}' in text
    assert "repro_admission_wait_seconds_count" in text
    assert "repro_http_requests_total" in text
    assert 'repro_admissions_total{path="queued"}' in text
    # the dashboard's obs report mirrors the same counters
    obs = daemon.obs_report()
    assert obs["trace_enabled"] is True
    assert obs["pump_tick"]["count"] > 0
    assert obs["admission_wait"]["count"] >= 1


def test_request_id_echoed_minted_and_correlated(gw):
    """The gateway echoes a caller's X-Request-ID (minting one when
    absent) and the id rides the trace into event payloads."""
    server, daemon = gw
    before = daemon.bus.latest_seq
    s, a, hdrs = req(server, "POST", "/v1/submit", "tok-alice",
                     {"n_chips": 1, "job": SIM},
                     headers={"X-Request-ID": "req-corr-42"})
    assert s == 201
    assert hdrs["X-Request-ID"] == "req-corr-42"
    evs = [e for e in daemon.events_since(before)
           if e.app_id == a["app_id"]]
    assert evs and all(e.payload.get("request_id") == "req-corr-42"
                       for e in evs if e.kind in ("registered", "admitted"))
    # no header -> one is minted
    _, _, hdrs = req(server, "GET", "/v1/profile", "tok-alice")
    assert hdrs["X-Request-ID"].startswith("req-")


def test_trace_endpoints_chrome_json(gw):
    """/v1/trace (admin) and /v1/blocks/<id>/trace (owner) export valid
    Chrome-trace JSON with a connected span forest: the HTTP request
    span, the daemon queue/exec spans and the scheduler's submit span
    all share the request's trace."""
    server, _ = gw
    s, a, _ = req(server, "POST", "/v1/submit", "tok-alice",
                  {"n_chips": 1, "job": SIM})
    assert s == 201
    app = a["app_id"]
    s, tr, _ = req(server, "GET", "/v1/trace", "tok-admin")
    assert s == 200 and tr["displayTimeUnit"] == "ms"
    for ev in tr["traceEvents"]:
        assert ev["ph"] == "X"
        assert isinstance(ev["ts"], (int, float))
        assert isinstance(ev["dur"], (int, float)) and ev["dur"] >= 0
        assert ev["args"]["trace_id"]
    s, btr, _ = req(server, "GET", f"/v1/blocks/{app}/trace", "tok-alice")
    assert s == 200
    names = {e["name"] for e in btr["traceEvents"]}
    assert any(n.startswith("http.POST:/v1/submit") for n in names)
    assert "daemon.exec:submit" in names
    assert "sched.submit" in names
    traces = {e["args"]["trace_id"] for e in btr["traceEvents"]}
    assert len(traces) == 1                     # one connected trace
    # non-admin cannot read the global trace
    s, _, _ = req(server, "GET", "/v1/trace", "tok-alice")
    assert s == 403


def test_http_413_and_429_counters(gw, tmp_path):
    server, daemon = gw
    big = {"junk": "x" * 8192}                  # > the fixture's 4096 cap
    s, body, _ = req(server, "POST", "/v1/submit", "tok-alice", big)
    assert s == 413 and "exceeds" in body["error"]
    assert REGISTRY.counter_total("repro_http_413_total") >= 1
    # a rate-limited server: burst of 1, negligible refill -> second
    # request trips 429 (shares the daemon; the limiter is per-server)
    limited = GatewayServer(daemon, ProfileStore([
        UserProfile("alice", "tok-limited")]),
        rate_limit_rps=0.001, rate_limit_burst=1).start()
    try:
        s1, _, _ = req(limited, "GET", "/v1/profile", "tok-limited")
        s2, body2, _ = req(limited, "GET", "/v1/profile", "tok-limited")
        assert s1 == 200 and s2 == 429
        assert "retry_after_s" in body2
    finally:
        limited.stop()
    assert REGISTRY.counter_total("repro_http_429_total") >= 1
    rep = daemon.cluster_report()
    assert rep["obs"]["http_413"] >= 1 and rep["obs"]["http_429"] >= 1


def test_postmortem_endpoints(gw):
    server, daemon = gw
    RECORDER.dump("manual", apps=None, now=2.0, detail={"why": "test"})
    s, lst, _ = req(server, "GET", "/v1/postmortems", "tok-admin")
    assert s == 200 and lst["postmortems"]
    name = lst["postmortems"][0]["name"]
    s, art, _ = req(server, "GET", f"/v1/postmortems/{name}", "tok-admin")
    assert s == 200 and art["detail"] == {"why": "test"}
    s, _, _ = req(server, "GET", "/v1/postmortems/nope", "tok-admin")
    assert s == 404
    s, _, _ = req(server, "GET", "/v1/postmortems", "tok-alice")
    assert s == 403                             # admin-only
    # the access log recorded all of the above with latencies
    s, acc, _ = req(server, "GET", "/v1/access?limit=10", "tok-admin")
    assert s == 200 and acc["access"]
    entry = acc["access"][0]
    assert {"t", "method", "path", "status", "ms",
            "request_id"} <= set(entry)


def test_straggler_surfaces_in_status_and_report(tmp_path):
    """A block whose EWMA step time blows past 1.5x its median is
    flagged in ``status()`` and counted in the obs report gauge."""
    d = make_daemon(tmp_path)
    app, _ = d.submit("alice", "slowpoke", 1,
                      job=SimJobSpec(step_s=0.001))
    blk_id = d.registry.get(app).block_id
    mon = d.ctl.monitor
    for _ in range(16):
        mon.record_step(blk_id, 0.01, 1)
    assert d.status(app)["straggler"] is False
    for _ in range(16):                         # EWMA rises, median lags
        mon.record_step(blk_id, 0.1, 1)
    assert d.status(app)["straggler"] is True
    obs = d.obs_report()
    assert blk_id in obs["stragglers"]
    assert REGISTRY.gauge_value("repro_stragglers") == len(
        obs["stragglers"])
