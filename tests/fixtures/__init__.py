"""Seeded-violation corpus for the analyzer tests.

Every ``seeded_*.py`` module here contains a deliberate concurrency or
lifecycle bug that ``python -m repro.analysis`` must flag — they are the
analyzer's regression fixtures, parsed (never imported/executed) by
tests/test_analysis.py.  Do NOT "fix" them.
"""
