"""SEEDED BUG: lock-order inversion (Alpha._lock <-> Beta._lock).

``Alpha.ping`` takes Alpha._lock then calls ``Beta.poke`` (which takes
Beta._lock); ``Beta.ping`` does the mirror image.  Two threads running the
two ``ping``s concurrently can deadlock.  The analyzer must report a
``lock-order-cycle`` finding for this module.
"""
import threading


class Alpha:
    def __init__(self):
        self._lock = threading.Lock()
        self.peer = Beta()
        self.hits = 0

    def ping(self):
        with self._lock:
            self.hits += 1
            self.peer.poke()        # Beta._lock under Alpha._lock

    def poke(self):
        with self._lock:
            self.hits += 1


class Beta:
    def __init__(self):
        self._lock = threading.Lock()
        self.owner = Alpha()
        self.hits = 0

    def ping(self):
        with self._lock:
            self.hits += 1
            self.owner.poke()       # Alpha._lock under Beta._lock: cycle

    def poke(self):
        with self._lock:
            self.hits += 1
