"""SEEDED BUG: the falsy-zero model-time trap.

``now or time.time()`` silently replaces an explicit ``now=0.0`` (model
time zero — a perfectly valid simulated clock reading) with wall-clock
time.  The analyzer must produce a ``falsy-zero-param`` finding for each
truthiness test below.
"""
import time


def expired(deadline_at, now=None):
    now = now or time.time()
    return now >= deadline_at


def remaining(deadline_at, now=None):
    if not now:
        now = time.time()
    return max(0.0, deadline_at - now)
