"""SEEDED BUGS: lock-discipline violations.

``Counter.add`` establishes that ``total`` is guarded by ``_lock``;
``Counter.sneak`` then mutates it bare — the analyzer must produce a
``lock-discipline`` finding.  ``Counter.double`` calls ``snapshot`` (which
re-acquires the same non-reentrant lock) while holding it — a
``lock-self-deadlock`` finding.
"""
import threading


class Counter:
    def __init__(self):
        self._lock = threading.Lock()
        self.total = 0

    def add(self, n):
        with self._lock:
            self.total += n

    def sneak(self, n):
        self.total += n

    def snapshot(self):
        with self._lock:
            return self.total

    def double(self):
        with self._lock:
            return self.snapshot() * 2
