"""SEEDED BUGS: event-taxonomy violations.

Three ``unknown-event-kind`` hits the analyzer must produce: a publish of
an undeclared kind, a subscribe filter on an undeclared kind, and a dead
``ev.kind == ...`` consumer branch (the renamed-kind failure mode).
"""


def announce_reboot(bus, app_id):
    bus.publish("block_rebooted", app_id=app_id)


def watch_admissions(bus):
    return bus.subscribe(kinds={"state", "rebooted"})


def on_event(ev):
    if ev.kind == "warp":
        return "engaged"
    return None
