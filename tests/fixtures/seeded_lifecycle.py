"""SEEDED BUGS: lifecycle-transition violations.

Three distinct rule hits the analyzer must produce for this module:

* ``illegal-transition-target`` — nothing may transition back to REQUESTED;
* ``state-assign-bypass`` — direct ``blk.state = ...`` store skips
  Block.transition's validation and history log;
* ``illegal-transition-edge`` — a dominating guard pins the state to DONE,
  and DONE -> CONFIRMED is not in TRANSITIONS.
"""
from repro.core.block import BlockState


def resurrect(blk):
    blk.transition(BlockState.REQUESTED, "resurrect")


def force_running(blk):
    blk.state = BlockState.RUNNING


def reconfirm_done(blk):
    assert blk.state == BlockState.DONE
    blk.transition(BlockState.CONFIRMED, "redo the confirmation")
