"""Optimizer, quantized state, grad compression, checkpoint, and an
end-to-end loss-goes-down integration test with checkpoint-resume
equivalence."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import repro.configs as C
from repro.checkpoint.manager import CheckpointManager
from repro.data import pipeline
from repro.models.config import ShapeConfig
from repro.train import grad_compression as gc
from repro.train import optimizer as opt_lib
from repro.train import quantized_state as qs
from repro.train import train_step as train_lib

KEY = jax.random.PRNGKey(3)


# ----------------------------------------------------------------- adamw

def test_adamw_converges_quadratic():
    cfg = opt_lib.OptConfig(lr=0.1, warmup_steps=0, total_steps=200,
                            weight_decay=0.0, min_lr_frac=1.0)
    target = jnp.array([1.5, -2.0, 0.5])
    params = {"w": jnp.zeros(3)}
    state = opt_lib.init(params, cfg)
    for _ in range(150):
        grads = {"w": 2 * (params["w"] - target)}
        params, state, _ = opt_lib.apply(cfg, params, state, grads)
    np.testing.assert_allclose(params["w"], target, atol=0.05)


def test_adamw_clipping():
    cfg = opt_lib.OptConfig(clip_norm=1.0, warmup_steps=0)
    params = {"w": jnp.zeros(4)}
    state = opt_lib.init(params, cfg)
    huge = {"w": jnp.full(4, 1e6)}
    _, _, metrics = opt_lib.apply(cfg, params, state, huge)
    assert float(metrics["grad_norm"]) > 1e6  # reported unclipped


def test_schedule_warmup_cosine():
    cfg = opt_lib.OptConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            min_lr_frac=0.1)
    assert float(opt_lib.schedule(cfg, jnp.int32(5))) == pytest.approx(0.5)
    assert float(opt_lib.schedule(cfg, jnp.int32(10))) == pytest.approx(1.0)
    assert float(opt_lib.schedule(cfg, jnp.int32(100))) == pytest.approx(0.1)


def test_adamw_int8_states_track_fp32():
    """8-bit Adam should land near the fp32 trajectory on a toy problem."""
    target = jnp.array([1.5, -2.0, 0.5, 3.0] * 64)   # 256 elems = 1 block
    def run(bits):
        cfg = opt_lib.OptConfig(lr=0.05, warmup_steps=0, total_steps=300,
                                weight_decay=0.0, min_lr_frac=1.0,
                                state_bits=bits)
        params = {"w": jnp.zeros_like(target)}
        state = opt_lib.init(params, cfg)
        for _ in range(200):
            grads = {"w": 2 * (params["w"] - target)}
            params, state, _ = opt_lib.apply(cfg, params, state, grads)
        return params["w"]
    w8, w32 = run(8), run(None)
    np.testing.assert_allclose(w8, target, atol=0.15)
    np.testing.assert_allclose(w8, w32, atol=0.15)


def test_adamw_fused_dispatch_matches_reference():
    """``fused="jnp"`` replays ``_adam_leaf`` literally, so ``apply`` must be
    bitwise identical to the composed ``fused="off"`` reference for both
    state formats — across the scan_stacked layer-slice path, a ragged
    matrix, a 1-D vector, and a scalar leaf — over two steps so the
    requantized state feeds back through the dispatcher."""
    ks = jax.random.split(KEY, 8)
    params = {
        "stack": jax.random.normal(ks[0], (4, 256, 256)).astype(jnp.bfloat16),
        "w": jax.random.normal(ks[1], (8, 300)).astype(jnp.bfloat16),
        "b": jax.random.normal(ks[2], (257,), jnp.float32),
        "t": jnp.float32(0.3),
    }
    grads = {k: jax.random.normal(kk, p.shape, jnp.float32)
             for (k, p), kk in zip(sorted(params.items()), ks[4:])}

    def run(fused, bits):
        cfg = opt_lib.OptConfig(state_bits=bits, fused=fused)
        state = opt_lib.init(params, cfg)
        p2, s2, _ = opt_lib.apply(cfg, params, state, grads)
        return opt_lib.apply(cfg, p2, s2, grads)[:2]

    for bits in (None, 8):
        ref, out = run("off", bits), run("jnp", bits)
        eq = jax.tree.map(lambda a, b: bool(jnp.array_equal(a, b)), ref, out)
        assert all(jax.tree.leaves(eq)), (bits, eq)


# ------------------------------------------------------- quantized state

@given(st.integers(1, 900), st.floats(0.01, 100.0))
@settings(max_examples=30, deadline=None)
def test_quantize_roundtrip_error_bound(n, scale):
    """Property: blockwise int8 roundtrip error <= blockmax/127."""
    x = jnp.sin(jnp.arange(n, dtype=jnp.float32) * 0.7) * scale
    q = qs.quantize(x)
    back = qs.dequantize(q)
    assert back.shape == x.shape
    err = np.max(np.abs(np.asarray(back - x)))
    assert err <= scale / 127.0 * 1.01 + 1e-7


def test_quantize_multidim():
    x = jax.random.normal(KEY, (3, 5, 300))
    back = qs.dequantize(qs.quantize(x))
    assert back.shape == x.shape
    assert np.max(np.abs(np.asarray(back - x))) < np.max(np.abs(x)) / 100


def test_quantize_scalar_leaf():
    x = jnp.float32(0.37)
    st_ = qs.quantize(x)
    assert st_["q"].shape == () and st_["s"].shape == (1,)
    back = qs.dequantize(st_)
    assert back.shape == ()
    assert abs(float(back) - 0.37) <= 0.37 / 127 * 1.01


def test_quantize_zero_tensor():
    x = jnp.zeros((3, 700))
    st_ = qs.quantize(x)
    assert np.all(np.asarray(st_["s"]) == 1.0)   # amax=0 -> scale 1, not 0/0
    assert np.all(np.asarray(st_["q"]) == 0)
    assert jnp.array_equal(qs.dequantize(st_), x)


def test_zeros_like_quantized_shapes():
    for shape in [(), (5,), (300,), (2, 3, 513)]:
        p = jnp.zeros(shape, jnp.bfloat16)
        st_ = qs.zeros_like_quantized(p)
        assert st_["q"].shape == shape
        nb = -(-(shape[-1] if shape else 1) // qs.BLOCK)
        assert st_["s"].shape == ((*shape[:-1], nb) if shape else (nb,))
        assert jnp.array_equal(qs.dequantize(st_), jnp.zeros(shape))


def test_pad_to_block_edges():
    x, pad = qs._pad_to_block(jnp.ones((2, 256)))
    assert pad == 0 and x.shape == (2, 256)
    x, pad = qs._pad_to_block(jnp.ones((2, 257)))
    assert pad == 255 and x.shape == (2, 512)
    assert float(x[0, 257]) == 0.0   # zero fill
    x, pad = qs._pad_to_block(jnp.ones((1,)))
    assert pad == 255 and x.shape == (256,)


# -------------------------------------------------------- grad compression

def test_compression_error_feedback_property():
    """EF property: sum of (quantized + carried error) over steps converges
    to the true gradient sum (error does not accumulate unboundedly)."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(size=257).astype(np.float32))
    err = jnp.zeros_like(g_true)
    total_q = jnp.zeros_like(g_true)
    for _ in range(50):
        codes, scale, err = gc.compress_residual(g_true, err)
        total_q = total_q + gc.dequantize(codes, scale)
    np.testing.assert_allclose(total_q / 50, g_true,
                               atol=float(jnp.abs(g_true).max()) / 100)


def test_quantize_exact_for_uniform():
    g = jnp.full((128,), 0.5)
    codes, scale = gc.quantize(g)
    np.testing.assert_allclose(gc.dequantize(codes, scale), g, rtol=1e-6)


# ------------------------------------------------------------- checkpoint

def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), namespace="t")
    tree = {"a": jnp.arange(8, dtype=jnp.bfloat16),
            "b": {"c": jnp.ones((3, 3)), "d": jnp.int32(7)},
            "count": 5}
    mgr.save(3, tree)
    restored, step = mgr.restore(tree)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(restored["a"], np.float32),
                                  np.asarray(tree["a"], np.float32))
    assert restored["count"] == 5
    assert restored["a"].dtype == jnp.bfloat16


def test_checkpoint_gc_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), namespace="t", keep=2)
    tree = {"x": jnp.zeros(4)}
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert mgr.steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_checkpoint_corruption_detected(tmp_path):
    mgr = CheckpointManager(str(tmp_path), namespace="t")
    tree = {"x": jnp.arange(100, dtype=jnp.float32)}
    path = mgr.save(1, tree)
    leaf = os.path.join(path, "leaf_00000.npy")
    arr = np.load(leaf)
    arr[0] = 999.0
    np.save(leaf, arr)
    with pytest.raises(IOError, match="crc"):
        mgr.restore(tree)


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), namespace="t")
    tree = {"x": jnp.ones((64, 64))}
    mgr.save_async(1, tree)
    mgr.wait()
    restored, _ = mgr.restore(tree)
    np.testing.assert_array_equal(restored["x"], tree["x"])


def test_accum_dtype_policy():
    big = jnp.zeros((2048, 2048))        # 4M elements: at the threshold
    small = jnp.zeros((256, 256))
    assert train_lib.accum_dtype("mixed", big) == jnp.bfloat16
    assert train_lib.accum_dtype("mixed", small) == jnp.float32
    assert train_lib.accum_dtype("f32", big) == jnp.float32
    assert train_lib.accum_dtype("mixed", small, threshold=0) == jnp.bfloat16


def test_train_step_mixed_accum_close_to_f32():
    """``accum="mixed"`` with the threshold forced to 0 (every leaf
    accumulates in bf16) must track the fp32-accumulator loss trajectory
    within bf16 accumulation error, while actually perturbing the params
    (proof the bf16 path ran)."""
    cfg = C.get_smoke("deepseek_7b")
    shape = ShapeConfig("t", "train", seq_len=64, global_batch=4,
                        microbatch=2)
    opt_cfg = opt_lib.OptConfig(lr=1e-3, warmup_steps=2, total_steps=20)
    data = pipeline.DataIterator(cfg, shape)

    def run(**kw):
        step = jax.jit(train_lib.make_train_step(cfg, shape, opt_cfg, **kw))
        state = train_lib.make_train_state(cfg, KEY, opt_cfg)
        losses = []
        for i in range(6):
            state, m = step(state, data.batch(i))
            losses.append(float(m["loss"]))
        return losses, state

    l_f32, s_f32 = run()
    l_mix, s_mix = run(accum="mixed", accum_threshold=0)
    np.testing.assert_allclose(l_mix, l_f32, rtol=0.02, atol=0.02)
    leaves_f32 = jax.tree.leaves(s_f32["params"])
    leaves_mix = jax.tree.leaves(s_mix["params"])
    assert any(not bool(jnp.array_equal(a, b))
               for a, b in zip(leaves_f32, leaves_mix)), \
        "bf16 accumulation produced bitwise-identical params — path not taken?"
    # default threshold: no smoke-model leaf reaches 4M elems, so "mixed"
    # must be bitwise identical to "f32"
    l_mix_def, s_def = run(accum="mixed")
    assert l_mix_def == l_f32
    eq = jax.tree.map(lambda a, b: bool(jnp.array_equal(a, b)),
                      s_f32["params"], s_def["params"])
    assert all(jax.tree.leaves(eq))


def test_train_step_overlap_comm_matches_serial_single_pod():
    """``overlap_comm`` on a 1-pod mesh degenerates to per-microbatch int8
    quantization with error feedback — the loss trajectory must track the
    serial path within compression tolerance.  (Real multi-pod reduction is
    covered in test_multidevice.py.)"""
    cfg = C.get_smoke("deepseek_7b")
    shape = ShapeConfig("t", "train", seq_len=64, global_batch=4,
                        microbatch=2)
    opt_cfg = opt_lib.OptConfig(lr=1e-3, warmup_steps=2, total_steps=20)
    data = pipeline.DataIterator(cfg, shape)
    mesh = jax.make_mesh((1,), ("pod",))

    def run(**kw):
        step = jax.jit(train_lib.make_train_step(cfg, shape, opt_cfg, **kw))
        state = train_lib.make_train_state(cfg, KEY, opt_cfg)
        losses = []
        for i in range(6):
            state, m = step(state, data.batch(i))
            losses.append(float(m["loss"]))
        return losses

    base = run()
    over = run(overlap_comm=True, mesh=mesh)
    np.testing.assert_allclose(over, base, rtol=0.05, atol=0.05)


def test_train_step_overlap_comm_requires_pod_axis():
    cfg = C.get_smoke("deepseek_7b")
    shape = ShapeConfig("t", "train", seq_len=32, global_batch=4,
                        microbatch=2)
    with pytest.raises(AssertionError):
        train_lib.make_train_step(cfg, shape, opt_lib.OptConfig(),
                                  overlap_comm=True, mesh=None)


# --------------------------------------------------------- compile cache

def test_compile_cache_freeze_is_hashable_and_order_insensitive():
    from repro.train import compile_cache as cc
    cfg = opt_lib.OptConfig()
    k = cc.freeze(cfg)
    hash(k)                                         # usable as a dict key
    assert k[0] == "OptConfig"
    assert cc.freeze({"b": 2, "a": [1, {2}]}) == \
        cc.freeze({"a": (1, frozenset({2})), "b": 2})
    assert cc.mesh_fingerprint(None) == ("default",)
    mesh = jax.make_mesh((1,), ("pod",))
    fp = cc.mesh_fingerprint(mesh)
    assert fp[0] == (("pod", 1),) and len(fp[1]) == 1
    assert fp == cc.mesh_fingerprint(jax.make_mesh((1,), ("pod",)))


def test_compile_cache_hit_miss_and_events():
    from repro.core.events import EventBus
    from repro.train import compile_cache as cc

    cache = cc.CompileCache()
    bus = EventBus()
    cache.set_bus(bus)
    builds = []

    def builder():
        builds.append(1)
        return "artifact"

    assert cache.get(("k", 1), builder, label="unit") == "artifact"
    assert cache.get(("k", 1), builder, label="unit") == "artifact"
    assert cache.get(("k", 2), builder) == "artifact"
    assert builds == [1, 1]                         # second call was a hit
    assert cache.stats() == {"hits": 1, "misses": 2, "entries": 2}
    actions = [e.payload["action"]
               for e in bus.events_since(kinds={"compile"})]
    assert actions == ["miss", "hit", "miss"]
    cache.clear()
    assert cache.stats() == {"hits": 0, "misses": 0, "entries": 0}


# ----------------------------------------------------------- integration

@pytest.mark.slow
def test_training_reduces_loss_and_resumes(tmp_path):
    """30 steps of a tiny xlstm: loss decreases; stopping at 15 and resuming
    from checkpoint reproduces the same final loss (bitwise state restore)."""
    cfg = C.get_smoke("deepseek_7b")
    shape = ShapeConfig("t", "train", seq_len=64, global_batch=4,
                        microbatch=2)
    opt_cfg = opt_lib.OptConfig(lr=1e-3, warmup_steps=2, total_steps=30)
    step = jax.jit(train_lib.make_train_step(cfg, shape, opt_cfg))
    data = pipeline.DataIterator(cfg, shape)

    state = train_lib.make_train_state(cfg, KEY, opt_cfg)
    losses = []
    mgr = CheckpointManager(str(tmp_path), namespace="run")
    for i in range(30):
        state, m = step(state, data.batch(i))
        losses.append(float(m["loss"]))
        if i == 14:
            mgr.save(15, state)
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, losses

    # resume path
    state2 = train_lib.make_train_state(cfg, KEY, opt_cfg)
    state2, _ = mgr.restore(state2)
    losses2 = []
    for i in range(15, 30):
        state2, m = step(state2, data.batch(i))
        losses2.append(float(m["loss"]))
    np.testing.assert_allclose(losses2, losses[15:], rtol=1e-4)
