"""Optimizer, quantized state, grad compression, checkpoint, and an
end-to-end loss-goes-down integration test with checkpoint-resume
equivalence."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import repro.configs as C
from repro.checkpoint.manager import CheckpointManager
from repro.data import pipeline
from repro.models.config import ShapeConfig
from repro.train import grad_compression as gc
from repro.train import optimizer as opt_lib
from repro.train import quantized_state as qs
from repro.train import train_step as train_lib

KEY = jax.random.PRNGKey(3)


# ----------------------------------------------------------------- adamw

def test_adamw_converges_quadratic():
    cfg = opt_lib.OptConfig(lr=0.1, warmup_steps=0, total_steps=200,
                            weight_decay=0.0, min_lr_frac=1.0)
    target = jnp.array([1.5, -2.0, 0.5])
    params = {"w": jnp.zeros(3)}
    state = opt_lib.init(params, cfg)
    for _ in range(150):
        grads = {"w": 2 * (params["w"] - target)}
        params, state, _ = opt_lib.apply(cfg, params, state, grads)
    np.testing.assert_allclose(params["w"], target, atol=0.05)


def test_adamw_clipping():
    cfg = opt_lib.OptConfig(clip_norm=1.0, warmup_steps=0)
    params = {"w": jnp.zeros(4)}
    state = opt_lib.init(params, cfg)
    huge = {"w": jnp.full(4, 1e6)}
    _, _, metrics = opt_lib.apply(cfg, params, state, huge)
    assert float(metrics["grad_norm"]) > 1e6  # reported unclipped


def test_schedule_warmup_cosine():
    cfg = opt_lib.OptConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            min_lr_frac=0.1)
    assert float(opt_lib.schedule(cfg, jnp.int32(5))) == pytest.approx(0.5)
    assert float(opt_lib.schedule(cfg, jnp.int32(10))) == pytest.approx(1.0)
    assert float(opt_lib.schedule(cfg, jnp.int32(100))) == pytest.approx(0.1)


def test_adamw_int8_states_track_fp32():
    """8-bit Adam should land near the fp32 trajectory on a toy problem."""
    target = jnp.array([1.5, -2.0, 0.5, 3.0] * 64)   # 256 elems = 1 block
    def run(bits):
        cfg = opt_lib.OptConfig(lr=0.05, warmup_steps=0, total_steps=300,
                                weight_decay=0.0, min_lr_frac=1.0,
                                state_bits=bits)
        params = {"w": jnp.zeros_like(target)}
        state = opt_lib.init(params, cfg)
        for _ in range(200):
            grads = {"w": 2 * (params["w"] - target)}
            params, state, _ = opt_lib.apply(cfg, params, state, grads)
        return params["w"]
    w8, w32 = run(8), run(None)
    np.testing.assert_allclose(w8, target, atol=0.15)
    np.testing.assert_allclose(w8, w32, atol=0.15)


# ------------------------------------------------------- quantized state

@given(st.integers(1, 900), st.floats(0.01, 100.0))
@settings(max_examples=30, deadline=None)
def test_quantize_roundtrip_error_bound(n, scale):
    """Property: blockwise int8 roundtrip error <= blockmax/127."""
    x = jnp.sin(jnp.arange(n, dtype=jnp.float32) * 0.7) * scale
    q = qs.quantize(x)
    back = qs.dequantize(q)
    assert back.shape == x.shape
    err = np.max(np.abs(np.asarray(back - x)))
    assert err <= scale / 127.0 * 1.01 + 1e-7


def test_quantize_multidim():
    x = jax.random.normal(KEY, (3, 5, 300))
    back = qs.dequantize(qs.quantize(x))
    assert back.shape == x.shape
    assert np.max(np.abs(np.asarray(back - x))) < np.max(np.abs(x)) / 100


# -------------------------------------------------------- grad compression

def test_compression_error_feedback_property():
    """EF property: sum of (quantized + carried error) over steps converges
    to the true gradient sum (error does not accumulate unboundedly)."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(size=257).astype(np.float32))
    err = jnp.zeros_like(g_true)
    total_q = jnp.zeros_like(g_true)
    for _ in range(50):
        codes, scale, err = gc.compress_residual(g_true, err)
        total_q = total_q + gc.dequantize(codes, scale)
    np.testing.assert_allclose(total_q / 50, g_true,
                               atol=float(jnp.abs(g_true).max()) / 100)


def test_quantize_exact_for_uniform():
    g = jnp.full((128,), 0.5)
    codes, scale = gc.quantize(g)
    np.testing.assert_allclose(gc.dequantize(codes, scale), g, rtol=1e-6)


# ------------------------------------------------------------- checkpoint

def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), namespace="t")
    tree = {"a": jnp.arange(8, dtype=jnp.bfloat16),
            "b": {"c": jnp.ones((3, 3)), "d": jnp.int32(7)},
            "count": 5}
    mgr.save(3, tree)
    restored, step = mgr.restore(tree)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(restored["a"], np.float32),
                                  np.asarray(tree["a"], np.float32))
    assert restored["count"] == 5
    assert restored["a"].dtype == jnp.bfloat16


def test_checkpoint_gc_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), namespace="t", keep=2)
    tree = {"x": jnp.zeros(4)}
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert mgr.steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_checkpoint_corruption_detected(tmp_path):
    mgr = CheckpointManager(str(tmp_path), namespace="t")
    tree = {"x": jnp.arange(100, dtype=jnp.float32)}
    path = mgr.save(1, tree)
    leaf = os.path.join(path, "leaf_00000.npy")
    arr = np.load(leaf)
    arr[0] = 999.0
    np.save(leaf, arr)
    with pytest.raises(IOError, match="crc"):
        mgr.restore(tree)


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), namespace="t")
    tree = {"x": jnp.ones((64, 64))}
    mgr.save_async(1, tree)
    mgr.wait()
    restored, _ = mgr.restore(tree)
    np.testing.assert_array_equal(restored["x"], tree["x"])


# ----------------------------------------------------------- integration

@pytest.mark.slow
def test_training_reduces_loss_and_resumes(tmp_path):
    """30 steps of a tiny xlstm: loss decreases; stopping at 15 and resuming
    from checkpoint reproduces the same final loss (bitwise state restore)."""
    cfg = C.get_smoke("deepseek_7b")
    shape = ShapeConfig("t", "train", seq_len=64, global_batch=4,
                        microbatch=2)
    opt_cfg = opt_lib.OptConfig(lr=1e-3, warmup_steps=2, total_steps=30)
    step = jax.jit(train_lib.make_train_step(cfg, shape, opt_cfg))
    data = pipeline.DataIterator(cfg, shape)

    state = train_lib.make_train_state(cfg, KEY, opt_cfg)
    losses = []
    mgr = CheckpointManager(str(tmp_path), namespace="run")
    for i in range(30):
        state, m = step(state, data.batch(i))
        losses.append(float(m["loss"]))
        if i == 14:
            mgr.save(15, state)
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, losses

    # resume path
    state2 = train_lib.make_train_state(cfg, KEY, opt_cfg)
    state2, _ = mgr.restore(state2)
    losses2 = []
    for i in range(15, 30):
        state2, m = step(state2, data.batch(i))
        losses2.append(float(m["loss"]))
    np.testing.assert_allclose(losses2, losses[15:], rtol=1e-4)
