"""Deterministic stand-in for ``hypothesis`` when it is not installed.

Property-test modules import ``given``/``settings``/``st`` from here instead
of from ``hypothesis`` directly.  With hypothesis installed (see
requirements-dev.txt) they get the real thing; without it they fall back to
a small seeded example-drawing shim so the suite still collects and the
properties still run against boundary values plus deterministic random
draws (seeded per test, so failures reproduce).
"""
try:
    from hypothesis import given, settings, strategies
    st = strategies
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import inspect
    import random
    import zlib

    DEFAULT_MAX_EXAMPLES = 25

    class _Strategy:
        def example(self, rng):
            raise NotImplementedError

        def boundaries(self):
            """Deterministic edge-case examples drawn before random ones."""
            return []

    class _Integers(_Strategy):
        def __init__(self, lo, hi):
            self.lo, self.hi = lo, hi

        def example(self, rng):
            return rng.randint(self.lo, self.hi)

        def boundaries(self):
            return [self.lo, self.hi]

    class _Floats(_Strategy):
        def __init__(self, lo, hi):
            self.lo, self.hi = lo, hi

        def example(self, rng):
            return rng.uniform(self.lo, self.hi)

        def boundaries(self):
            return [self.lo, self.hi]

    class _SampledFrom(_Strategy):
        def __init__(self, elements):
            self.elements = list(elements)

        def example(self, rng):
            return rng.choice(self.elements)

        def boundaries(self):
            return [self.elements[0], self.elements[-1]]

    class _Lists(_Strategy):
        def __init__(self, elem, min_size=0, max_size=None):
            self.elem = elem
            self.min_size = min_size
            self.max_size = max_size if max_size is not None else min_size + 10

        def example(self, rng):
            n = rng.randint(self.min_size, self.max_size)
            return [self.elem.example(rng) for _ in range(n)]

        def boundaries(self):
            b = self.elem.boundaries() or [None]
            return [[b[0]] * self.min_size if b[0] is not None else []]

    class _Tuples(_Strategy):
        def __init__(self, *elems):
            self.elems = elems

        def example(self, rng):
            return tuple(e.example(rng) for e in self.elems)

        def boundaries(self):
            bs = [e.boundaries() for e in self.elems]
            if all(bs):
                return [tuple(b[0] for b in bs), tuple(b[-1] for b in bs)]
            return []

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Integers(min_value, max_value)

        @staticmethod
        def floats(min_value, max_value, **_kw):
            return _Floats(min_value, max_value)

        @staticmethod
        def sampled_from(elements):
            return _SampledFrom(elements)

        @staticmethod
        def lists(elements, min_size=0, max_size=None, **_kw):
            return _Lists(elements, min_size, max_size)

        @staticmethod
        def tuples(*elements):
            return _Tuples(*elements)

    strategies = st = _Strategies()

    class settings:
        """Decorator stub: records max_examples for the ``given`` wrapper."""

        def __init__(self, max_examples=DEFAULT_MAX_EXAMPLES, deadline=None,
                     **_kw):
            self.max_examples = max_examples

        def __call__(self, fn):
            fn._compat_max_examples = self.max_examples
            return fn

    def given(*arg_strategies, **kw_strategies):
        def deco(fn):
            sig = inspect.signature(fn)
            params = list(sig.parameters.values())
            # hypothesis binds positional strategies to the rightmost params
            pos_names = ([p.name for p in params][len(params)
                                                  - len(arg_strategies):]
                         if arg_strategies else [])
            strat_map = dict(zip(pos_names, arg_strategies))
            strat_map.update(kw_strategies)

            def wrapper(*args, **kwargs):
                n = getattr(fn, "_compat_max_examples", DEFAULT_MAX_EXAMPLES)
                seed = zlib.crc32(
                    f"{fn.__module__}.{fn.__qualname__}".encode())
                rng = random.Random(seed)
                for i in range(max(1, n)):
                    drawn = {}
                    for name, s in strat_map.items():
                        b = s.boundaries()
                        drawn[name] = b[i] if i < len(b) else s.example(rng)
                    fn(*args, **kwargs, **drawn)

            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__module__ = fn.__module__
            wrapper.__doc__ = fn.__doc__
            # hide strategy-bound params so pytest doesn't treat them as
            # fixtures (explicit __signature__ wins over __wrapped__)
            wrapper.__signature__ = sig.replace(
                parameters=[p for p in params if p.name not in strat_map])
            if hasattr(fn, "pytestmark"):
                wrapper.pytestmark = fn.pytestmark
            return wrapper

        return deco
