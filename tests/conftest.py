"""Shared test fixtures.  NOTE: no XLA_FLAGS device-count override here —
unit tests see the real single CPU device; multi-device behaviour is tested
via subprocesses (test_multidevice.py) per the dry-run isolation rule.

``REPRO_RACE_CHECK=1`` turns the whole suite into a race-detection corpus:
``repro.analysis.runtime_check`` instruments every lock created after
configure time (acquisition-order recording + deadlock-cycle detection +
serialized-section ownership), and the session-scoped gate below fails the
run if any violation was recorded by the end.
"""
import os

import jax
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
    if os.environ.get("REPRO_RACE_CHECK") == "1":
        from repro.analysis import runtime_check
        runtime_check.install()


@pytest.fixture(scope="session", autouse=True)
def _race_check_gate():
    """Assert the session recorded no lock-order or serialized-section
    violations.  Runs as the last session teardown; a violation fails the
    suite with the full list (the detectors record-and-continue so one bad
    interleaving doesn't hide the rest)."""
    yield
    if os.environ.get("REPRO_RACE_CHECK") != "1":
        return
    from repro.analysis import runtime_check
    vs = runtime_check.violations()
    assert not vs, ("runtime race check recorded violations:\n"
                    + "\n".join(f"  - {v}" for v in vs))
