"""Shared test fixtures.  NOTE: no XLA_FLAGS device-count override here —
unit tests see the real single CPU device; multi-device behaviour is tested
via subprocesses (test_multidevice.py) per the dry-run isolation rule.
"""
import jax
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
