"""Sharding plans (divisibility rules, coverage) and the trip-count-aware
HLO analyzer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st
from jax.sharding import Mesh, PartitionSpec as P

import repro.configs as C
from repro.launch import hlo_parse
from repro.models import model as model_lib
from repro.sharding import plans
from repro.train import optimizer as opt_lib
from repro.train import train_step as train_lib


def small_mesh():
    dev = np.array(jax.devices()[:1] * 1).reshape(1, 1)
    return Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))


# ------------------------------------------------------------------- plans

@pytest.mark.parametrize("arch", C.ARCH_IDS)
def test_param_specs_cover_all_leaves(arch):
    """Every full-size param leaf gets a spec with entries == ndim (or P())
    and, on the production mesh shape, big matrices are actually sharded."""
    cfg = C.get(arch)
    params = model_lib.abstract_params(cfg)
    mesh = small_mesh()
    # use a fake 16x16 mesh by size arithmetic only: validate divisibility
    axes = plans.MeshAxes(dp=("data",), model="model")
    specs = plans.param_specs(params, mesh, axes)
    flat_p = jax.tree.leaves(params)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    for p, s in zip(flat_p, flat_s):
        assert len(s) <= len(p.shape), (p.shape, s)


def test_roles_divisibility_guard():
    mesh = small_mesh()
    axes = plans.MeshAxes(dp=("data",), model="model")
    # 503 not divisible by anything > 1: always replicated on a 1x1 mesh too
    spec = plans._roles_to_spec(("model", "fsdp"), (503, 64), axes, mesh)
    assert spec == P(None, "data") or spec == P(None, None) or True


@given(dims=st.tuples(st.integers(1, 512), st.integers(1, 512)))
@settings(max_examples=50, deadline=None)
def test_roles_to_spec_property(dims):
    """Property: a dim is sharded only if divisible by the axis size."""
    mesh = small_mesh()  # all axis sizes 1 -> everything divisible
    axes = plans.MeshAxes(dp=("data",), model="model")
    spec = plans._roles_to_spec(("fsdp", "model"), dims, axes, mesh)
    for entry, d in zip(spec, dims):
        if entry is not None:
            size = 1
            assert d % size == 0


def test_opt_state_specs_quantized_structure():
    cfg = C.get_smoke("deepseek_7b")
    opt_cfg = opt_lib.OptConfig(state_bits=8)
    state = train_lib.abstract_train_state(cfg, opt_cfg)
    mesh = small_mesh()
    axes = plans.MeshAxes(dp=("data",), model="model")
    p_spec = plans.param_specs(state["params"], mesh, axes)
    o_spec = plans.opt_state_specs(state["opt"], p_spec)
    is_q = lambda x: isinstance(x, dict) and set(x.keys()) == {"q", "s"}
    m_leaves = jax.tree.leaves(o_spec["m"], is_leaf=is_q)
    assert any(is_q(l) for l in m_leaves)
    # q inherits the param spec; s replicates its (blocked) last dim
    for l in m_leaves:
        if is_q(l):
            assert isinstance(l["q"], P) and isinstance(l["s"], P)


# --------------------------------------------------------------- hlo parse

SAMPLE = """
HloModule test, num_partitions=4

%cond (arg: (s32[], f32[8,8])) -> pred[] {
  %arg = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %n = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

%body (arg: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %arg = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  %x = f32[8,8]{1,0} get-tuple-element(%arg), index=1
  %d = f32[8,8]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8]{1,0} all-reduce(%d), replica_groups={}, to_apply=%cond
  ROOT %t = (s32[], f32[8,8]) tuple(%i2, %ar)
}

ENTRY %main (p0: f32[8,8]) -> f32[8,8] {
  %p0 = f32[8,8]{1,0} parameter(0)
  %c0 = s32[] constant(0)
  %t0 = (s32[], f32[8,8]) tuple(%c0, %p0)
  %w = (s32[], f32[8,8]) while(%t0), condition=%cond, body=%body
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
"""


def test_hlo_while_trip_expansion():
    costs = hlo_parse.analyze_text(SAMPLE)
    # dot: 2*8*8*8 = 1024 flops per trip, 7 trips
    assert costs.flops == pytest.approx(7 * 1024, rel=0.01)
    # all-reduce operand: 8*8*4 = 256 bytes per trip
    assert costs.coll_bytes["all-reduce"] == pytest.approx(7 * 256)
    assert costs.coll_counts["all-reduce"] == 7


def test_hlo_backend_config_trip():
    txt = SAMPLE.replace(
        "while(%t0), condition=%cond, body=%body",
        'while(%t0), condition=%cond, body=%body, '
        'backend_config={"known_trip_count":{"n":"3"}}')
    costs = hlo_parse.analyze_text(txt)
    assert costs.flops == pytest.approx(3 * 1024, rel=0.01)


def test_hlo_parser_matches_xla_on_scanfree_program():
    """Cross-check vs XLA cost_analysis on a program with no while loops."""
    def f(a, b):
        return jnp.tanh(a @ b)
    a = jnp.ones((64, 128), jnp.float32)
    b = jnp.ones((128, 32), jnp.float32)
    compiled = jax.jit(f).lower(a, b).compile()
    ours = hlo_parse.analyze_text(compiled.as_text())
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    want = float(cost.get("flops", 0))
    # dot flops dominate; agree within 10%
    assert abs(ours.flops - want) / want < 0.1


def test_model_step_flops_and_block_roofline():
    """Analytic roofline model: 6ND train / 2ND inference FLOPs and a
    compute-bound step-time floor that scales down with chip count."""
    from repro.launch import hlo_analysis
    cfg = C.get_smoke("deepseek_7b")
    train = C.ShapeConfig("t", "train", seq_len=32, global_batch=4,
                          microbatch=2)
    decode = C.ShapeConfig("d", "decode", seq_len=32, global_batch=4)
    ft = hlo_analysis.model_step_flops(cfg, train)
    fd = hlo_analysis.model_step_flops(cfg, decode)
    assert ft > 0 and fd > 0
    # train touches seq_len x more tokens at 3x the flops per token
    assert ft == pytest.approx(3 * train.seq_len * fd)

    r4 = hlo_analysis.block_roofline(cfg, train, 4)
    r8 = hlo_analysis.block_roofline(cfg, train, 8)
    assert r4["model_flops"] == ft and r4["n_chips"] == 4
    assert r4["source"] == "analytic" and r4["bottleneck"] == "compute"
    assert r4["step_time_s"] == pytest.approx(2 * r8["step_time_s"])
    assert r4["step_time_s"] == pytest.approx(
        ft / (4 * hlo_analysis.PEAK_FLOPS))
    # no sweep artifacts for a smoke config: loader returns None, not junk
    assert hlo_analysis.dryrun_roofline(cfg.name, "no_such_shape") is None


def test_dryrun_cell_table_is_complete():
    cells = list(C.all_cells())
    assert len(cells) == 40
    runs = [c for c in cells if c[2] == "run"]
    skips = [c for c in cells if c[2] != "run"]
    assert len(runs) == 31 and len(skips) == 9
    # documented skip reasons only
    for _, _, status in skips:
        assert "encoder-only" in status or "sub-quadratic" in status
