"""CI step-time gate (benchmarks/check_step_time.py): floor rows must hold,
the wall-clock trend fails past 10% median regression, and --update rewrites
the committed baseline."""
import importlib.util
import json
import os

import pytest

_HERE = os.path.dirname(os.path.abspath(__file__))
_CHECK = os.path.join(_HERE, "..", "benchmarks", "check_step_time.py")


@pytest.fixture()
def gate(tmp_path, monkeypatch):
    spec = importlib.util.spec_from_file_location("check_step_time", _CHECK)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    monkeypatch.setattr(mod, "BASELINE", str(tmp_path / "baseline.json"))
    return mod


def doc(tmp_path, name, rows, ok=True):
    path = tmp_path / name
    path.write_text(json.dumps(
        {"section": "step_time", "ok": ok,
         "rows": [{"name": n, "us_per_call": str(us), "derived": str(d)}
                  for n, us, d in rows]}))
    return str(path)


GOOD_FLOORS = [
    ("opt_hbm_model_i8_speedup_model", 0, 5.6),
    ("opt_hbm_model_f32_speedup_model", 0, 1.3),
    ("overlap_hidden_frac_model", 0, 1.0),
]


def test_floors_pass_and_update_writes_baseline(gate, tmp_path):
    rows = GOOD_FLOORS + [("train_step_serial", 1000, 1.0)]
    path = doc(tmp_path, "run.json", rows)
    assert gate.main(["--update", path]) == 0
    assert os.path.exists(gate.BASELINE)
    # same numbers vs the fresh baseline: trend ratio 1.0, still green
    assert gate.main([path]) == 0


def test_unfused_kernel_fails_the_floor(gate, tmp_path):
    rows = [("opt_hbm_model_i8_speedup_model", 0, 1.2)] + GOOD_FLOORS[1:]
    assert gate.main([doc(tmp_path, "bad.json", rows)]) == 1


def test_missing_floor_row_fails(gate, tmp_path):
    assert gate.main([doc(tmp_path, "empty.json", GOOD_FLOORS[1:])]) == 1


def test_failed_bench_run_fails(gate, tmp_path):
    assert gate.main([doc(tmp_path, "crashed.json", GOOD_FLOORS,
                          ok=False)]) == 1


def test_trend_gate_median_regression(gate, tmp_path):
    base = GOOD_FLOORS + [("train_step_serial", 1000, 1.0),
                          ("train_step_overlap", 1000, 1.0),
                          ("opt_apply_i8_fused", 500, 1.0)]
    assert gate.main(["--update", doc(tmp_path, "base.json", base)]) == 0
    # one noisy row is tolerated (median of ratios)
    noisy = GOOD_FLOORS + [("train_step_serial", 2000, 1.0),
                           ("train_step_overlap", 1010, 1.0),
                           ("opt_apply_i8_fused", 505, 1.0)]
    assert gate.main([doc(tmp_path, "noisy.json", noisy)]) == 0
    # everything 20% slower = real regression
    slow = GOOD_FLOORS + [("train_step_serial", 1200, 1.0),
                          ("train_step_overlap", 1200, 1.0),
                          ("opt_apply_i8_fused", 600, 1.0)]
    assert gate.main([doc(tmp_path, "slow.json", slow)]) == 1
