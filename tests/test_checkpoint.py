"""CheckpointManager coverage: rotation honors keep=N, restore(step=None)
picks the latest step, save_async + wait round-trips bit-identically, and
the shape-mismatch guard for cross-geometry restores."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


def tree_at(step):
    """Distinct per-step content so 'which step restored' is observable."""
    return {
        "w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4) * step,
        "b": {"bf16": jnp.full((5,), 1.5 * step, dtype=jnp.bfloat16),
              "i": jnp.int32(step)},
        "count": step,
    }


def assert_bit_identical(a, b):
    xa = [np.asarray(l) for l in jax.tree_util.tree_leaves(a)]
    xb = [np.asarray(l) for l in jax.tree_util.tree_leaves(b)]
    assert len(xa) == len(xb)
    for x, y in zip(xa, xb):
        assert x.dtype == y.dtype and x.shape == y.shape
        assert x.tobytes() == y.tobytes()


def test_rotation_honors_keep(tmp_path):
    mgr = CheckpointManager(str(tmp_path), namespace="rot", keep=2)
    for s in (1, 2, 3, 4, 5):
        mgr.save(s, tree_at(s))
    assert mgr.steps() == [4, 5]            # oldest steps deleted
    for s in (1, 2, 3):
        assert not os.path.exists(
            os.path.join(mgr.dir, f"step_{s:08d}"))
    # survivors still restore
    restored, at = mgr.restore(tree_at(0), step=4)
    assert at == 4 and restored["count"] == 4


def test_restore_default_picks_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), namespace="latest", keep=5)
    for s in (3, 7, 11):
        mgr.save(s, tree_at(s))
    restored, at = mgr.restore(tree_at(0), step=None)
    assert at == 11
    assert restored["count"] == 11
    assert_bit_identical(restored, tree_at(11))
    # explicit older step still reachable
    restored7, at7 = mgr.restore(tree_at(0), step=7)
    assert at7 == 7 and restored7["count"] == 7


def test_save_async_wait_roundtrips_bit_identically(tmp_path):
    mgr = CheckpointManager(str(tmp_path), namespace="async", keep=3)
    tree = tree_at(9)
    mgr.save_async(9, tree)
    mgr.wait()
    restored, at = mgr.restore(tree_at(0))
    assert at == 9
    assert_bit_identical(restored, tree)
    # bf16 logical dtype survives the byte-view serialization
    assert restored["b"]["bf16"].dtype == jnp.bfloat16


def test_save_async_back_to_back_serializes(tmp_path):
    """A second save_async waits for the first; latest wins; no torn state."""
    mgr = CheckpointManager(str(tmp_path), namespace="serial", keep=5)
    for s in (1, 2, 3):
        mgr.save_async(s, tree_at(s))
    mgr.wait()
    assert mgr.steps() == [1, 2, 3]
    restored, at = mgr.restore(tree_at(0))
    assert at == 3 and restored["count"] == 3


def test_shape_mismatch_raises_informative(tmp_path):
    """Cross-geometry restore reshards *placement*; a logical shape change
    (different model config) must fail loudly, not silently truncate."""
    mgr = CheckpointManager(str(tmp_path), namespace="shape")
    mgr.save(1, {"w": jnp.zeros((3, 4))})
    with pytest.raises(ValueError, match="cross-geometry"):
        mgr.restore({"w": jnp.zeros((4, 4))})


def test_leftover_tmp_dir_is_ignored(tmp_path):
    """A crash mid-save leaves step_<n>.tmp; steps()/restore skip it and a
    re-save of the same step replaces it."""
    mgr = CheckpointManager(str(tmp_path), namespace="crash")
    mgr.save(1, tree_at(1))
    os.makedirs(os.path.join(mgr.dir, "step_00000002.tmp"))
    assert mgr.steps() == [1]
    restored, at = mgr.restore(tree_at(0))
    assert at == 1
    mgr.save(2, tree_at(2))
    assert mgr.steps() == [1, 2]
