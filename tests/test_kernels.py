"""Per-kernel allclose sweeps: Pallas (interpret mode) and the chunked jnp
production paths vs. the naive oracles in kernels/ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.rmsnorm import rmsnorm_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas

KEY = jax.random.PRNGKey(42)


def tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=3e-5, rtol=3e-4)


# ---------------------------------------------------------------- attention

ATTN_CASES = [
    # (B, Hq, Hkv, Sq, Sk, D, Dv, causal, window)
    (1, 2, 2, 16, 16, 16, 16, True, 0),
    (2, 4, 2, 48, 48, 32, 32, True, 0),       # GQA
    (1, 4, 1, 33, 65, 16, 16, True, 0),       # ragged sizes, MQA
    (2, 2, 2, 32, 32, 16, 16, False, 0),      # bidirectional
    (1, 2, 2, 64, 64, 16, 16, True, 24),      # sliding window
    (1, 2, 2, 40, 40, 16, 8, True, 0),        # Dv != D
]


@pytest.mark.parametrize("case", ATTN_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_pallas_vs_ref(case, dtype):
    B, Hq, Hkv, Sq, Sk, D, Dv, causal, window = case
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Hq, Sq, D), dtype)
    k = jax.random.normal(ks[1], (B, Hkv, Sk, D), dtype)
    v = jax.random.normal(ks[2], (B, Hkv, Sk, Dv), dtype)
    want = ref.attention(q, k, v, causal=causal, sliding_window=window)
    got = flash_attention_pallas(q, k, v, causal=causal,
                                 sliding_window=window,
                                 block_q=16, block_k=16, interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


@pytest.mark.parametrize("case", ATTN_CASES)
def test_flash_attention_jnp_vs_ref(case):
    B, Hq, Hkv, Sq, Sk, D, Dv, causal, window = case
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Hq, Sq, D))
    k = jax.random.normal(ks[1], (B, Hkv, Sk, D))
    v = jax.random.normal(ks[2], (B, Hkv, Sk, Dv))
    want = ref.attention(q, k, v, causal=causal, sliding_window=window)
    got = ops._flash_jnp(q, k, v, causal, window, None, 0, 16)
    np.testing.assert_allclose(got, want, atol=3e-5, rtol=3e-4)


@pytest.mark.parametrize("case", ATTN_CASES[:4])
def test_flash_attention_custom_vjp_grads(case):
    """Flash backward (custom VJP) == autodiff through naive reference."""
    B, Hq, Hkv, Sq, Sk, D, Dv, causal, window = case
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, Hq, Sq, D))
    k = jax.random.normal(ks[1], (B, Hkv, Sk, D))
    v = jax.random.normal(ks[2], (B, Hkv, Sk, Dv))
    ct = jax.random.normal(ks[3], (B, Hq, Sq, Dv))

    def loss_flash(q, k, v):
        return jnp.sum(ops._flash_jnp(q, k, v, causal, window, None, 0, 16) * ct)

    def loss_ref(q, k, v):
        return jnp.sum(ref.attention(q, k, v, causal=causal,
                                     sliding_window=window) * ct)

    g_flash = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    g_ref = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for gf, gr in zip(g_flash, g_ref):
        np.testing.assert_allclose(gf, gr, atol=2e-4, rtol=2e-3)


def test_decode_attention_matches_ref():
    B, Hq, Hkv, S, D = 2, 4, 2, 24, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Hq, 1, D))
    k = jax.random.normal(ks[1], (B, Hkv, S, D))
    v = jax.random.normal(ks[2], (B, Hkv, S, D))
    cache_len = 17
    want = ref.attention(q, k[:, :, :cache_len], v[:, :, :cache_len],
                         causal=True, q_offset=cache_len - 1)
    got = ops.decode_attention(q, k, v, jnp.int32(cache_len))
    np.testing.assert_allclose(got, want, atol=3e-5, rtol=3e-4)


# ------------------------------------------------------------------ rmsnorm

@pytest.mark.parametrize("shape", [(4, 64), (3, 17, 128), (1, 1, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_pallas_vs_ref(shape, dtype):
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], shape, dtype)
    s = jax.random.normal(ks[1], (shape[-1],), dtype)
    got = rmsnorm_pallas(x, s, interpret=True, block_rows=8)
    want = ref.rmsnorm(x, s)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


# ---------------------------------------------------------------------- ssd

SSD_CASES = [(1, 16, 2, 8, 4, 8), (2, 40, 3, 8, 4, 16), (1, 33, 1, 16, 8, 8)]


@pytest.mark.parametrize("case", SSD_CASES)
def test_ssd_pallas_vs_ref(case):
    Bt, S, H, P, N, chunk = case
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (Bt, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bt, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    B = jax.random.normal(ks[3], (Bt, S, N)) * 0.5
    C = jax.random.normal(ks[4], (Bt, S, N)) * 0.5
    D = jnp.ones((H,))
    yw, hw = ref.ssd_scan(x, dt, A, B, C, D)
    yg, _ = ssd_scan_pallas(x, dt, A, B, C, D, chunk=chunk, interpret=True)
    np.testing.assert_allclose(yg, yw, atol=5e-4, rtol=5e-3)
    yj, hj = ops._ssd_jnp(x, dt, A, B, C, D, chunk=chunk, h0=None)
    np.testing.assert_allclose(yj, yw, atol=5e-4, rtol=5e-3)
    np.testing.assert_allclose(hj, hw, atol=5e-4, rtol=5e-3)


def test_ssd_decode_matches_scan():
    Bt, S, H, P, N = 2, 12, 2, 8, 4
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (Bt, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bt, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    B = jax.random.normal(ks[3], (Bt, S, N)) * 0.5
    C = jax.random.normal(ks[4], (Bt, S, N)) * 0.5
    D = jnp.ones((H,))
    y_ref, h_ref = ref.ssd_scan(x, dt, A, B, C, D)
    h = jnp.zeros((Bt, H, P, N))
    ys = []
    for t in range(S):
        y, h = ops.ssd_decode_step(x[:, t], dt[:, t], A, B[:, t], C[:, t],
                                   D, h)
        ys.append(y)
    np.testing.assert_allclose(jnp.stack(ys, 1), y_ref, atol=5e-4, rtol=5e-3)
    np.testing.assert_allclose(h, h_ref, atol=5e-4, rtol=5e-3)


# -------------------------------------------------------------------- mlstm

@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_mlstm_chunked_vs_ref(chunk):
    B, H, S, Dk, Dv = 2, 2, 37, 16, 8
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (B, H, S, Dk))
    k = jax.random.normal(ks[1], (B, H, S, Dk))
    v = jax.random.normal(ks[2], (B, H, S, Dv))
    ig = jax.random.normal(ks[3], (B, H, S))
    fg = jax.random.normal(ks[4], (B, H, S)) + 2.0
    hw, (Cw, nw, mw) = ref.mlstm_scan(q, k, v, ig, fg)
    hg, (Cg, ng, mg) = ops.mlstm_scan(q, k, v, ig, fg, chunk=chunk)
    np.testing.assert_allclose(hg, hw, atol=5e-4, rtol=5e-3)
    np.testing.assert_allclose(Cg, Cw, atol=5e-4, rtol=5e-3)
    np.testing.assert_allclose(mg, mw, atol=5e-4, rtol=5e-3)


def test_mlstm_decode_matches_scan():
    B, H, S, Dk, Dv = 1, 2, 9, 8, 8
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (B, H, S, Dk))
    k = jax.random.normal(ks[1], (B, H, S, Dk))
    v = jax.random.normal(ks[2], (B, H, S, Dv))
    ig = jax.random.normal(ks[3], (B, H, S))
    fg = jax.random.normal(ks[4], (B, H, S)) + 2.0
    h_ref, _ = ref.mlstm_scan(q, k, v, ig, fg)
    carry = (jnp.zeros((B, H, Dk, Dv)), jnp.zeros((B, H, Dk)),
             jnp.full((B, H), -jnp.inf))
    hs = []
    for t in range(S):
        h, carry = ops.mlstm_decode_step(q[:, :, t], k[:, :, t], v[:, :, t],
                                         ig[:, :, t], fg[:, :, t], carry)
        hs.append(h)
    np.testing.assert_allclose(jnp.stack(hs, 2), h_ref, atol=5e-4, rtol=5e-3)
