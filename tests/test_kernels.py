"""Per-kernel allclose sweeps: Pallas (interpret mode) and the chunked jnp
production paths vs. the naive oracles in kernels/ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.fused_adamw import fused_adamw_update
from repro.kernels.rmsnorm import rmsnorm_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas
from repro.train import optimizer as opt_lib
from repro.train import quantized_state as qs

KEY = jax.random.PRNGKey(42)


def tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=3e-5, rtol=3e-4)


# ---------------------------------------------------------------- attention

ATTN_CASES = [
    # (B, Hq, Hkv, Sq, Sk, D, Dv, causal, window)
    (1, 2, 2, 16, 16, 16, 16, True, 0),
    (2, 4, 2, 48, 48, 32, 32, True, 0),       # GQA
    (1, 4, 1, 33, 65, 16, 16, True, 0),       # ragged sizes, MQA
    (2, 2, 2, 32, 32, 16, 16, False, 0),      # bidirectional
    (1, 2, 2, 64, 64, 16, 16, True, 24),      # sliding window
    (1, 2, 2, 40, 40, 16, 8, True, 0),        # Dv != D
]


@pytest.mark.parametrize("case", ATTN_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_pallas_vs_ref(case, dtype):
    B, Hq, Hkv, Sq, Sk, D, Dv, causal, window = case
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Hq, Sq, D), dtype)
    k = jax.random.normal(ks[1], (B, Hkv, Sk, D), dtype)
    v = jax.random.normal(ks[2], (B, Hkv, Sk, Dv), dtype)
    want = ref.attention(q, k, v, causal=causal, sliding_window=window)
    got = flash_attention_pallas(q, k, v, causal=causal,
                                 sliding_window=window,
                                 block_q=16, block_k=16, interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


@pytest.mark.parametrize("case", ATTN_CASES)
def test_flash_attention_jnp_vs_ref(case):
    B, Hq, Hkv, Sq, Sk, D, Dv, causal, window = case
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Hq, Sq, D))
    k = jax.random.normal(ks[1], (B, Hkv, Sk, D))
    v = jax.random.normal(ks[2], (B, Hkv, Sk, Dv))
    want = ref.attention(q, k, v, causal=causal, sliding_window=window)
    got = ops._flash_jnp(q, k, v, causal, window, None, 0, 16)
    np.testing.assert_allclose(got, want, atol=3e-5, rtol=3e-4)


@pytest.mark.parametrize("case", ATTN_CASES[:4])
def test_flash_attention_custom_vjp_grads(case):
    """Flash backward (custom VJP) == autodiff through naive reference."""
    B, Hq, Hkv, Sq, Sk, D, Dv, causal, window = case
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, Hq, Sq, D))
    k = jax.random.normal(ks[1], (B, Hkv, Sk, D))
    v = jax.random.normal(ks[2], (B, Hkv, Sk, Dv))
    ct = jax.random.normal(ks[3], (B, Hq, Sq, Dv))

    def loss_flash(q, k, v):
        return jnp.sum(ops._flash_jnp(q, k, v, causal, window, None, 0, 16) * ct)

    def loss_ref(q, k, v):
        return jnp.sum(ref.attention(q, k, v, causal=causal,
                                     sliding_window=window) * ct)

    g_flash = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    g_ref = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for gf, gr in zip(g_flash, g_ref):
        np.testing.assert_allclose(gf, gr, atol=2e-4, rtol=2e-3)


def test_decode_attention_matches_ref():
    B, Hq, Hkv, S, D = 2, 4, 2, 24, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Hq, 1, D))
    k = jax.random.normal(ks[1], (B, Hkv, S, D))
    v = jax.random.normal(ks[2], (B, Hkv, S, D))
    cache_len = 17
    want = ref.attention(q, k[:, :, :cache_len], v[:, :, :cache_len],
                         causal=True, q_offset=cache_len - 1)
    got = ops.decode_attention(q, k, v, jnp.int32(cache_len))
    np.testing.assert_allclose(got, want, atol=3e-5, rtol=3e-4)


# ------------------------------------------------------------------ rmsnorm

@pytest.mark.parametrize("shape", [(4, 64), (3, 17, 128), (1, 1, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_pallas_vs_ref(shape, dtype):
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], shape, dtype)
    s = jax.random.normal(ks[1], (shape[-1],), dtype)
    got = rmsnorm_pallas(x, s, interpret=True, block_rows=8)
    want = ref.rmsnorm(x, s)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


# ---------------------------------------------------------------------- ssd

SSD_CASES = [(1, 16, 2, 8, 4, 8), (2, 40, 3, 8, 4, 16), (1, 33, 1, 16, 8, 8)]


@pytest.mark.parametrize("case", SSD_CASES)
def test_ssd_pallas_vs_ref(case):
    Bt, S, H, P, N, chunk = case
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (Bt, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bt, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    B = jax.random.normal(ks[3], (Bt, S, N)) * 0.5
    C = jax.random.normal(ks[4], (Bt, S, N)) * 0.5
    D = jnp.ones((H,))
    yw, hw = ref.ssd_scan(x, dt, A, B, C, D)
    yg, _ = ssd_scan_pallas(x, dt, A, B, C, D, chunk=chunk, interpret=True)
    np.testing.assert_allclose(yg, yw, atol=5e-4, rtol=5e-3)
    yj, hj = ops._ssd_jnp(x, dt, A, B, C, D, chunk=chunk, h0=None)
    np.testing.assert_allclose(yj, yw, atol=5e-4, rtol=5e-3)
    np.testing.assert_allclose(hj, hw, atol=5e-4, rtol=5e-3)


def test_ssd_decode_matches_scan():
    Bt, S, H, P, N = 2, 12, 2, 8, 4
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (Bt, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bt, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    B = jax.random.normal(ks[3], (Bt, S, N)) * 0.5
    C = jax.random.normal(ks[4], (Bt, S, N)) * 0.5
    D = jnp.ones((H,))
    y_ref, h_ref = ref.ssd_scan(x, dt, A, B, C, D)
    h = jnp.zeros((Bt, H, P, N))
    ys = []
    for t in range(S):
        y, h = ops.ssd_decode_step(x[:, t], dt[:, t], A, B[:, t], C[:, t],
                                   D, h)
        ys.append(y)
    np.testing.assert_allclose(jnp.stack(ys, 1), y_ref, atol=5e-4, rtol=5e-3)
    np.testing.assert_allclose(h, h_ref, atol=5e-4, rtol=5e-3)


# -------------------------------------------------------------------- mlstm

@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_mlstm_chunked_vs_ref(chunk):
    B, H, S, Dk, Dv = 2, 2, 37, 16, 8
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (B, H, S, Dk))
    k = jax.random.normal(ks[1], (B, H, S, Dk))
    v = jax.random.normal(ks[2], (B, H, S, Dv))
    ig = jax.random.normal(ks[3], (B, H, S))
    fg = jax.random.normal(ks[4], (B, H, S)) + 2.0
    hw, (Cw, nw, mw) = ref.mlstm_scan(q, k, v, ig, fg)
    hg, (Cg, ng, mg) = ops.mlstm_scan(q, k, v, ig, fg, chunk=chunk)
    np.testing.assert_allclose(hg, hw, atol=5e-4, rtol=5e-3)
    np.testing.assert_allclose(Cg, Cw, atol=5e-4, rtol=5e-3)
    np.testing.assert_allclose(mg, mw, atol=5e-4, rtol=5e-3)


def test_mlstm_decode_matches_scan():
    B, H, S, Dk, Dv = 1, 2, 9, 8, 8
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (B, H, S, Dk))
    k = jax.random.normal(ks[1], (B, H, S, Dk))
    v = jax.random.normal(ks[2], (B, H, S, Dv))
    ig = jax.random.normal(ks[3], (B, H, S))
    fg = jax.random.normal(ks[4], (B, H, S)) + 2.0
    h_ref, _ = ref.mlstm_scan(q, k, v, ig, fg)
    carry = (jnp.zeros((B, H, Dk, Dv)), jnp.zeros((B, H, Dk)),
             jnp.full((B, H), -jnp.inf))
    hs = []
    for t in range(S):
        h, carry = ops.mlstm_decode_step(q[:, :, t], k[:, :, t], v[:, :, t],
                                         ig[:, :, t], fg[:, :, t], carry)
        hs.append(h)
    np.testing.assert_allclose(jnp.stack(hs, 2), h_ref, atol=5e-4, rtol=5e-3)


# ---------------------------------------------------------- fused AdamW

def _adamw_ref_harness(cfg, p, g, m, v, lr, scale, bc1, bc2, *,
                       block_rows=256):
    """``optimizer._adam_leaf`` evaluated inside the *same* interpret-mode
    grid harness as the fused kernel (rows-of-blocks layout, SMEM scalars,
    same block specs).  XLA:CPU contracts mul+add into FMA differently per
    compilation context, so an eager reference is not bitwise comparable —
    the same op sequence in the same harness is.
    """
    from jax.experimental import pallas as pl
    from jax.experimental.pallas import tpu as pltpu
    from repro.kernels.fused_adamw import QBLOCK, _rows_of_blocks

    quantized = isinstance(m, dict)
    shape = p.shape
    L = shape[-1] if p.ndim else 1
    R = int(np.prod(shape[:-1])) if p.ndim > 1 else 1
    Lp = -(-L // QBLOCK) * QBLOCK
    nb = Lp // QBLOCK
    RB = R * nb
    block_rows = min(block_rows, max(RB, 1))
    RBp = -(-RB // block_rows) * block_rows

    def rows(x):
        x = _rows_of_blocks(x, R, L, Lp)
        return jnp.pad(x, ((0, RBp - RB), (0, 0))) if RBp != RB else x

    def srows(s):
        s2 = s.reshape(RB, 1).astype(jnp.float32)
        return jnp.pad(s2, ((0, RBp - RB), (0, 0)), constant_values=1.0) \
            if RBp != RB else s2

    def unrows(x):
        return x[:RB].reshape(R, Lp)[:, :L].reshape(shape)

    sc = jnp.stack([jnp.asarray(x, jnp.float32)
                    for x in (lr, scale, bc1, bc2)])
    ds = pl.BlockSpec((block_rows, QBLOCK), lambda i: (i, 0))
    ss = pl.BlockSpec((block_rows, 1), lambda i: (i, 0))
    grid = (RBp // block_rows,)
    # the reference gates weight decay on the *original* leaf's ndim; the
    # harness always sees 2-D tiles, so pin the branch via a cfg with wd=0
    wd_cfg = cfg if p.ndim >= 2 else type(cfg)(
        **{**cfg.__dict__, "weight_decay": 0.0})

    if not quantized:
        def body(sc_ref, p_ref, g_ref, m_ref, v_ref,
                 np_ref, nm_ref, nv_ref):
            np_, nm_, nv_ = opt_lib._adam_leaf(
                wd_cfg, sc_ref[0], sc_ref[1], sc_ref[2], sc_ref[3],
                p_ref[...], g_ref[...], m_ref[...], v_ref[...])
            np_ref[...] = np_
            nm_ref[...] = nm_
            nv_ref[...] = nv_

        out = pl.pallas_call(
            body, grid=grid,
            in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM), ds, ds, ds, ds],
            out_specs=[ds, ds, ds],
            out_shape=[jax.ShapeDtypeStruct((RBp, QBLOCK), p.dtype),
                       jax.ShapeDtypeStruct((RBp, QBLOCK), jnp.float32),
                       jax.ShapeDtypeStruct((RBp, QBLOCK), jnp.float32)],
            interpret=True,
        )(sc, rows(p), rows(g), rows(m), rows(v))
        return unrows(out[0]), unrows(out[1]), unrows(out[2])

    def body8(sc_ref, p_ref, g_ref, mq_ref, ms_ref, vq_ref, vs_ref,
              np_ref, nmq_ref, nms_ref, nvq_ref, nvs_ref):
        # a (rows, 256) tile has exactly one quant block per row, so the
        # reference's per-block scales ARE the kernel's per-row scales
        np_, nm_, nv_ = opt_lib._adam_leaf(
            wd_cfg, sc_ref[0], sc_ref[1], sc_ref[2], sc_ref[3],
            p_ref[...], g_ref[...],
            {"q": mq_ref[...], "s": ms_ref[...]},
            {"q": vq_ref[...], "s": vs_ref[...]})
        np_ref[...] = np_
        nmq_ref[...] = nm_["q"]
        nms_ref[...] = nm_["s"]
        nvq_ref[...] = nv_["q"]
        nvs_ref[...] = nv_["s"]

    s_shape = (*shape[:-1], nb) if p.ndim else (nb,)
    out = pl.pallas_call(
        body8, grid=grid,
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM),
                  ds, ds, ds, ss, ds, ss],
        out_specs=[ds, ds, ss, ds, ss],
        out_shape=[jax.ShapeDtypeStruct((RBp, QBLOCK), p.dtype),
                   jax.ShapeDtypeStruct((RBp, QBLOCK), jnp.int8),
                   jax.ShapeDtypeStruct((RBp, 1), jnp.float32),
                   jax.ShapeDtypeStruct((RBp, QBLOCK), jnp.int8),
                   jax.ShapeDtypeStruct((RBp, 1), jnp.float32)],
        interpret=True,
    )(sc, rows(p), rows(g), rows(m["q"]), srows(m["s"]),
      rows(v["q"]), srows(v["s"]))
    unscale = lambda s: s[:RB, 0].reshape(s_shape)
    return (unrows(out[0]),
            {"q": unrows(out[1]), "s": unscale(out[2])},
            {"q": unrows(out[3]), "s": unscale(out[4])})


ADAMW_CASES = [
    # (shape, param dtype) — multiples, ragged last dim, stacks, vectors
    ((8, 512), jnp.bfloat16),
    ((8, 300), jnp.bfloat16),        # non-multiple of the 256 quant block
    ((257,), jnp.float32),           # 1-D, ragged
    ((4, 16, 256), jnp.bfloat16),    # stacked (scan_stacked slices)
    ((5, 3, 7), jnp.bfloat16),       # tiny, everything padded
    ((1000,), jnp.float32),
]


@pytest.mark.parametrize("case", ADAMW_CASES)
def test_fused_adamw_pallas_bitwise_f32_state(case):
    shape, dtype = case
    cfg = opt_lib.OptConfig()
    ks = jax.random.split(KEY, 4)
    p = jax.random.normal(ks[0], shape, jnp.float32).astype(dtype)
    g = jax.random.normal(ks[1], shape, jnp.float32)
    m = jax.random.normal(ks[2], shape, jnp.float32) * 0.1
    v = jnp.abs(jax.random.normal(ks[3], shape, jnp.float32)) * 0.01
    lr, scale, bc1, bc2 = 3e-4, 0.7, 0.1, 0.05
    ref = _adamw_ref_harness(cfg, p, g, m, v, lr, scale, bc1, bc2)
    out = fused_adamw_update(
        p, g, m, v, lr=lr, scale=scale, bc1=bc1, bc2=bc2, b1=cfg.b1,
        b2=cfg.b2, eps=cfg.eps, weight_decay=cfg.weight_decay,
        apply_wd=p.ndim >= 2, interpret=True)
    for r, o, name in zip(ref, out, "pmv"):
        assert r.shape == o.shape and r.dtype == o.dtype, name
        assert jnp.array_equal(r, o), name


@pytest.mark.parametrize("case", ADAMW_CASES)
def test_fused_adamw_pallas_bitwise_int8_state(case):
    shape, dtype = case
    cfg = opt_lib.OptConfig(state_bits=8)
    ks = jax.random.split(KEY, 4)
    p = jax.random.normal(ks[0], shape, jnp.float32).astype(dtype)
    g = jax.random.normal(ks[1], shape, jnp.float32)
    m = qs.quantize(jax.random.normal(ks[2], shape, jnp.float32) * 0.1)
    v = qs.quantize(jnp.abs(jax.random.normal(ks[3], shape, jnp.float32))
                    * 0.01)
    lr, scale, bc1, bc2 = 3e-4, 0.7, 0.1, 0.05
    ref = _adamw_ref_harness(cfg, p, g, m, v, lr, scale, bc1, bc2)
    out = fused_adamw_update(
        p, g, m, v, lr=lr, scale=scale, bc1=bc1, bc2=bc2, b1=cfg.b1,
        b2=cfg.b2, eps=cfg.eps, weight_decay=cfg.weight_decay,
        apply_wd=p.ndim >= 2, interpret=True)
    assert jnp.array_equal(ref[0], out[0]), "params"
    for r, o, name in zip(ref[1:], out[1:], "mv"):
        assert r["q"].shape == o["q"].shape, name
        assert r["s"].shape == o["s"].shape, name
        assert jnp.array_equal(r["q"], o["q"]), (name, "codes")
        assert jnp.array_equal(r["s"], o["s"]), (name, "scales")


@pytest.mark.parametrize("bits", [None, 8])
def test_fused_adamw_pallas_multiblock_grid(bits):
    # >1 grid step exercises the block-index map and row padding
    shape = (40, 256)
    cfg = opt_lib.OptConfig(state_bits=bits)
    ks = jax.random.split(KEY, 4)
    p = jax.random.normal(ks[0], shape, jnp.float32)
    g = jax.random.normal(ks[1], shape, jnp.float32)
    m0 = jax.random.normal(ks[2], shape, jnp.float32) * 0.1
    v0 = jnp.abs(jax.random.normal(ks[3], shape, jnp.float32)) * 0.01
    m = qs.quantize(m0) if bits == 8 else m0
    v = qs.quantize(v0) if bits == 8 else v0
    lr, scale, bc1, bc2 = 1e-3, 1.0, 0.5, 0.3
    ref = _adamw_ref_harness(cfg, p, g, m, v, lr, scale, bc1, bc2,
                             block_rows=16)
    out = fused_adamw_update(
        p, g, m, v, lr=lr, scale=scale, bc1=bc1, bc2=bc2, b1=cfg.b1,
        b2=cfg.b2, eps=cfg.eps, weight_decay=cfg.weight_decay,
        apply_wd=True, block_rows=16, interpret=True)
    ref_p, ref_m, ref_v = ref
    out_p, out_m, out_v = out
    eq = jax.tree.map(lambda a, b: bool(jnp.array_equal(a, b)),
                      (ref_m, ref_v), (out_m, out_v))
    assert all(jax.tree.leaves(eq)), eq
    if bits == 8:
        # with int8 state + f32 params under a multi-program grid the
        # reference's dequantize reshapes perturb XLA:CPU fusion enough to
        # flip mul+add contraction in the delta chain — the bitwise-equal
        # m/v already prove the index map and row padding; allow 1 ulp on p
        assert jnp.max(jnp.abs(ref_p - out_p)) <= 2 ** -23 * jnp.max(
            jnp.abs(ref_p)), "p beyond 1 ulp"
    else:
        assert jnp.array_equal(ref_p, out_p)


def test_fused_adamw_jnp_fallback_matches_adam_leaf():
    # the CPU fallback replays the reference op sequence literally — it
    # must be bitwise identical *eagerly*, no harness needed
    for bits in (None, 8):
        cfg = opt_lib.OptConfig(state_bits=bits)
        ks = jax.random.split(KEY, 4)
        p = jax.random.normal(ks[0], (8, 300), jnp.float32)
        g = jax.random.normal(ks[1], (8, 300), jnp.float32)
        m0 = jax.random.normal(ks[2], (8, 300), jnp.float32) * 0.1
        v0 = jnp.abs(jax.random.normal(ks[3], (8, 300), jnp.float32)) * 0.01
        m = qs.quantize(m0) if bits == 8 else m0
        v = qs.quantize(v0) if bits == 8 else v0
        lr, scale, bc1, bc2 = 3e-4, 0.7, 0.1, 0.05
        ref = opt_lib._adam_leaf(cfg, lr, scale, bc1, bc2, p, g, m, v)
        out = ops.fused_adamw(
            p, g, m, v, lr=lr, scale=scale, bc1=bc1, bc2=bc2, b1=cfg.b1,
            b2=cfg.b2, eps=cfg.eps, weight_decay=cfg.weight_decay,
            impl="jnp")
        eq = jax.tree.map(lambda a, b: bool(jnp.array_equal(a, b)),
                          list(ref), list(out))
        assert all(jax.tree.leaves(eq)), (bits, eq)


def test_fused_adamw_pallas_close_to_eager_reference():
    # compilation-context FMA aside, the kernel must track the eager
    # reference to ~1 ulp on every output
    cfg = opt_lib.OptConfig()
    ks = jax.random.split(KEY, 4)
    p = jax.random.normal(ks[0], (16, 384), jnp.float32)
    g = jax.random.normal(ks[1], (16, 384), jnp.float32)
    m = jax.random.normal(ks[2], (16, 384), jnp.float32) * 0.1
    v = jnp.abs(jax.random.normal(ks[3], (16, 384), jnp.float32)) * 0.01
    lr, scale, bc1, bc2 = 3e-4, 0.7, 0.1, 0.05
    ref = opt_lib._adam_leaf(cfg, lr, scale, bc1, bc2, p, g, m, v)
    out = fused_adamw_update(
        p, g, m, v, lr=lr, scale=scale, bc1=bc1, bc2=bc2, b1=cfg.b1,
        b2=cfg.b2, eps=cfg.eps, weight_decay=cfg.weight_decay,
        apply_wd=True, interpret=True)
    for r, o in zip(ref, out):
        np.testing.assert_allclose(np.asarray(r, np.float32),
                                   np.asarray(o, np.float32),
                                   rtol=1e-6, atol=1e-7)
