"""Continuous-batching serve data plane.

Layers under test, bottom-up:

* the Pallas paged-attention gather kernel vs ``ref.attention`` —
  **bit-for-bit** in interpret mode (the kernel replicates the reference's
  op sequence exactly, so ``array_equal``, not ``allclose``);
* ``DecodeScheduler`` semantics: bit-identical paged-vs-dense greedy
  decode, ``cache_len=0`` (the falsy-zero trap the analysis rule pack
  hunts), page-boundary crossing, full-pool admission refusal,
  evict/requeue determinism, slot isolation;
* the ``BlockRuntime`` session API + daemon/engine event plumbing
  (``generate``/``session`` kinds);
* ``POST /v1/blocks/<id>/generate`` over real HTTP: SSE stream, long-poll
  JSON, 429 rate-limit storm, 413 body cap;
* checkpointed paged state: in-flight sessions survive preempt/resume,
  including resume on a different mesh geometry (subprocess).
"""
import json
import os
import subprocess
import sys
import textwrap
import time
import urllib.error
import urllib.request

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.paged_attention import paged_attention_pallas
from repro.models import model as model_lib
from repro.models.config import AttentionConfig, ModelConfig, ShapeConfig
from repro.serve.decode_scheduler import DecodeScheduler, PagePool

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
KEY = jax.random.PRNGKey(7)


def tiny_cfg(**kw):
    base = dict(name="serve_t", family="dense", n_layers=2, d_model=32,
                vocab_size=64, d_ff=64,
                attention=AttentionConfig(n_heads=4, n_kv_heads=2,
                                          head_dim=8),
                param_dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


@pytest.fixture(scope="module")
def cfg():
    return tiny_cfg()


@pytest.fixture(scope="module")
def params(cfg):
    return model_lib.init_params(cfg, jax.random.PRNGKey(0))


def drain(sch, cap=500):
    ems = []
    for _ in range(cap):
        if not sch.has_work:
            return ems
        ems.extend(sch.step())
    raise AssertionError("scheduler did not drain")


def greedy_dense(cfg, params, prompt, max_new, smax):
    """Reference decode: dense prefill + per-token decode_step, greedy."""
    cache = model_lib.init_cache(cfg, 1, smax)
    logits, cache = model_lib.prefill(
        cfg=cfg, params=params, batch={"tokens": jnp.asarray([prompt],
                                                             jnp.int32)},
        cache=cache)
    toks = [int(jnp.argmax(logits[0], -1))]
    for i in range(max_new - 1):
        lg, cache = model_lib.decode_step(
            params, cfg, jnp.asarray([[toks[-1]]], jnp.int32), cache,
            jnp.int32(len(prompt) + i))
        toks.append(int(jnp.argmax(lg[0], -1)))
    return toks


# ======================================================== kernel vs ref

def make_paged(key, B, Hkv, D, Dv, page, maxp, n_pages, lens):
    """Random pool + per-slot tables; page 0 is the (garbage) trash page."""
    ks = jax.random.split(key, 3)
    k_pages = jax.random.normal(ks[0], (n_pages, page, Hkv, D), jnp.float32)
    v_pages = jax.random.normal(ks[1], (n_pages, page, Hkv, Dv), jnp.float32)
    rng = np.random.default_rng(3)
    free = list(rng.permutation(np.arange(1, n_pages)))
    table = np.zeros((B, maxp), np.int32)      # unallocated -> trash page
    for b, ln in enumerate(lens):
        for j in range((ln + page - 1) // page):
            table[b, j] = free.pop()
    return k_pages, v_pages, jnp.asarray(table), jnp.asarray(lens, jnp.int32)


def gather_dense(pages, table, B, S, H):
    """(B, S, Hkv, D) dense view of each slot's gathered pages."""
    return pages[table].reshape(B, S, H, -1).swapaxes(1, 2)


@pytest.mark.parametrize("B,Hq,Hkv,D,Dv,page,maxp",
                         [(3, 4, 2, 16, 16, 8, 2),    # GQA
                          (2, 4, 1, 16, 8, 4, 4),     # MQA, Dv != D
                          (1, 2, 2, 8, 8, 16, 1)])    # MHA, single page
def test_paged_kernel_bitwise_vs_ref_full_slots(B, Hq, Hkv, D, Dv, page,
                                                maxp):
    """Every slot filled to capacity: the length mask is all-true, so the
    kernel must reproduce ``ref.attention`` on the gathered dense layout
    bit-for-bit (same fp32 einsums, same softmax)."""
    S = page * maxp
    lens = [S] * B
    k_pages, v_pages, table, seq_lens = make_paged(
        KEY, B, Hkv, D, Dv, page, maxp, n_pages=B * maxp + 2, lens=lens)
    q = jax.random.normal(jax.random.fold_in(KEY, 9), (B, Hq, D))
    got = paged_attention_pallas(q, k_pages, v_pages, table, seq_lens,
                                 interpret=True)
    kd = gather_dense(k_pages, table, B, S, Hkv)
    vd = gather_dense(v_pages, table, B, S, Hkv)
    want = ref.attention(q[:, :, None], kd, vd, causal=False)[:, :, 0]
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_paged_kernel_ragged_lens_and_trash_page():
    """Ragged fills (1 token, mid-page, page boundary): the kernel must
    match the jnp production path bitwise (identical op sequence on the
    identical masked layout) and ``ref.attention`` on the *truncated*
    cache numerically — rows past ``seq_lens`` (garbage pages, trash page)
    must not leak in."""
    B, Hq, Hkv, D, page, maxp = 4, 4, 2, 16, 4, 3
    lens = [1, 5, 8, 12]                      # mid-page / boundary / full
    k_pages, v_pages, table, seq_lens = make_paged(
        jax.random.fold_in(KEY, 1), B, Hkv, D, D, page, maxp,
        n_pages=B * maxp + 1, lens=lens)
    # poison the trash page: a masking bug would surface immediately
    k_pages = k_pages.at[0].set(1e4)
    v_pages = v_pages.at[0].set(-1e4)
    q = jax.random.normal(jax.random.fold_in(KEY, 2), (B, Hq, 1, D))
    got = ops.paged_attention(q, k_pages, v_pages, table, seq_lens,
                              impl="pallas")
    want = ops.paged_attention(q, k_pages, v_pages, table, seq_lens,
                               impl="jnp")
    assert np.array_equal(np.asarray(got), np.asarray(want))
    for b, ln in enumerate(lens):             # vs truncated naive oracle
        kd = gather_dense(k_pages, table[b:b + 1], 1, page * maxp, Hkv)
        vd = gather_dense(v_pages, table[b:b + 1], 1, page * maxp, Hkv)
        w = ref.attention(q[b:b + 1], kd[:, :, :ln], vd[:, :, :ln],
                          causal=False)
        np.testing.assert_allclose(np.asarray(got[b:b + 1]), np.asarray(w),
                                   atol=3e-5, rtol=3e-4)


def test_paged_decode_model_matches_dense_bitwise(cfg, params):
    """``decode_step_paged`` == ``decode_step`` bit-for-bit when the paged
    layout mirrors a contiguous dense cache of the same attention width
    (equal S is required: softmax reduction width changes the bits)."""
    page, maxp = 4, 4
    smax = page * maxp
    prompt = [3, 1, 4, 1, 5]
    cache = model_lib.init_cache(cfg, 1, smax)
    logits, cache = model_lib.prefill(
        cfg=cfg, params=params,
        batch={"tokens": jnp.asarray([prompt], jnp.int32)}, cache=cache)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)

    pool = model_lib.init_paged_cache(cfg, n_pages=maxp + 1, page_size=page)
    pool = model_lib.write_prefill_to_pages(pool, cache, [1, 2, 3, 4], page)
    table = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
    for i in range(3):
        want, cache = model_lib.decode_step(params, cfg, tok, cache,
                                            jnp.int32(len(prompt) + i))
        got, pool = model_lib.decode_step_paged(
            params, cfg, tok, pool, table,
            jnp.asarray([len(prompt) + i], jnp.int32))
        assert np.array_equal(np.asarray(got), np.asarray(want))
        tok = jnp.argmax(got, -1)[:, None].astype(jnp.int32)


# ================================================= scheduler semantics

def test_paged_greedy_decode_bit_identical_to_dense(cfg, params):
    """End-to-end token identity, including the admission prefill with
    ``cache_len=0`` (falsy-zero trap: a ``0`` must mean "empty cache",
    never "no cache") and prompts of every page-alignment flavour."""
    page, max_seq = 4, 32
    prompts = [[9], [3, 1, 4], [3, 1, 4, 1], [3, 1, 4, 1, 5]]
    sch = DecodeScheduler(cfg, params, page_size=page, n_pages=0,
                          max_slots=len(prompts), max_seq_len=max_seq)
    sids = [sch.submit(p, max_new_tokens=10) for p in prompts]
    drain(sch)
    for sid, p in zip(sids, prompts):
        assert sch.sessions[sid].generated == greedy_dense(
            cfg, params, p, 10, max_seq), f"prompt {p} diverged"


def test_page_boundary_crossing_grows_allocation(cfg, params):
    """Generation across page boundaries allocates pages on demand and the
    tokens stay identical to the dense path (an off-by-one at the
    boundary would corrupt the row the next K/V write lands in)."""
    page = 4
    prompt = [3, 1, 4]                       # 1 page; crosses at pos 4, 8
    sch = DecodeScheduler(cfg, params, page_size=page, n_pages=0,
                          max_slots=1, max_seq_len=16)
    sid = sch.submit(prompt, max_new_tokens=9)   # final pos 11 -> 3 pages
    sch.step()
    assert len(sch.sessions[sid].pages) == 1
    peak = 1
    while sch.has_work:
        sch.step()
        peak = max(peak, len(sch.sessions[sid].pages))
    assert peak == 3                          # grown page by page, on demand
    assert sch.sessions[sid].pages == []      # reclaimed on finish
    assert sch.sessions[sid].generated == greedy_dense(
        cfg, params, prompt, 9, 16)


def test_full_pool_admission_refusal_then_progress(cfg, params):
    """All-or-nothing admission: with every page owned by the running
    session the queued one must NOT be half-admitted — it waits, then
    completes once the pool frees."""
    page = 4
    # 3 usable pages (page 0 reserved): session A needs 2 on admission
    sch = DecodeScheduler(cfg, params, page_size=page, n_pages=4,
                          max_slots=2, max_seq_len=12)
    a = sch.submit([1, 2, 3, 4, 5], max_new_tokens=6)   # 2 pages
    b = sch.submit([6, 7, 8, 9, 10], max_new_tokens=6)  # needs 2: refused
    sch.step()
    assert sch.sessions[a].state == "running"
    assert sch.sessions[b].state == "queued"
    assert sch.pages.available == 1           # partial grab would show here
    drain(sch)
    assert sch.sessions[a].state == "done"
    assert sch.sessions[b].state == "done"
    assert sch.sessions[b].generated == greedy_dense(
        cfg, params, [6, 7, 8, 9, 10], 6, 12)
    assert sch.pages.available == 3           # everything reclaimed


def test_eviction_requeue_resumes_exactly(cfg, params):
    """Pool pressure evicts the shortest-progress victim; the evicted
    session re-queues with its generated prefix as prompt context and
    must finish with the same tokens as an uninterrupted run."""
    sch = DecodeScheduler(cfg, params, page_size=4, n_pages=6,
                          max_slots=3, max_seq_len=32)
    sids = [sch.submit([s, s + 1, s + 2], max_new_tokens=12)
            for s in (1, 4, 7)]
    drain(sch)
    assert sch.evictions > 0                  # the pressure actually hit
    assert all(sch.sessions[s].state == "done" for s in sids)
    for sid, s in zip(sids, (1, 4, 7)):
        assert sch.sessions[sid].generated == greedy_dense(
            cfg, params, [s, s + 1, s + 2], 12, 32)


def test_idle_slots_do_not_contaminate(cfg, params):
    """A lone session surrounded by idle slots (``seq_lens == 0``, trash
    page table rows) must decode exactly as a max_slots=1 scheduler —
    zero-length masking treating 0 as "no mask" would leak garbage."""
    solo = DecodeScheduler(cfg, params, page_size=4, n_pages=0,
                           max_slots=1, max_seq_len=16)
    wide = DecodeScheduler(cfg, params, page_size=4, n_pages=0,
                           max_slots=8, max_seq_len=16)
    a = solo.submit([5, 6, 7], max_new_tokens=8)
    b = wide.submit([5, 6, 7], max_new_tokens=8)
    drain(solo), drain(wide)
    assert solo.sessions[a].generated == wide.sessions[b].generated


def test_submit_validation_and_eos(cfg, params):
    sch = DecodeScheduler(cfg, params, page_size=4, n_pages=0,
                          max_slots=2, max_seq_len=8)
    with pytest.raises(ValueError):
        sch.submit([], max_new_tokens=2)                # empty prompt
    with pytest.raises(ValueError):
        sch.submit([1] * 8, max_new_tokens=2)           # >= max_seq_len
    with pytest.raises(ValueError):
        sch.submit([1], max_new_tokens=0)
    sid = sch.submit([1, 2], max_new_tokens=6)
    with pytest.raises(ValueError):
        sch.submit([3], sid=sid)                        # duplicate id
    # eos_id == first generated token -> finishes after exactly 1 token
    first = greedy_dense(cfg, params, [1, 2], 1, 8)[0]
    eos = sch.submit([1, 2], max_new_tokens=6, eos_id=first)
    drain(sch)
    assert sch.sessions[eos].generated == [first]
    assert sch.sessions[eos].finish_reason == "eos"
    assert sch.sessions[sid].finish_reason == "length"


def test_page_pool_invariants():
    pool = PagePool(n_pages=5)                # page 0 reserved
    assert pool.available == 4
    got = pool.alloc(4)
    assert sorted(got) == [1, 2, 3, 4] and pool.alloc(1) is None
    pool.release([got[0]])
    assert pool.available == 1
    with pytest.raises(AssertionError):
        pool.release([0])                     # trash page is never released


# ============================================ scheduler state round-trip

def test_scheduler_state_roundtrip_mid_flight(cfg, params):
    """state_tree -> load_state into a fresh scheduler reproduces the
    exact remaining token stream (pool bits, tables, session metadata and
    the queued/running split all survive)."""
    geom = dict(page_size=4, n_pages=6, max_slots=2, max_seq_len=32)
    a = DecodeScheduler(cfg, params, **geom)
    sids = [a.submit([s, s + 1], max_new_tokens=10) for s in (1, 5, 9)]
    for _ in range(4):
        a.step()
    tree = jax.tree.map(np.copy, a.state_tree())

    b = DecodeScheduler(cfg, params, init_pool=False, **geom)
    b.load_state(tree)
    assert {s: b.sessions[s].generated for s in b.sessions} == \
           {s: a.sessions[s].generated for s in a.sessions}
    drain(a), drain(b)
    for sid in sids:
        assert a.sessions[sid].generated == b.sessions[sid].generated
        assert b.sessions[sid].state == "done"


def test_abstract_state_matches_concrete(cfg, params):
    geom = dict(page_size=4, n_pages=6, max_slots=2, max_seq_len=32)
    sch = DecodeScheduler(cfg, params, **geom)
    sch.submit([1, 2, 3], max_new_tokens=4)
    sch.step()
    concrete = sch.state_tree()
    abstract = DecodeScheduler.abstract_state(cfg, **geom)
    cl = jax.tree.leaves(concrete)
    al = jax.tree.leaves(abstract)
    assert len(cl) == len(al)
    for c, ab in zip(cl, al):
        assert tuple(np.shape(c)) == tuple(ab.shape)
        assert np.asarray(c).dtype == ab.dtype


# ===================================== runtime + daemon event plumbing

def make_daemon(tmp_path, **kw):
    from repro.core.daemon import ClusterDaemon
    from repro.core.topology import Topology
    topo = Topology(n_pods=1, pod_x=2, pod_y=1)
    dev = jax.devices()[0]
    return ClusterDaemon(topo, devices=[dev] * topo.n_chips,
                         ckpt_root=str(tmp_path / "ckpt"), **kw)


def paged_job(cfg, **kw):
    from repro.core.runtime import JobSpec
    shape = ShapeConfig("s", "serve", seq_len=32, global_batch=1)
    geom = dict(paged=True, page_size=4, max_slots=4)
    geom.update(kw)
    return JobSpec(cfg, shape, kind="serve", **geom)


@pytest.mark.slow
def test_runtime_session_api_and_event_kinds(tmp_path, cfg):
    """start_session -> engine-driven decode -> harvested emissions surface
    on the bus as ``generate``/``session`` events, in order, and the
    engine quiesces (idle_serve) once the session finishes."""
    d = make_daemon(tmp_path)
    app, _ = d.submit("alice", "serve", 1, job=paged_job(cfg))
    rt = d.runtime(app)
    assert rt.idle_serve                      # no sessions yet
    sid = d.generate(app, [7, 8, 9], max_new_tokens=5)
    d.autostep_enable(app)
    for i in range(12):
        d.autostep_round(now=1.0 + i)
    evs = [e for e in d.events_since(0)
           if e.kind in ("generate", "session")
           and e.payload.get("session") == sid]
    gen = [e for e in evs if e.kind == "generate"]
    assert [e.payload["index"] for e in gen] == list(range(5))
    assert [e.payload["done"] for e in gen] == [False] * 4 + [True]
    actions = [e.payload["action"] for e in evs if e.kind == "session"]
    assert actions == ["submitted", "admitted", "finished"]
    assert rt.sessions.sessions[sid].generated == \
        [e.payload["token"] for e in gen]
    assert rt.idle_serve                      # engine goes quiet again
    before = d.bus.latest_seq
    for i in range(3):
        d.autostep_round(now=20.0 + i)
    assert d.bus.latest_seq == before         # no idle step/event chatter
    # generate against a non-paged target refuses cleanly
    with pytest.raises((ValueError, KeyError)):
        d.generate("nope", [1])


@pytest.mark.slow
def test_paged_sessions_survive_preempt_resume(tmp_path, cfg, params):
    """An in-flight session's pool/page-table/metadata checkpoint on
    preemption and the resumed block finishes the stream bit-identically
    to an uninterrupted run."""
    d = make_daemon(tmp_path)
    app, _ = d.submit("alice", "serve", 1, job=paged_job(cfg))
    rt = d.runtime(app)
    sid = rt.start_session([7, 8, 9], max_new_tokens=10)
    rt.feed(rounds=4)
    partial = list(rt.sessions.sessions[sid].generated)
    assert 0 < len(partial) < 10

    d.preempt(app, reason="pool checkpoint test")
    assert rt.sessions is None                # suspended: state on disk only
    d.tick()                                  # auto-resume
    sess = rt.sessions.sessions[sid]
    assert sess.generated == partial          # nothing lost, nothing replayed
    while rt.sessions.has_work:
        rt.feed()
    ref_sch = DecodeScheduler(rt.job.cfg, rt.state["params"], page_size=4,
                              max_slots=4, max_seq_len=32)
    x = ref_sch.submit([7, 8, 9], max_new_tokens=10)
    drain(ref_sch)
    assert sess.state == "done"
    assert sess.generated == ref_sch.sessions[x].generated


@pytest.mark.slow
def test_cross_geometry_resume_of_active_session(tmp_path):
    """Suspend a paged serve block on a 2-chip mesh, resume on 1 chip: the
    checkpoint manager reshards params onto the new mesh and the rebuilt
    scheduler continues the session bit-identically.  Needs >1 device, so
    runs in a subprocess (dry-run isolation rule)."""
    code = f"""
    import jax, numpy as np
    import repro.configs as C
    from repro.core.controller import ClusterController
    from repro.core.runtime import JobSpec
    from repro.core.topology import Topology
    from repro.models.config import ShapeConfig
    from repro.serve.decode_scheduler import DecodeScheduler

    topo = Topology(n_pods=1, pod_x=4, pod_y=2)
    ctl = ClusterController(topo, ckpt_root={str(tmp_path)!r})
    cfg = C.get_smoke("mistral_nemo_12b")
    shape = ShapeConfig("s", "serve", seq_len=32, global_batch=1)
    job = JobSpec(cfg, shape, kind="serve", paged=True, page_size=4,
                  max_slots=4)
    a, g = ctl.submit("alice", "serve", 2, job=job)
    assert g.mesh_shape in ((1, 2), (2, 1)), g.mesh_shape
    rt = ctl.runtimes[a]
    sid = rt.start_session([5, 6, 7], max_new_tokens=10)
    rt.feed(rounds=3)
    ctl.preempt(a, "geometry test")
    grant = ctl.resume(a, n_chips=1)
    assert grant.mesh_shape == (1, 1), grant.mesh_shape
    rt = ctl.runtimes[a]
    while rt.sessions.has_work:
        rt.feed()
    toks = rt.sessions.sessions[sid].generated
    sch = DecodeScheduler(cfg, rt.state["params"], page_size=4,
                          max_slots=4, max_seq_len=32)
    x = sch.submit([5, 6, 7], max_new_tokens=10)
    while sch.has_work:
        sch.step()
    assert toks == sch.sessions[x].generated, (toks,
                                               sch.sessions[x].generated)
    ctl.partitioner.check_invariants()
    print("SERVE_GEOMETRY_OK")
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=600, env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "SERVE_GEOMETRY_OK" in r.stdout


# ================================================= generate over the wire

SERVE_JOB = {"kind": "serve", "arch": "mistral_nemo_12b", "paged": True,
             "page_size": 4, "max_slots": 4, "seq_len": 32,
             "global_batch": 1}


@pytest.fixture
def gw(tmp_path):
    from repro.gateway import GatewayServer, ProfileStore, UserProfile
    daemon = make_daemon(tmp_path, background=True, tick_interval_s=0.01)
    profiles = ProfileStore([UserProfile("alice", "tok-alice"),
                             UserProfile("bob", "tok-bob")])
    server = GatewayServer(daemon, profiles).start()
    yield server, daemon
    server.stop()
    daemon.stop()


def req(server, method, path, token=None, body=None, timeout=30):
    r = urllib.request.Request(server.url + path, method=method,
                               data=(json.dumps(body).encode()
                                     if body is not None else None))
    if token:
        r.add_header("Authorization", f"Bearer {token}")
    try:
        with urllib.request.urlopen(r, timeout=timeout) as resp:
            return resp.status, json.loads(resp.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read())


def submit_paged(server, token="tok-alice"):
    s, a = req(server, "POST", "/v1/submit", token,
               {"job_description": "serve", "n_chips": 1,
                "job": SERVE_JOB})
    assert s == 201 and a["admitted"], a
    return a["app_id"]


@pytest.mark.slow
def test_generate_sse_stream_over_the_wire(gw):
    """The quickstart path: submit a paged serve block, POST a prompt,
    read the token-by-token SSE stream to the final frame."""
    server, daemon = gw
    app = submit_paged(server)
    r = urllib.request.Request(
        server.url + f"/v1/blocks/{app}/generate", method="POST",
        data=json.dumps({"prompt": [5, 6, 7],
                         "max_new_tokens": 6}).encode())
    r.add_header("Authorization", "Bearer tok-alice")
    frames = []
    with urllib.request.urlopen(r, timeout=30) as resp:
        assert resp.headers["Content-Type"].startswith("text/event-stream")
        cur = {}
        for raw in resp:
            line = raw.decode().rstrip("\n")
            if line.startswith("event: "):
                cur["event"] = line[7:]
            elif line.startswith("data: "):
                cur["data"] = json.loads(line[6:])
            elif line == "" and cur.get("data"):
                frames.append(cur)
                cur = {}
    gen = [f for f in frames if f["event"] == "generate"]
    assert [f["data"]["index"] for f in gen] == list(range(6))
    assert gen[-1]["data"]["done"] is True
    acts = [f["data"]["action"] for f in frames if f["event"] == "session"]
    assert acts[0] == "submitted" and "admitted" in acts
    # the streamed tokens are the session's actual output
    rt = daemon.runtime(app)
    sid = gen[0]["data"]["session"]
    assert [f["data"]["token"] for f in gen] == \
        rt.sessions.sessions[sid].generated
    req(server, "POST", f"/v1/blocks/{app}/expire", "tok-alice", {})


@pytest.mark.slow
def test_generate_longpoll_validation_and_ownership(gw):
    server, daemon = gw
    app = submit_paged(server)
    s, out = req(server, "POST", f"/v1/blocks/{app}/generate", "tok-alice",
                 {"prompt": [9, 9], "max_new_tokens": 4, "stream": False})
    assert s == 200 and out["done"] and len(out["tokens"]) == 4
    # two concurrent sessions keep their streams apart
    s2, out2 = req(server, "POST", f"/v1/blocks/{app}/generate",
                   "tok-alice", {"prompt": [1, 2, 3], "max_new_tokens": 4,
                                 "stream": False})
    assert s2 == 200 and out2["session"] != out["session"]
    # malformed prompts never reach the scheduler
    for bad in [None, [], [1.5], [-1], [True], "abc"]:
        s, e = req(server, "POST", f"/v1/blocks/{app}/generate",
                   "tok-alice", {"prompt": bad, "stream": False})
        assert s == 400, bad
    s, _ = req(server, "POST", f"/v1/blocks/{app}/generate", "tok-alice",
               {"prompt": [1], "max_new_tokens": 0, "stream": False})
    assert s == 400
    # ownership: bob cannot generate on alice's block
    s, _ = req(server, "POST", f"/v1/blocks/{app}/generate", "tok-bob",
               {"prompt": [1], "stream": False})
    assert s == 403
    # a dense (non-paged) serve block has no generate surface -> 409
    s, dense = req(server, "POST", "/v1/submit", "tok-bob",
                   {"job_description": "dense", "n_chips": 1,
                    "job": {"kind": "serve", "arch": "mistral_nemo_12b",
                            "seq_len": 32, "global_batch": 1}})
    assert s == 201
    s, e = req(server, "POST",
               f"/v1/blocks/{dense['app_id']}/generate", "tok-bob",
               {"prompt": [1], "stream": False})
    assert s == 409 and "paged" in e["error"]
    for a, t in [(app, "tok-alice"), (dense["app_id"], "tok-bob")]:
        req(server, "POST", f"/v1/blocks/{a}/expire", t, {})


@pytest.mark.slow
def test_generate_storm_429_and_body_cap_413(tmp_path):
    """Satellite hardening: the generate endpoint sits behind the same
    per-session token bucket (429 on a storm) and body cap (413 on an
    oversized prompt) as every other authed route."""
    from repro.gateway import GatewayServer, ProfileStore, UserProfile
    daemon = make_daemon(tmp_path, background=True, tick_interval_s=0.01)
    profiles = ProfileStore([UserProfile("alice", "tok-alice"),
                             UserProfile("bob", "tok-bob")])
    server = GatewayServer(daemon, profiles, rate_limit_rps=0.001,
                           rate_limit_burst=4, max_body_bytes=2048).start()
    try:
        cfg = tiny_cfg()
        app = submit_paged(server)            # burst 1
        gen = f"/v1/blocks/{app}/generate"
        body = {"prompt": [1, 2], "max_new_tokens": 2, "stream": False}
        codes = [req(server, "POST", gen, "tok-alice", body)[0]
                 for _ in range(6)]
        assert codes[:3] == [200, 200, 200], codes    # burst 2..4
        assert codes[3:] == [429, 429, 429], codes    # storm throttled
        s, e = req(server, "POST", gen, "tok-alice", body)
        assert s == 429 and e["retry_after_s"] > 0
        # another user's bucket is untouched by alice's storm
        app_b = submit_paged(server, "tok-bob")
        s, _ = req(server, "POST", f"/v1/blocks/{app_b}/generate",
                   "tok-bob", {"prompt": [3], "max_new_tokens": 2,
                               "stream": False})
        assert s == 200
        # oversized prompt body: refused by the cap before parsing (the
        # server may close the socket without reading the body)
        try:
            s, e = req(server, "POST", f"/v1/blocks/{app_b}/generate",
                       "tok-bob", {"prompt": list(range(1000)),
                                   "stream": False})
            assert s == 413 and "cap" in e["error"]
        except (ConnectionError, urllib.error.URLError):
            pass
        assert req(server, "GET", "/v1/ping")[0] == 200   # still serving
    finally:
        server.stop()
        daemon.stop()
